"""Distributed layer tests.

Sharding-rule resolution runs in-process (pure metadata).  Everything that
needs multiple devices runs in ONE subprocess with 8 fake CPU devices
(XLA_FLAGS must be set before jax initializes, and the main test process
must keep its single-device view for the other tests).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.compression import wire_bytes
from repro.distributed.pipeline import bubble_fraction

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# In-process: rule resolution (no devices needed — uses AbstractMesh)
# ---------------------------------------------------------------------------
def _abstract_mesh(*name_size_pairs):
    """AbstractMesh across JAX versions: the current API takes
    ``((name, size), ...)`` pairs; older releases took (shape, names)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(name_size_pairs))
    except TypeError:  # pre-0.4.36 signature
        names, sizes = zip(*name_size_pairs)
        return AbstractMesh(tuple(sizes), tuple(names))


def _mesh_16x16():
    return _abstract_mesh(("data", 16), ("model", 16))


def test_resolve_divisible_axes():
    mesh = _mesh_16x16()
    rules = {"heads": "model", "embed": None}
    spec = shd.resolve_spec(P("embed", "heads"), (1024, 4096), rules, mesh)
    assert spec == P(None, "model")


def test_resolve_indivisible_falls_back_to_replication():
    mesh = _mesh_16x16()
    rules = {"heads": "model"}
    # 3 heads (custom-encoder) cannot shard 16 ways -> replicate
    spec = shd.resolve_spec(P(None, "heads"), (200, 198), rules, mesh)
    assert spec == P()


def test_resolve_no_axis_reuse():
    mesh = _mesh_16x16()
    rules = {"a": "model", "b": "model"}
    spec = shd.resolve_spec(P("a", "b"), (64, 64), rules, mesh)
    assert spec == P("model")  # second use of 'model' dropped


def test_strategy_for_mesh_multi_pod():
    mesh = _abstract_mesh(("pod", 2), ("data", 16), ("model", 16))
    s = shd.strategy_for_mesh(mesh)
    assert s.dp_axes == ("pod", "data") and s.tp_axis == "model"


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


def test_compression_wire_bytes_save():
    n = 10_000_000
    assert wire_bytes(n, 256, compressed=True) < \
        0.7 * wire_bytes(n, 256, compressed=False)


# ---------------------------------------------------------------------------
# Subprocess: 8 fake devices
# ---------------------------------------------------------------------------
_SUBPROCESS_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

B, S = int(sys.argv[1]), int(sys.argv[2])
train_only = len(sys.argv) > 3 and sys.argv[3] == "train_only"
results = {}

# --- 1. sharded train step == single-device train step ---------------------
from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.distributed import sharding as shd
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainStepConfig, init_state,
                                       make_step_fn, make_train_step)

cfg = reduced(get_config("qwen1.5-0.5b"))
model = Model(cfg)
oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
state = init_state(model, jax.random.PRNGKey(0), oc)
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

single = jax.jit(make_step_fn(model, TrainStepConfig(optimizer=oc)))
s1, m1 = single(state, batch)

mesh = jax.make_mesh((4, 2), ("data", "model"))
strategy = shd.strategy_for_mesh(mesh)
specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
jitted, st_sh, b_sh = make_train_step(model, mesh, strategy,
                                      TrainStepConfig(optimizer=oc,
                                                      donate=False), specs)
state_sharded = jax.device_put(state, st_sh)
batch_sharded = jax.device_put(batch, b_sh)
s2, m2 = jitted(state_sharded, batch_sharded)
results["train_loss_diff"] = abs(float(m1["loss"]) - float(m2["loss"]))
diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params))]
results["train_param_diff"] = max(diffs)

if train_only:
    print("RESULTS" + json.dumps(results))
    sys.exit(0)

# --- 2. ring collectives == native psum ------------------------------------
from repro.distributed.collectives import ring_allreduce, ring_reduce_scatter
m8 = jax.make_mesh((8,), ("d",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
f = shard_map(lambda xs: ring_reduce_scatter(xs[0], "d")[None],
              mesh=m8, in_specs=(P("d", None),), out_specs=P("d", None))
results["ring_rs_err"] = float(jnp.max(jnp.abs(f(x) - x.sum(0).reshape(8, 8))))
g = shard_map(lambda xs: ring_allreduce(xs[0], "d")[None],
              mesh=m8, in_specs=(P("d", None),), out_specs=P("d", None))
results["ring_ar_err"] = float(jnp.max(jnp.abs(
    g(x) - jnp.broadcast_to(x.sum(0, keepdims=True), x.shape))))

# --- 3. pipeline forward/grad == sequential ---------------------------------
from repro.distributed.pipeline import make_pipelined_apply
mesh_pp = jax.make_mesh((8,), ("stage",))
S, D, NM, MB = 8, 16, 16, 4
ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) / jnp.sqrt(D)
bs = jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1
xpp = jax.random.normal(jax.random.PRNGKey(2), (NM, MB, D))
stage_fn = lambda p, h: jnp.tanh(h @ p[0] + p[1])
apply = make_pipelined_apply(stage_fn, mesh_pp, axis_name="stage")

def seq_apply(params, x):
    h = x
    for i in range(S):
        h = jnp.tanh(h @ params[0][i] + params[1][i])
    return h

results["pp_fwd_err"] = float(jnp.max(jnp.abs(
    apply((ws, bs), xpp) - seq_apply((ws, bs), xpp))))
gp = jax.grad(lambda p: jnp.sum(apply(p, xpp) ** 2))((ws, bs))
gr = jax.grad(lambda p: jnp.sum(seq_apply(p, xpp) ** 2))((ws, bs))
results["pp_grad_err"] = max(float(jnp.max(jnp.abs(a - b)))
                             for a, b in zip(jax.tree.leaves(gp),
                                             jax.tree.leaves(gr)))

# --- 4. compressed allreduce: mean + EF bias decay ---------------------------
from repro.distributed.compression import compressed_allreduce, init_ef_state
shard = 1000 // 8 + (1 if 1000 % 8 else 0)
shard = (1000 + (-1000) % 8) // 8
gs = jax.random.normal(jax.random.PRNGKey(3), (8, 1000))

def one_round(g, resid):
    f = shard_map(
        lambda gg, rr: (lambda o, s: (o[None], s.residual[None]))(
            *compressed_allreduce(gg[0], init_ef_state((shard,))._replace(
                residual=rr[0]), "d")),
        mesh=m8, in_specs=(P("d", None), P("d", None)),
        out_specs=(P("d", None), P("d", None)), check_rep=False)
    return f(g, resid)

resid = jnp.zeros((8, shard))
want = gs.mean(0)
errs = []
acc_err = jnp.zeros(1000)
for _ in range(30):
    out, resid = one_round(gs, resid)
    acc_err = acc_err + (out[0] - want)
    errs.append(float(jnp.linalg.norm(acc_err) / (jnp.linalg.norm(want) + 1e-9)))
results["ef_single_round_rel"] = errs[0]
results["ef_accum_rel_after_30"] = errs[-1] / 30.0

print("RESULTS" + json.dumps(results))
"""


def _run_subprocess(batch: int, seq: int, *extra: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT,
                           str(batch), str(seq), *extra],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][-1]
    return json.loads(line[len("RESULTS"):])


@pytest.fixture(scope="module")
def sub_results():
    # reduced default sizes; the full-size train step runs under -m slow
    return _run_subprocess(8, 9)


def test_sharded_train_step_matches_single(sub_results):
    assert sub_results["train_loss_diff"] < 1e-3
    assert sub_results["train_param_diff"] < 5e-3


@pytest.mark.slow
def test_sharded_train_step_matches_single_full_size():
    res = _run_subprocess(8, 17, "train_only")
    assert res["train_loss_diff"] < 1e-3
    assert res["train_param_diff"] < 5e-3


def test_ring_collectives(sub_results):
    assert sub_results["ring_rs_err"] < 1e-5
    assert sub_results["ring_ar_err"] < 1e-5


def test_pipeline_parallel(sub_results):
    assert sub_results["pp_fwd_err"] < 1e-5
    assert sub_results["pp_grad_err"] < 1e-3


def test_error_feedback_keeps_time_average_unbiased(sub_results):
    """One int8 round is ~5% off; with error feedback the *time-averaged*
    gradient error decays ~1/T instead of staying constant."""
    assert sub_results["ef_single_round_rel"] < 0.2
    assert sub_results["ef_accum_rel_after_30"] < \
        sub_results["ef_single_round_rel"] / 3
