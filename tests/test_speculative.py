"""Speculative decoding: the fused draft-propose / target-verify step.

The contract under test (PR 10): on greedy workloads the speculative
engine's emitted streams are **token-identical** to target-only decode
while spending strictly fewer fused steps; everything stays on one
decode compilation; the per-slot PRNG lanes make stochastic speculation
replay byte-identically; and the whole lane composes with paging,
int8 KV, and prefix sharing (rollback truncates block tails through
the decref/park path, exercised end-to-end here).
"""
import jax
import pytest

from conftest import reduced_cfg
from repro.core.spec import (MemorySpec, RuntimeSpec, SchedulerSpec,
                             SpeculationSpec)
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_cfg("qwen1.5-0.5b")
    return cfg, Model(cfg).init(jax.random.PRNGKey(0))


def _engine(cfg, params, *, spec_k=0, layout="paged", prefix=False,
            kv_dtype="compute", max_batch=4, max_len=64, block_size=8,
            num_blocks=None, sampling=None, greedy_accept=True):
    speculation = SpeculationSpec(draft_model=cfg, k=spec_k,
                                  greedy_accept=greedy_accept) \
        if spec_k else None
    spec = RuntimeSpec(
        arch=cfg,
        memory=MemorySpec(cache_layout=layout, max_batch=max_batch,
                          max_len=max_len, block_size=block_size,
                          num_blocks=num_blocks, kv_dtype=kv_dtype,
                          prefix_cache=prefix),
        scheduler=SchedulerSpec(policy="chunked", chunk_size=block_size),
        speculation=speculation)
    eng = ServingEngine(spec, sampling=sampling or SamplingParams())
    eng.load(params, draft=params if speculation else None)
    return eng


def _drain(eng, reqs):
    uids = [eng.submit(p, max_new_tokens=b) for p, b in reqs]
    done = {r.uid: r.generated for r in eng.run_to_completion()}
    return [done[u] for u in uids]


@pytest.mark.parametrize("kv_dtype", ["compute", "int8"])
def test_greedy_token_identical_fewer_steps(qwen, kv_dtype):
    """Self-draft greedy speculation must stream exactly what the
    target-only engine streams — an accepted proposal IS the target
    argmax — while spending fewer fused steps, on one decode trace."""
    cfg, params = qwen
    reqs = [([1, 2, 3], 16), (list(range(9, 17)), 12), ([5, 4], 10)]
    streams, steps = {}, {}
    for k in (0, 3):
        eng = _engine(cfg, params, spec_k=k, kv_dtype=kv_dtype)
        streams[k] = _drain(eng, reqs)
        steps[k] = eng.stats["decode_steps"]
        assert eng.compilations["decode"] == 1
        if k:
            assert eng.stats["spec_steps"] > 0
            assert eng.stats["spec_accepted"] > 0   # non-vacuous
    assert streams[3] == streams[0]
    assert steps[3] < steps[0]


def test_stochastic_replay_byte_identical(qwen):
    """greedy_accept=False + temperature: rejection sampling draws from
    the per-slot key lanes, so two fresh engines replay identically."""
    cfg, params = qwen
    reqs = [([1, 2, 3], 12), ([7, 8], 10)]
    sampling = SamplingParams(temperature=0.8)
    runs = []
    for _ in range(2):
        eng = _engine(cfg, params, spec_k=2, greedy_accept=False,
                      sampling=sampling)
        runs.append(_drain(eng, reqs))
        assert eng.compilations["decode"] == 1
    assert runs[0] == runs[1]


def test_spec_composes_with_prefix_sharing(qwen):
    """Speculation over prefix-shared blocks: rollback truncates the
    slot's block tail while the trie (and a sibling request) still hold
    the prefix chain — the decref/park path, end to end.  Streams must
    match the non-speculative prefix engine exactly."""
    cfg, params = qwen
    shared = list(range(1, 25))                    # 3 full 8-token blocks
    waves = [[(shared + [30], 4)],                 # warm the trie
             [(shared + [40], 12), (shared + [41], 12)]]
    streams = {}
    for k in (0, 3):
        eng = _engine(cfg, params, spec_k=k, prefix=True)
        outs = []
        for wave in waves:
            outs += _drain(eng, wave)
        streams[k] = outs
        assert eng.compilations["decode"] == 1
        if k:
            assert eng.stats["prefix_hits"] >= 2
        # drained: every slot released its blocks through the
        # truncate/park path without double-frees or leaks
        s = eng.memory_stats()
        assert s.used_blocks == s.cached_blocks
    assert streams[3] == streams[0]


def test_speculation_spec_validation(qwen):
    cfg, _ = qwen
    with pytest.raises(ValueError, match="must be >= 1"):
        SpeculationSpec(draft_model=cfg, k=0)
    with pytest.raises(ValueError, match="chunked scheduler"):
        RuntimeSpec(arch=cfg,
                    memory=MemorySpec(max_batch=2, max_len=64),
                    scheduler=SchedulerSpec(policy="bucketed"),
                    speculation=SpeculationSpec(draft_model=cfg, k=2))
    with pytest.raises(ValueError, match="verify lanes"):
        RuntimeSpec(arch=cfg,
                    memory=MemorySpec(cache_layout="paged", max_batch=2,
                                      max_len=64, block_size=8),
                    scheduler=SchedulerSpec(policy="chunked", chunk_size=8),
                    speculation=SpeculationSpec(draft_model=cfg, k=8))
