"""The analyzer analyzes: each RA rule trips on a seeded violation, the
real tree lints clean, the pallas contracts catch broken geometry, the
jaxpr audit sees callbacks/budgets, the census round-trips, and
strict_jit escalates donation failures under REPRO_STRICT=1."""
import json
import os
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import census as census_mod
from repro.analysis.jaxpr_audit import audit_jaxpr, count_primitives
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.pallas_contracts import (KernelGeometry,
                                             check_contracts,
                                             check_geometry, trace_kernels)
from repro.core.jitutil import DonationError, platform_donates, strict_jit

REPO = pathlib.Path(__file__).resolve().parents[1]


def _codes(src):
    return [f.code for f in lint_source(textwrap.dedent(src), "t.py")]


# ---------------------------------------------------------------------------
# lint: every rule trips on a seeded violation
# ---------------------------------------------------------------------------
def test_ra001_host_sync_in_jit_region():
    src = """
    import jax
    import numpy as np
    @jax.jit
    def step(x):
        v = float(x)
        a = np.asarray(x)
        jax.device_get(x)
        return x.item()
    """
    assert _codes(src) == ["RA001"] * 4


def test_ra002_traced_python_if():
    src = """
    import jax
    @jax.jit
    def step(x):
        if x > 0:
            x = x + 1
        while x < 5:
            x = x * 2
        return x
    """
    assert _codes(src) == ["RA002", "RA002"]


def test_ra002_structural_tests_are_static():
    src = """
    import jax
    @jax.jit
    def step(params, x, kind):
        if x is None:                 # pytree structure
            return params
        if "dec" in params:           # pytree structure
            x = x + 1
        if kind == "r":               # string config dispatch
            x = x * 2
        if x.shape[0] > 4:            # trace-static metadata
            x = x[:4]
        return x
    """
    assert _codes(src) == []


def test_ra003_use_after_donate():
    src = """
    import jax
    def f(p, c, s):
        return c, s
    step = jax.jit(f, donate_argnums=(1, 2))
    def drive(p, c, s):
        out = step(p, c, s)           # c, s dead but not rebound
        return out, c
    """
    assert _codes(src) == ["RA003"]


def test_ra003_rebinding_is_clean():
    src = """
    import jax
    def f(p, c, s):
        return c, s
    step = jax.jit(f, donate_argnums=(1, 2))
    def drive(p, c, s):
        c, s = step(p, c, s)
        return c, s
    """
    assert _codes(src) == []


def test_ra004_mutable_dataclass_default():
    src = """
    import dataclasses
    import numpy as np
    @dataclasses.dataclass
    class Spec:
        tables: list = []
        scales: dict = {}
        buf = None
        weights: np.ndarray = np.zeros(4)
    """
    assert _codes(src) == ["RA004"] * 3


def test_ra005_per_slot_device_gets():
    src = """
    import jax
    def harvest(state, slot):
        n = jax.device_get(state.count[slot])
        row = jax.device_get(state.buf[slot])
        return n, row
    """
    assert _codes(src) == ["RA005"] * 2


def test_ra005_single_bulk_get_is_clean():
    src = """
    import jax
    def harvest(state, slot):
        n, row = jax.device_get((state.count[slot], state.buf[slot]))
        return n, row
    """
    assert _codes(src) == []


def test_suppression_comment():
    src = """
    import jax
    @jax.jit
    def step(x):
        return float(x)  # ra: ignore[RA001]
    """
    assert _codes(src) == []


def test_static_argnames_are_not_traced():
    src = """
    import functools
    import jax
    @functools.partial(jax.jit, static_argnames=("causal",))
    def step(x, causal):
        if causal:
            x = x + 1
        return x
    """
    assert _codes(src) == []


def test_jit_region_marker():
    src = """
    # jit-region
    def inner_step(x):
        return float(x)
    """
    assert _codes(src) == ["RA001"]


def test_shard_map_body_is_a_jit_region():
    # a shard_map body runs inside jit on every mesh device — host
    # round-trips and python-controlled branches there are real traps
    src = """
    import functools
    from jax.experimental.shard_map import shard_map
    def _body(mesh, x):
        n = float(x.sum())
        return x / n
    def run(mesh, specs, x):
        return shard_map(functools.partial(_body, mesh), mesh=mesh,
                         in_specs=specs, out_specs=specs)(x)
    """
    assert _codes(src) == ["RA001"]


def test_shard_map_decorator_form_is_a_jit_region():
    src = """
    import functools
    from jax.experimental.shard_map import shard_map
    import numpy as np
    @functools.partial(shard_map, mesh=None, in_specs=(), out_specs=())
    def body(x):
        return np.asarray(x)
    """
    assert _codes(src) == ["RA001"]


def test_pallas_partial_bound_args_are_static():
    src = """
    import functools
    from jax.experimental import pallas as pl
    def _kernel(scale, quantized, x_ref, o_ref):
        if quantized:
            o_ref[...] = x_ref[...] * scale
        else:
            o_ref[...] = x_ref[...]
    def run(x):
        return pl.pallas_call(functools.partial(_kernel, 2.0, True),
                              out_shape=x)(x)
    """
    assert _codes(src) == []


def test_tree_is_clean():
    findings = lint_paths(REPO / "src" / "repro")
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# pallas contracts
# ---------------------------------------------------------------------------
GEO = KernelGeometry(num_heads=4, num_kv_heads=2, head_dim=16,
                     max_batch=2, max_len=32, block_size=8, num_blocks=8)


def test_contracts_hold_on_serving_geometry():
    assert check_geometry(GEO) == []
    assert trace_kernels(GEO) == []


def test_contracts_catch_bad_head_grouping():
    import dataclasses
    bad = dataclasses.replace(GEO, num_heads=5)
    assert any("not a multiple" in v for v in check_geometry(bad))


def test_contracts_catch_starved_pool():
    import dataclasses
    bad = dataclasses.replace(GEO, num_blocks=2)   # max_len needs 4
    assert any("could never be admitted" in v for v in check_geometry(bad))


def test_contracts_catch_vmem_blowup():
    import dataclasses
    bad = dataclasses.replace(GEO, head_dim=8192, block_size=512)
    assert any("VMEM" in v for v in check_geometry(bad))


def test_check_contracts_aggregates():
    import dataclasses
    bad = dataclasses.replace(GEO, num_heads=5)
    out = check_contracts({"ok": GEO, "bad": bad}, trace=False)
    assert list(out) == ["bad"]


# ---------------------------------------------------------------------------
# jaxpr audit
# ---------------------------------------------------------------------------
def test_audit_flags_callbacks():
    def step(x):
        jax.debug.callback(lambda v: None, x)
        return x + 1

    jaxpr = jax.make_jaxpr(step)(jnp.ones(4))
    assert any("callback" in v for v in audit_jaxpr(jaxpr))


def test_audit_budget():
    jaxpr = jax.make_jaxpr(lambda x: x * 2 + 1)(jnp.ones(4))
    n = count_primitives(jaxpr)
    assert audit_jaxpr(jaxpr, budget=n) == []
    assert any("budget" in v for v in audit_jaxpr(jaxpr, budget=n - 1))


def test_audit_clean_step_passes():
    jaxpr = jax.make_jaxpr(
        lambda x: jax.lax.scan(lambda c, t: (c + t, c), 0.0, x)[0]
    )(jnp.ones(8))
    assert audit_jaxpr(jaxpr, budget=50) == []


# ---------------------------------------------------------------------------
# census round trip (the two cheapest matrix points)
# ---------------------------------------------------------------------------
SMALL = ["gqa-dense-xla-bucketed", "gqa-dense-xla-chunked"]


def test_census_round_trip():
    report = census_mod.run_census(SMALL)
    for name, rec in report["points"].items():
        assert "violation" not in rec, (name, rec)
        assert rec["compilations"]["decode"] == 1, (name, rec)
    # self-compare: no diffs
    assert census_mod.compare(report, report, subset=True) == []
    # a grown compile count is a diff
    tampered = json.loads(json.dumps(report))
    tampered["points"][SMALL[0]]["compilations"]["decode"] = 2
    diffs = census_mod.compare(tampered, report, subset=True)
    assert any("compile counts" in d for d in diffs)
    # a lowering swap on the same jax version is a diff
    tampered = json.loads(json.dumps(report))
    tampered["points"][SMALL[1]]["fingerprint"] = "0" * 16
    diffs = census_mod.compare(tampered, report, subset=True)
    assert any("fingerprint" in d for d in diffs)
    # ... but not across jax versions (lowering drift is not ours)
    tampered["jax_version"] = "0.0.0"
    assert census_mod.compare(tampered, report, subset=True) == []


def test_committed_baseline_covers_matrix():
    baseline = census_mod.load_baseline()
    assert baseline is not None, \
        "ANALYSIS.json missing — python -m repro.analysis --update-baseline"
    names = {p.name for p in census_mod.support_matrix()}
    assert set(baseline["points"]) == names
    for name, rec in baseline["points"].items():
        assert rec["compilations"]["decode"] == 1, name


# ---------------------------------------------------------------------------
# strict donation escalation (satellite of the same invariant)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not platform_donates(),
                    reason="backend never aliases donated buffers")
def test_strict_jit_raises_on_unusable_donation():
    assert os.environ.get("REPRO_STRICT") == "1"
    # output dtype != input dtype -> the donated buffer cannot be reused
    f = strict_jit(lambda x: x.astype(jnp.int32), donate_argnums=(0,))
    with pytest.raises(DonationError):
        f(jnp.ones((8,), jnp.float32))


def test_strict_jit_passes_clean_donation():
    f = strict_jit(lambda x: x + 1, donate_argnums=(0,))
    out = f(jnp.ones((8,), jnp.float32))
    assert out[0] == 2.0
    assert f._cache_size() == 1


def test_strict_jit_off_by_default(monkeypatch):
    monkeypatch.setenv("REPRO_STRICT", "0")
    f = strict_jit(lambda x: x.astype(jnp.int32), donate_argnums=(0,))
    out = f(jnp.ones((8,), jnp.float32))    # warns, but must not raise
    assert out.dtype == jnp.int32
