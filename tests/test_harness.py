"""Load harness: seeded traces, hand-computed metrics, exactly-once
lifecycle events across engine modes, and the analytical autotuner.

The metric tests build ``EngineEvent`` lists by hand and check the
reduction against arithmetic done in comments — the definitions in
``repro.harness.metrics`` are only trustworthy if a human can recompute
them.
"""
import dataclasses

import pytest

from conftest import reduced_cfg
from repro.core.spec import (ExecutionSpec, MemorySpec, RuntimeSpec,
                             SchedulerSpec, maxima_for)
from repro.harness import (SLO, DeviceProfile, WorkloadProfile,
                           bursty_trace, fleet_trace, load_trace,
                           poisson_trace, reduce_events, replay, save_trace,
                           scripted_trace, shared_prefix_trace, tune)
from repro.harness.metrics import percentile
from repro.harness.trace import TraceRequest, dumps_trace, loads_trace
from repro.harness.tune import cache_bytes, naive_default
from repro.serving.events import EngineEvent

# ----------------------------------------------------------------------
# traces
# ----------------------------------------------------------------------

_GENERATORS = [
    lambda seed: poisson_trace(12, rate=0.5, max_len=32, max_new=4,
                               seed=seed),
    lambda seed: bursty_trace(12, burst_size=4, gap_steps=6, max_len=32,
                              max_new=4, seed=seed),
    lambda seed: shared_prefix_trace(12, n_families=2, prefix_len=16,
                                     max_len=48, max_new=4, seed=seed),
    lambda seed: fleet_trace(12, n_models=3, max_len=32, max_new=4,
                             seed=seed),
]


@pytest.mark.parametrize("gen", _GENERATORS)
def test_traces_byte_reproducible(gen):
    a, b = gen(7), gen(7)
    assert dumps_trace(a) == dumps_trace(b)
    assert dumps_trace(gen(8)) != dumps_trace(a)


@pytest.mark.parametrize("gen", _GENERATORS)
def test_trace_roundtrip(gen, tmp_path):
    t = gen(3)
    assert loads_trace(dumps_trace(t)) == t
    p = tmp_path / "t.jsonl"
    save_trace(t, p)
    assert load_trace(p) == t


def test_trace_invariants():
    for gen in _GENERATORS:
        t = gen(5)
        assert len(t) == 12
        for r in t.requests:
            assert r.arrival_step >= 0
            assert len(r.prompt) >= 1
            assert r.max_new_tokens >= 1
            assert all(tok >= 1 for tok in r.prompt)   # 0 is the pad id


def test_trace_request_validation():
    with pytest.raises(ValueError):
        TraceRequest(rid=0, arrival_step=-1, prompt=(1,), max_new_tokens=1)
    with pytest.raises(ValueError):
        TraceRequest(rid=0, arrival_step=0, prompt=(), max_new_tokens=1)
    with pytest.raises(ValueError):
        TraceRequest(rid=0, arrival_step=0, prompt=(1,), max_new_tokens=0)


def test_scripted_trace_preserves_rows():
    t = scripted_trace([(0, [1, 2], 3), (4, [5], 1)], name="toy")
    assert [r.arrival_step for r in t.requests] == [0, 4]
    assert t.requests[0].prompt == (1, 2)
    assert t.requests[1].max_new_tokens == 1


def test_shared_prefix_trace_shares_prefixes():
    t = shared_prefix_trace(10, n_families=2, prefix_len=8, shared_frac=0.8,
                            max_len=32, max_new=4, seed=1)
    prefixes = {}
    shared = 0
    for r in t.requests:
        head = r.prompt[:8]
        if head in prefixes:
            shared += 1
        prefixes[head] = prefixes.get(head, 0) + 1
    assert shared >= 5        # 80% of 10 across 2 families must collide
    assert t.meta["shared_frac"] == 0.8


# ----------------------------------------------------------------------
# metrics: hand-computed on a toy event stream
# ----------------------------------------------------------------------

def _ev(kind, uid, step, t, **data):
    return EngineEvent(kind=kind, uid=uid, step=step, t=t, data=data)


def _toy_events():
    """Three requests; r2 is preempted once and never finishes."""
    return [
        _ev("submit", 0, 0, 0.0), _ev("submit", 1, 0, 0.0),
        _ev("submit", 2, 0, 0.0),
        _ev("admit", 0, 0, 0.0),
        _ev("admit", 1, 1, 1.0), _ev("admit", 2, 1, 1.0),
        _ev("first_token", 0, 1, 0.5),
        _ev("progress", 0, 1, 1.0, count=1),
        _ev("preempt", 2, 2, 2.0, banked=0),
        _ev("first_token", 1, 3, 2.5),
        _ev("progress", 0, 3, 3.0, count=3),
        _ev("progress", 1, 3, 3.0, count=1),
        _ev("finish", 0, 3, 3.0, n_generated=3),
        _ev("admit", 2, 4, 4.0),
        _ev("progress", 1, 4, 4.0, count=2),
        _ev("finish", 1, 4, 4.0, n_generated=2),
        _ev("first_token", 2, 5, 4.5),
        _ev("progress", 2, 5, 5.0, count=1),
    ]


def test_metrics_hand_computed():
    m = reduce_events(_toy_events(), slo=SLO(ttft_steps=2))
    assert m.n_requests == 3
    assert m.n_finished == 2
    assert m.n_preemptions == 1
    # admits: r0@0 -> 1; r1,r2@1 -> 3 (peak); preempt r2 -> 2; ...
    assert m.peak_concurrency == 3
    assert m.steps == 5                      # event steps span 0..5
    # TTFT steps: r0 = 1-0, r1 = 3-0, r2 = 5-0
    assert m.per_request[0]["ttft_steps"] == 1
    assert m.per_request[1]["ttft_steps"] == 3
    assert m.per_request[2]["ttft_steps"] == 5
    # nearest-rank over [1, 3, 5]: p50 -> ceil(1.5)=2nd -> 3; p99 -> 5
    assert m.ttft_steps_p50 == 3
    assert m.ttft_steps_p99 == 5
    # ITL: r0 counts 1@1 -> 3@3 gives 2 samples of (3-1)/2 = 1.0;
    # r1 counts 1@3 -> 2@4 gives 1 sample of 1.0; r2 has no pair
    assert m.per_request[0]["n_itl_samples"] == 2
    assert m.per_request[1]["n_itl_samples"] == 1
    assert m.per_request[2]["n_itl_samples"] == 0
    assert m.itl_steps_p50 == 1.0
    assert m.itl_steps_p99 == 1.0
    # only finished requests generate: 3 + 2 (r2 never finished)
    assert m.total_new_tokens == 5
    assert m.tokens_per_step == 1.0
    # SLO ttft<=2: r0 met (1), r1 finished but ttft 3, r2 unfinished
    assert m.n_slo_met == 1
    assert m.slo_attainment == pytest.approx(1 / 3)
    assert m.goodput_req_per_1k_steps == pytest.approx(1000 * 1 / 5)
    # wall view: TTFT seconds = first count>=1 progress minus submit
    assert m.ttft_s_p50 == pytest.approx(3.0)     # [1.0, 3.0, 5.0]
    assert m.wall_s == pytest.approx(5.0)


def test_metrics_no_slo_means_finished():
    m = reduce_events(_toy_events())
    assert m.n_slo_met == m.n_finished == 2


def test_itl_rebaseline_on_count_decrease():
    # counts 2@s0 -> 1@s2 (preemption rollback: re-baseline, no samples)
    # -> 3@s6: 2 samples of (6-2)/2 = 2.0
    events = [
        _ev("submit", 0, 0, 0.0), _ev("admit", 0, 0, 0.0),
        _ev("progress", 0, 0, 0.0, count=2),
        _ev("progress", 0, 2, 2.0, count=1),
        _ev("progress", 0, 6, 6.0, count=3),
        _ev("finish", 0, 6, 6.0, n_generated=3),
    ]
    m = reduce_events(events)
    assert m.per_request[0]["n_itl_samples"] == 2
    assert m.itl_steps_p50 == 2.0
    assert m.per_request[0]["max_itl_steps"] == 2.0


def test_spec_metrics_hand_computed():
    """Mean accepted draft length from the cumulative ``accepted`` /
    ``spec_steps`` progress counters, including the preemption
    re-baseline (mirrors the ITL count-decrease rule)."""
    events = [
        _ev("submit", 0, 0, 0.0), _ev("admit", 0, 0, 0.0),
        _ev("submit", 1, 0, 0.0), _ev("admit", 1, 0, 0.0),
        # r0 speculates: cumulative counters ride its progress events
        _ev("progress", 0, 1, 1.0, count=3, accepted=2, spec_steps=1),
        # r1 speculates twice and accepts nothing — steps still count
        _ev("progress", 1, 1, 1.0, count=2, accepted=0, spec_steps=2),
        _ev("finish", 1, 1, 1.0, n_generated=2),
        _ev("progress", 0, 2, 2.0, count=7, accepted=5, spec_steps=3),
        # preemption resets the device counters: accepted drops 5 -> 1,
        # so the (5, 3) epoch banks and the new epoch re-baselines
        _ev("preempt", 0, 3, 3.0, banked=0),
        _ev("admit", 0, 4, 4.0),
        _ev("progress", 0, 5, 5.0, count=2, accepted=1, spec_steps=1),
        _ev("finish", 0, 5, 5.0, n_generated=2),
    ]
    m = reduce_events(events)
    # r0 banks (5 acc, 3 steps) at the reset plus its open (1, 1) epoch;
    # r1 adds (0, 2): 6 accepted tokens over 6 speculative steps
    assert m.spec_accepted_tokens == 6
    assert m.spec_steps == 6
    assert m.mean_accepted_len == pytest.approx(1.0)
    # spec fields are step-currency: they ride the deterministic view
    assert m.deterministic()["mean_accepted_len"] == pytest.approx(1.0)


def test_spec_metrics_absent_without_speculation():
    m = reduce_events(_toy_events())
    assert m.spec_accepted_tokens == 0 and m.spec_steps == 0
    assert m.mean_accepted_len is None


def test_percentile_nearest_rank():
    assert percentile([], 50) is None
    assert percentile([4, 1, 3, 2], 50) == 2
    assert percentile([4, 1, 3, 2], 99) == 4
    assert percentile([7], 50) == 7


def test_reduce_events_rejects_empty():
    with pytest.raises(ValueError):
        reduce_events([])


def test_deterministic_view_excludes_wall():
    m = reduce_events(_toy_events())
    d = m.deterministic()
    for k in ("wall_s", "ttft_s_p50", "itl_s_p99", "goodput_req_s",
              "tokens_per_s"):
        assert k not in d
    assert d["steps"] == 5
    # canonical serialization is stable
    assert m.deterministic_json() == m.deterministic_json()


# ----------------------------------------------------------------------
# lifecycle events: exactly once per request, across engine modes
# ----------------------------------------------------------------------

def _engine(cfg, *, layout="dense", policy="bucketed", fleet=False):
    import jax

    from repro.models.model import Model
    from repro.serving.engine import ServingEngine
    from repro.serving.sampling import SamplingParams

    maxima = cfg_b = None
    if fleet:
        cfg_b = dataclasses.replace(cfg, name=cfg.name + "-b", num_layers=1,
                                    d_model=48, num_heads=3, num_kv_heads=3,
                                    d_ff=96, vocab_size=96)
        maxima = maxima_for(cfg, cfg_b, seq_max=64)
    spec = RuntimeSpec(
        arch=cfg, maxima=maxima,
        memory=MemorySpec(cache_layout=layout, max_batch=4, max_len=64,
                          block_size=8),
        scheduler=SchedulerSpec(policy=policy))
    eng = ServingEngine(spec, sampling=SamplingParams(),
                        **({"max_models": 2} if fleet else {}))
    eng.load(Model(cfg).init(jax.random.PRNGKey(0)))
    if fleet:
        eng.add_model(Model(cfg_b).init(jax.random.PRNGKey(1)), cfg_b)
    return eng


_MODES = [("dense", "bucketed", False), ("dense", "chunked", False),
          ("paged", "chunked", False), ("paged", "chunked", True)]


@pytest.mark.parametrize("layout,policy,fleet", _MODES)
def test_lifecycle_events_exactly_once(layout, policy, fleet):
    cfg = reduced_cfg("qwen1.5-0.5b")
    eng = _engine(cfg, layout=layout, policy=policy, fleet=fleet)
    rows = [(0, [1, 2, 3], 2), (0, list(range(1, 13)), 3),
            (1, [4, 5], 2), (2, [6, 7, 8, 9], 2),
            (2, list(range(20, 29)), 3), (4, [9, 8, 7], 2)]
    if fleet:
        rows = [(a, p, n, i % 2) for i, (a, p, n) in enumerate(rows)]
    res = replay(eng, scripted_trace(rows, name="lifecycle"))
    m = res.metrics
    assert m.n_finished == len(rows)
    by_uid = {}
    for e in res.events:
        by_uid.setdefault(e.uid, []).append(e)
    assert len(by_uid) == len(rows)
    for uid, evs in by_uid.items():
        kinds = [e.kind for e in evs]
        n_admit, n_preempt = kinds.count("admit"), kinds.count("preempt")
        assert kinds.count("submit") == 1, (uid, kinds)
        assert kinds.count("first_token") == 1, (uid, kinds)
        assert kinds.count("finish") == 1, (uid, kinds)
        assert n_admit - n_preempt == 1, (uid, kinds)
        assert kinds[0] == "submit" and kinds[-1] == "finish"
        # the logical clock never runs backwards within one request
        steps = [e.step for e in evs]
        assert steps == sorted(steps)
    # progress carried every finished request to its budget
    for uid, rec in m.per_request.items():
        assert rec["finished"]
        assert rec["n_generated"] >= 1


def test_replay_deterministic_metrics_across_engines():
    cfg = reduced_cfg("qwen1.5-0.5b")
    trace = bursty_trace(8, burst_size=4, gap_steps=6, max_len=24,
                         max_new=3, seed=13)
    views = []
    for _ in range(2):
        eng = _engine(cfg, layout="paged", policy="chunked")
        views.append(replay(eng, trace).metrics.deterministic_json())
    assert views[0] == views[1]


# ----------------------------------------------------------------------
# tuner
# ----------------------------------------------------------------------

def test_tuned_spec_is_valid_and_within_budget():
    cfg = reduced_cfg("qwen1.5-0.5b")
    device = DeviceProfile(cache_budget_bytes=256 * 1024)
    result = tune(cfg, device, max_len=64)
    spec = result.spec
    assert spec.validate() is spec
    assert cache_bytes(spec) <= device.budget(cfg)
    assert result.ranked[0] is result.best
    scores = [c.score for c in result.ranked]
    assert scores == sorted(scores, reverse=True)
    # deterministic: same inputs, same winner
    again = tune(cfg, device, max_len=64)
    assert again.spec == spec


def test_runtime_spec_tuned_matches_tune():
    cfg = reduced_cfg("qwen1.5-0.5b")
    device = DeviceProfile(cache_budget_bytes=128 * 1024)
    assert RuntimeSpec.tuned(cfg, device, max_len=64) \
        == tune(cfg, device, max_len=64).spec


def test_workload_profile_from_trace_reads_meta():
    t = shared_prefix_trace(16, n_families=2, prefix_len=12, shared_frac=0.8,
                            max_len=48, max_new=4, seed=2)
    w = WorkloadProfile.from_trace(t)
    assert w.shared_prefix_frac == 0.8
    assert w.shared_prefix_len == 12
    assert w.max_prompt_len == t.max_prompt_len
    assert w.effective_prompt_len < w.mean_prompt_len


def test_naive_default_pays_equal_bytes():
    cfg = reduced_cfg("qwen1.5-0.5b")
    tuned = tune(cfg, DeviceProfile(cache_budget_bytes=256 * 1024),
                 max_len=64).spec
    naive = naive_default(cfg, tuned)
    assert naive.memory.cache_layout == "dense"
    assert cache_bytes(naive) <= cache_bytes(tuned)
    # within one max_len row of equality — the definition of "equal memory"
    per_row = cache_bytes(naive) // naive.memory.max_batch
    assert cache_bytes(tuned) - cache_bytes(naive) < per_row


def test_tune_int8_kv_is_opt_in():
    cfg = reduced_cfg("qwen1.5-0.5b")
    device = DeviceProfile(cache_budget_bytes=128 * 1024)
    assert tune(cfg, device, max_len=64).spec.memory.kv_dtype == "compute"
    specs = [c.spec for c in
             tune(cfg, device, max_len=64, allow_int8_kv=True).ranked]
    assert any(s.memory.kv_dtype == "int8" for s in specs)


def test_fleet_cache_accounting_matches_fabric():
    from repro.harness.tune import _per_token_bytes
    from repro.serving.fabric import DecodeFabric

    cfg = reduced_cfg("qwen1.5-0.5b")
    cfg_b = dataclasses.replace(cfg, name=cfg.name + "-b", num_layers=1,
                                d_model=48, num_heads=3, num_kv_heads=3,
                                d_ff=96, vocab_size=96)
    maxima = maxima_for(cfg, cfg_b, seq_max=64)
    fab = DecodeFabric(maxima, 2, cfg)
    # one yardstick: the tuner's fleet bytes/token IS the fabric's
    assert _per_token_bytes(cfg, "compute", maxima) \
        == fab.kv_bytes_per_token()
    # maxima-shaped rows cost at least the biggest member's own rows
    assert _per_token_bytes(cfg, "compute", maxima) \
        >= _per_token_bytes(cfg, "compute", None)
    budget = 512 * 1024
    result = tune(cfg, DeviceProfile(cache_budget_bytes=budget),
                  max_len=64, maxima=maxima)
    assert result.spec.maxima is maxima
    assert cache_bytes(result.spec) <= budget


def test_tune_rejects_unsupported_family():
    cfg = reduced_cfg("falcon-mamba-7b")
    with pytest.raises(ValueError):
        tune(cfg, DeviceProfile(cache_budget_bytes=128 * 1024), max_len=64)
