"""The int8 KV-cache codec: round-trips, decode equivalence, and
stream identity across every serving mode.

The tentpole claims under test:

* the codec round-trips within the symmetric-int8 error bound
  (half a quantization step per element, per-row scales),
* prefill + decode with an int8 cache tracks the float-cache logits
  within a small tolerance for GQA *and* MLA,
* greedy streams are token-identical to the float cache on the
  test-size models across dense / paged / chunked / bucketed / Pallas
  serving, and a quantized fleet (int8 weight table + int8 cache)
  serves a mixed workload from ONE compiled step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.kv_quant import CacheCodec, cache_put
from repro.core.spec import (ExecutionSpec, MemorySpec, RuntimeSpec,
                             SchedulerSpec, maxima_for)
from repro.models.model import Model, ModelOptions
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams

INT8 = CacheCodec("int8")
FLOAT = CacheCodec("compute")


# ---------------------------------------------------------------------------
# Codec round-trip properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,seed", [((4, 7, 16), 0), ((2, 3, 5, 64), 1),
                                        ((1, 128), 2), ((6, 1), 3)])
def test_roundtrip_error_bound(shape, seed):
    """|x - decode(encode(x))| <= scale/2 + eps, scale = amax(row)/127."""
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * 3.0
    q, scale = INT8.encode(x)
    back = INT8.decode(q, scale, jnp.float32)
    bound = np.asarray(scale)[..., None] * 0.5 + 1e-6
    assert np.all(np.abs(np.asarray(back - x)) < bound)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert scale.shape == shape[:-1]


def test_roundtrip_extremes_and_zeros():
    # a zero row must round-trip to exactly zero (eps floor, no NaN)
    z = jnp.zeros((3, 8))
    q, s = INT8.encode(z)
    assert float(jnp.abs(INT8.decode(q, s)).max()) == 0.0
    # amax element is exactly representable (127 * amax/127)
    x = jnp.asarray([[5.0, -2.5, 0.125, 0.0]])
    q, s = INT8.encode(x)
    assert int(jnp.abs(q).max()) == 127
    assert abs(float(INT8.decode(q, s, jnp.float32)[0, 0]) - 5.0) < 1e-6


def test_roundtrip_scale_invariance():
    """Per-row scaling means scaling one row never perturbs another."""
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 16), jnp.float32)
    q1, s1 = INT8.encode(x)
    big = x.at[0].mul(1000.0)
    q2, s2 = INT8.encode(big)
    np.testing.assert_array_equal(np.asarray(q1[1:]), np.asarray(q2[1:]))
    np.testing.assert_allclose(np.asarray(s1[1:]), np.asarray(s2[1:]))


def test_compute_codec_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8), jnp.float32)
    vals, scale = FLOAT.store(x, jnp.bfloat16)
    assert scale is None and vals.dtype == jnp.bfloat16
    assert FLOAT.load(vals, None) is vals
    v, s = FLOAT.cache_arrays((2, 4, 8))
    assert s is None and v.dtype == jnp.bfloat16


def test_cache_put_writes_values_and_scales():
    vals = jnp.zeros((4, 8, 2, 16), jnp.int8)
    scales = jnp.zeros((4, 8, 2), jnp.float32)
    new = jax.random.normal(jax.random.PRNGKey(6), (4, 2, 16), jnp.float32)
    q, s = INT8.encode(new)
    rows = jnp.arange(4)
    idx = jnp.asarray([0, 3, 7, 2])
    v2, s2 = cache_put(vals, scales, (rows, idx), q, s)
    back = INT8.decode(v2[rows, idx], s2[rows, idx], jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(new), atol=0.1)


def test_bad_kv_dtype_rejected():
    with pytest.raises(ValueError, match="kv_dtype"):
        CacheCodec("int4")


# ---------------------------------------------------------------------------
# Cache construction (values + scale leaves, real and abstract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "deepseek-v3-671b"])
def test_init_cache_int8_structure(name):
    cfg = reduced_cfg(name, lossless_moe=True)
    model = Model(cfg, ModelOptions(kv_dtype="int8"))
    cache = model.init_cache(2, 16)
    abstract = model.init_cache(2, 16, abstract=True)
    vals = cache[0]
    assert vals.dtype == jnp.int8
    scale = cache[2]   # k_scale / c_scale
    assert scale is not None and scale.dtype == jnp.float32
    assert scale.shape == vals.shape[:-1]
    for real, ab in zip(jax.tree.leaves(cache), jax.tree.leaves(abstract)):
        assert (real.shape, real.dtype) == (ab.shape, ab.dtype)


def test_init_cache_int8_rejects_recurrent_families():
    cfg = reduced_cfg("falcon-mamba-7b")
    with pytest.raises(ValueError, match="kv_dtype='int8' is unsupported"):
        Model(cfg, ModelOptions(kv_dtype="int8")).init_cache(2, 16)


# ---------------------------------------------------------------------------
# Decode-equivalence tolerance sweeps (GQA and MLA, dense and paged)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "deepseek-v3-671b"])
def test_int8_cache_decode_tracks_float_cache(name):
    """prefill + token-by-token decode with the int8 cache stays within
    quantization tolerance of the float cache at every step."""
    cfg = reduced_cfg(name, lossless_moe=True)
    fm = Model(cfg, ModelOptions(kv_dtype="compute"))
    qm = Model(cfg, ModelOptions(kv_dtype="int8"))
    params = fm.init(jax.random.PRNGKey(0))
    S, P = 12, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    pb = {"tokens": toks[:, :P]}
    lg_f, cache_f = fm.prefill(params, pb, max_len=S)
    lg_q, cache_q = qm.prefill(params, pb, max_len=S)
    scale = float(jnp.abs(lg_f).max()) + 1e-6
    assert float(jnp.max(jnp.abs(lg_q - lg_f))) < 3e-2 * scale
    for t in range(P, S):
        lf, cache_f = fm.decode_step(params, cache_f, toks[:, t:t + 1],
                                     jnp.int32(t))
        lq, cache_q = qm.decode_step(params, cache_q, toks[:, t:t + 1],
                                     jnp.int32(t))
        err = float(jnp.max(jnp.abs(lq - lf)))
        assert err < 3e-2 * scale, f"{name} step {t}: {err}"


@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "deepseek-v3-671b"])
def test_int8_paged_decode_tracks_float_dense(name):
    """Paged int8 decode (block-table gather + scale gather) stays within
    quantization tolerance of the float dense cache."""
    from repro.core.paging import PagingConfig
    cfg = reduced_cfg(name, lossless_moe=True)
    fm = Model(cfg, ModelOptions(kv_dtype="compute"))
    qm = Model(cfg, ModelOptions(kv_dtype="int8"))
    params = fm.init(jax.random.PRNGKey(0))
    B, S, bs = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    cache_f = fm.init_cache(B, S)
    cache_q = qm.init_cache(B, S, paging=PagingConfig(block_size=bs,
                                                      num_blocks=B * S // bs))
    # disjoint physical blocks per slot (block 0 is the null block)
    tables = jnp.arange(1, 1 + B * (S // bs), dtype=jnp.int32) \
        .reshape(B, S // bs)
    step_f = jax.jit(lambda c, t, i: fm.decode_step(params, c, t, i))
    step_q = jax.jit(lambda c, t, i: qm.decode_step(params, c, t, i,
                                                    block_tables=tables))
    # the MoE model's top-k router can flip an expert choice under the
    # codec's perturbation — a discontinuous (but bounded) logit jump
    tol = 8e-2 if cfg.moe is not None else 3e-2
    scale = None
    for t in range(S):
        lf, cache_f = step_f(cache_f, toks[:, t:t + 1], jnp.int32(t))
        lq, cache_q = step_q(cache_q, toks[:, t:t + 1], jnp.int32(t))
        scale = scale or float(jnp.abs(lf).max()) + 1e-6
        assert float(jnp.max(jnp.abs(lq - lf))) < tol * scale, t


# ---------------------------------------------------------------------------
# Serving-mode stream identity (the test-size models move no argmax)
# ---------------------------------------------------------------------------
# per-arch workloads chosen free of argmax near-ties under the codec's
# <0.5% per-row error (verified across every layout/scheduler variant)
PROMPTS = {
    "qwen1.5-0.5b": [[1, 2, 3], [4, 5, 6, 7, 8, 9], [7] * 12, [30, 31]],
    "deepseek-v3-671b": [[1, 2, 3], [2, 4, 6, 8], [7] * 12, [30, 31]],
}


def _serve(cfg, params, kv_dtype, layout="dense", policy="auto",
           impl="gather", max_new=6):
    spec = RuntimeSpec(
        arch=cfg,
        execution=ExecutionSpec(paged_attn_impl=impl),
        memory=MemorySpec(cache_layout=layout, max_batch=4, max_len=64,
                          block_size=8, kv_dtype=kv_dtype),
        scheduler=SchedulerSpec(policy=policy))
    eng = ServingEngine(spec, sampling=SamplingParams())
    eng.load(params)
    prompts = PROMPTS[cfg.name]
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    done = {r.uid: r.generated for r in eng.run_to_completion()}
    return [done[u] for u in uids], eng


@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "deepseek-v3-671b"])
def test_int8_cache_streams_match_float(name):
    cfg = reduced_cfg(name, lossless_moe=True)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    base, _ = _serve(cfg, params, "compute")
    for kwargs in ({}, {"layout": "paged"}, {"policy": "bucketed"},
                   {"layout": "paged", "policy": "bucketed"}):
        got, eng = _serve(cfg, params, "int8", **kwargs)
        assert got == base, kwargs
        if kwargs.get("policy") != "bucketed":
            assert eng.compilations["decode"] == 1
            assert eng.compilations["prefill"] == 1


def test_int8_cache_pallas_kernels_match_gather():
    """The fused Pallas paged-decode and chunked-prefill kernels consume
    the int8 pool + scales through the block-table walk."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    params = Model(cfg).init(jax.random.PRNGKey(0))
    base, _ = _serve(cfg, params, "compute")
    got, eng = _serve(cfg, params, "int8", layout="paged", impl="pallas")
    assert got == base
    assert eng.compilations["decode"] == 1


# ---------------------------------------------------------------------------
# Fully-quantized fleet: int8 weight table + int8 cache, ONE compiled step
# ---------------------------------------------------------------------------
CFG_A = reduced_cfg("qwen1.5-0.5b")
CFG_B = dataclasses.replace(
    CFG_A, name="adaptor-bert-shaped", num_layers=1, d_model=48,
    num_heads=3, num_kv_heads=3, d_ff=96, vocab_size=96)
MAXIMA = maxima_for(CFG_A, CFG_B, seq_max=64)
# prompts chosen to carry no argmax near-tie under int8 weight + cache
# quantization (verified stable across every codec/weight combination)
FLEET_PROMPTS_A = [list(range(1, 12)), [10, 20, 30, 40], [5, 9, 14]]
FLEET_PROMPTS_B = [[4, 5], [6, 7, 8], [80, 70, 60, 50]]
MAX_NEW = 5


def _fleet_params():
    return (Model(CFG_A).init(jax.random.PRNGKey(0)),
            Model(CFG_B).init(jax.random.PRNGKey(1)))


def _fleet(pa, pb, quant, kv_dtype, layout="dense", impl="gather"):
    spec = RuntimeSpec(
        arch=CFG_A, maxima=MAXIMA,
        execution=ExecutionSpec(quant=quant, quant_min_size=1,
                                paged_attn_impl=impl),
        memory=MemorySpec(cache_layout=layout, max_batch=4, max_len=64,
                          block_size=8, kv_dtype=kv_dtype))
    eng = ServingEngine(spec, max_models=2, sampling=SamplingParams())
    a = eng.add_model(pa, CFG_A)
    b = eng.add_model(pb, CFG_B)
    uid_to = {}
    for name, mid, plist in (("a", a, FLEET_PROMPTS_A),
                             ("b", b, FLEET_PROMPTS_B)):
        for p in plist:
            uid = eng.submit(p, max_new_tokens=MAX_NEW, model=mid)
            uid_to[uid] = (name, tuple(p))
    done = eng.run_to_completion()
    return {uid_to[r.uid]: r.generated for r in done}, eng


def _solo_all(pa, pb, quant, kv_dtype):
    out = {}
    for name, cfg, params, plist in (("a", CFG_A, pa, FLEET_PROMPTS_A),
                                     ("b", CFG_B, pb, FLEET_PROMPTS_B)):
        spec = RuntimeSpec(
            arch=cfg,
            execution=ExecutionSpec(quant=quant, quant_min_size=1),
            memory=MemorySpec(max_batch=4, max_len=64, kv_dtype=kv_dtype))
        eng = ServingEngine(spec, sampling=SamplingParams())
        eng.load(params)
        uid_to = {eng.submit(p, max_new_tokens=MAX_NEW): (name, tuple(p))
                  for p in plist}
        out |= {uid_to[r.uid]: r.generated for r in eng.run_to_completion()}
    return out


def test_quantized_fleet_serves_mixed_workload():
    """The acceptance bar: RuntimeSpec(memory=MemorySpec(kv_dtype='int8'),
    execution=ExecutionSpec(quant='int8'), maxima=...) serves a mixed
    fleet end-to-end from ONE compiled decode step, with greedy streams
    matching the float-cache single-topology baselines."""
    pa, pb = _fleet_params()
    mixed, eng = _fleet(pa, pb, "int8", "int8")
    assert eng.compilations["decode"] == 1
    assert eng.compilations["prefill"] == 1
    # the float-cache, float-weight single-topology baseline
    float_base = _solo_all(pa, pb, "none", "compute")
    assert mixed == float_base
    # and the fully-quantized single-topology engines agree too
    assert mixed == _solo_all(pa, pb, "int8", "int8")


def test_quantized_fleet_paged_matches_dense():
    pa, pb = _fleet_params()
    dense, _ = _fleet(pa, pb, "int8", "int8")
    paged, eng = _fleet(pa, pb, "int8", "int8", layout="paged")
    assert paged == dense
    assert eng.compilations["decode"] == 1


def test_quantized_fleet_pallas_kernel_smoke():
    """int8 pool + scales through the fabric's Pallas block-table kernels
    (padded-head-lane masking) must run the fleet to completion with one
    compilation and in-vocab tokens."""
    pa, pb = _fleet_params()
    got, eng = _fleet(pa, pb, "int8", "int8", layout="paged", impl="pallas")
    assert eng.compilations["decode"] == 1
    for (name, _), toks in got.items():
        assert len(toks) == MAX_NEW
        vocab = CFG_B.vocab_size if name == "b" else CFG_A.vocab_size
        assert all(0 <= t < vocab for t in toks)


def test_fleet_int8_table_is_actually_quantized():
    """add_model packs int8 values + scales (not silently float)."""
    from repro.core.quant import QTensor
    pa, _ = _fleet_params()
    _, eng = _fleet(pa, Model(CFG_B).init(jax.random.PRNGKey(1)),
                    "int8", "int8")
    assert isinstance(eng.params["embed"], QTensor)
    wq = eng.params["layers"]["wq"]
    assert isinstance(wq, QTensor) and wq.values.dtype == jnp.int8
    assert eng.cache.k.dtype == jnp.int8
    assert eng.cache.k_scale is not None


# ---------------------------------------------------------------------------
# quant_min_size threading
# ---------------------------------------------------------------------------
def test_quant_min_size_threads_through_engine_load():
    from repro.core.quant import QTensor

    def n_qtensors(tree):
        return sum(1 for l in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, QTensor))
            if isinstance(l, QTensor))

    cfg = reduced_cfg("qwen1.5-0.5b")
    params = Model(cfg).init(jax.random.PRNGKey(0))
    few = ServingEngine(RuntimeSpec(
        arch=cfg, execution=ExecutionSpec(quant="int8"),
        memory=MemorySpec(max_batch=2, max_len=32)))
    few.load(params)
    many = ServingEngine(RuntimeSpec(
        arch=cfg, execution=ExecutionSpec(quant="int8", quant_min_size=1),
        memory=MemorySpec(max_batch=2, max_len=32)))
    many.load(params)
    # the default floor (65536 elements) leaves the reduced model's tiny
    # kernels in float; floor 1 quantizes all of them
    assert n_qtensors(few.params) < n_qtensors(many.params)
    assert n_qtensors(many.params) >= 5
