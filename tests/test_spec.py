"""RuntimeSpec: the one configuration surface — lowering, ceilings,
construction-time validation."""
import dataclasses

import jax.numpy as jnp
import pytest

from conftest import reduced_cfg
from repro.configs import get_config
from repro.core.registers import registers_for
from repro.core.spec import (ExecutionSpec, MemorySpec, RuntimeSpec,
                             maxima_for)


# ---------------------------------------------------------------------------
# registers() lowering round-trips through registers_for
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "adaptor-bert",
                                  "whisper-medium"])
def test_registers_roundtrip(name):
    cfg = get_config(name)
    spec = RuntimeSpec(arch=cfg)
    got = spec.registers(sequence=64)
    want = registers_for(cfg, sequence=64)
    for field in want._fields:
        assert int(getattr(got, field)) == int(getattr(want, field)), field


def test_static_registers_match_traced():
    cfg = get_config("qwen1.5-0.5b")
    spec = RuntimeSpec(arch=cfg, memory=MemorySpec(max_len=64))
    static = spec.static_registers()
    regs = spec.registers(sequence=static["sequence"])
    for k in ("sequence", "heads", "layers_enc", "layers_dec",
              "embeddings", "hidden", "out"):
        assert static[k] == int(getattr(regs, k)), k


# ---------------------------------------------------------------------------
# fits_within: exact maxima are a fit, one-over on any axis is not
# ---------------------------------------------------------------------------
def _exact_maxima(cfg, max_len):
    return maxima_for(cfg, seq_max=max_len)


def test_fits_within_at_exact_maxima():
    cfg = reduced_cfg("qwen1.5-0.5b")
    spec = RuntimeSpec(arch=cfg, memory=MemorySpec(max_len=64))
    mx = _exact_maxima(cfg, 64)
    assert spec.fits_within(mx)
    assert spec.violations(mx) == []


@pytest.mark.parametrize("shrink", ["seq_max", "heads_max", "layers_enc_max",
                                    "d_model_max", "d_ff_max", "out_max"])
def test_fits_within_rejects_one_over(shrink):
    cfg = reduced_cfg("qwen1.5-0.5b")
    spec = RuntimeSpec(arch=cfg, memory=MemorySpec(max_len=64))
    mx = _exact_maxima(cfg, 64)
    mx = mx._replace(**{shrink: getattr(mx, shrink) - 1})
    assert not spec.fits_within(mx)
    assert spec.violations(mx)


def test_spec_with_maxima_validates_at_construction():
    cfg = reduced_cfg("qwen1.5-0.5b")
    small = _exact_maxima(cfg, 64)._replace(heads_max=cfg.num_heads - 1)
    with pytest.raises(ValueError, match="re-synthesis"):
        RuntimeSpec(arch=cfg, maxima=small, memory=MemorySpec(max_len=64))


def test_maxima_for_covers_fleet():
    a = reduced_cfg("qwen1.5-0.5b")
    b = dataclasses.replace(a, name="b", d_model=48, num_heads=3,
                            num_kv_heads=3, d_ff=96, vocab_size=96,
                            num_layers=1)
    mx = maxima_for(a, b, seq_max=64)
    for cfg in (a, b):
        assert RuntimeSpec(arch=cfg,
                           memory=MemorySpec(max_len=64)).fits_within(mx)
    assert mx.heads_max == 4 and mx.d_model_max == 64
    assert mx.layers_enc_max == 2 and mx.out_max == 128


# ---------------------------------------------------------------------------
# Construction-time rejection with actionable messages
# ---------------------------------------------------------------------------
def test_arch_rejects_nondividing_heads():
    cfg = reduced_cfg("qwen1.5-0.5b")
    with pytest.raises(ValueError, match="not divisible"):
        dataclasses.replace(cfg, d_model=65, head_dim=0)


def test_arch_rejects_bad_kv_grouping():
    cfg = reduced_cfg("qwen1.5-0.5b")
    with pytest.raises(ValueError, match="divisor of num_heads"):
        dataclasses.replace(cfg, num_heads=4, num_kv_heads=3)


def test_memory_rejects_undersized_pool():
    with pytest.raises(ValueError, match="never be admitted"):
        MemorySpec(cache_layout="paged", max_len=64, block_size=8,
                   num_blocks=7)
    # exactly max_len of pool capacity is legal
    MemorySpec(cache_layout="paged", max_len=64, block_size=8, num_blocks=8)


def test_memory_rejects_nondividing_block_size():
    with pytest.raises(ValueError, match="must divide"):
        MemorySpec(cache_layout="paged", max_len=64, block_size=7)


def test_execution_rejects_unknown_backend():
    with pytest.raises(ValueError, match="matmul_backend"):
        ExecutionSpec(matmul_backend="cuda")
    with pytest.raises(ValueError, match="cache_layout"):
        MemorySpec(cache_layout="ring")


def test_paged_spec_rejects_unpageable_family():
    cfg = reduced_cfg("falcon-mamba-7b")
    with pytest.raises(ValueError, match="unsupported for family"):
        RuntimeSpec(arch=cfg, memory=MemorySpec(cache_layout="paged",
                                                max_len=64))


def test_resolved_num_blocks_defaults_to_dense_worst_case():
    mem = MemorySpec(cache_layout="paged", max_batch=4, max_len=64,
                     block_size=8)
    assert mem.resolved_num_blocks == 4 * 64 // 8
    assert mem.paging().num_blocks == 32
    assert MemorySpec().paging() is None


def test_execution_dtypes_flow_to_model_options():
    from repro.models.model import Model, ModelOptions
    spec = RuntimeSpec(arch=reduced_cfg("qwen1.5-0.5b"),
                       execution=ExecutionSpec(matmul_backend="pallas",
                                               compute_dtype=jnp.float32))
    model = Model.from_spec(spec)
    assert isinstance(model.opt, ModelOptions)
    assert model.opt.matmul_backend == "pallas"
    assert model.opt.compute_dtype == jnp.float32


# ---------------------------------------------------------------------------
# String dtypes, quant_min_size, kv_dtype (the fully-quantized surface)
# ---------------------------------------------------------------------------
def test_execution_accepts_string_dtype_names():
    ex = ExecutionSpec(param_dtype="fp32", compute_dtype="bf16")
    assert ex.param_dtype == jnp.float32
    assert ex.compute_dtype == jnp.bfloat16
    assert ExecutionSpec(compute_dtype="float16").compute_dtype == jnp.float16
    # normalized strings flow through from_spec like real dtypes
    from repro.models.model import Model
    spec = RuntimeSpec(arch=reduced_cfg("qwen1.5-0.5b"),
                       execution=ExecutionSpec(compute_dtype="fp32"))
    assert Model.from_spec(spec).opt.compute_dtype == jnp.float32


def test_execution_rejects_bad_dtypes():
    with pytest.raises(ValueError, match="recognized dtype name"):
        ExecutionSpec(param_dtype="int7")
    with pytest.raises(ValueError, match="floating"):
        ExecutionSpec(compute_dtype=jnp.int8)


def test_execution_quant_min_size_validated():
    assert ExecutionSpec().quant_min_size == 65_536
    assert ExecutionSpec(quant_min_size=0).quant_min_size == 0
    with pytest.raises(ValueError, match="quant_min_size"):
        ExecutionSpec(quant_min_size=-1)


def test_memory_kv_dtype_validated_and_lowered():
    from repro.core.kv_quant import CacheCodec
    mem = MemorySpec(kv_dtype="int8")
    assert mem.codec() == CacheCodec("int8") and mem.codec().quantized
    assert not MemorySpec().codec().quantized
    with pytest.raises(ValueError, match="kv_dtype"):
        MemorySpec(kv_dtype="fp8")


def test_kv_dtype_int8_rejects_recurrent_families():
    cfg = reduced_cfg("falcon-mamba-7b")
    with pytest.raises(ValueError, match="kv_dtype='int8' is unsupported"):
        RuntimeSpec(arch=cfg, memory=MemorySpec(kv_dtype="int8",
                                                max_len=64))


def test_kv_dtype_flows_to_model_options():
    from repro.models.model import Model
    spec = RuntimeSpec(arch=reduced_cfg("qwen1.5-0.5b"),
                       memory=MemorySpec(kv_dtype="int8", max_len=64))
    model = Model.from_spec(spec)
    assert model.opt.kv_dtype == "int8"
    assert model.codec.quantized


def test_prefix_cache_validated_at_construction():
    """prefix_cache composes only with paged + chunked; both illegal
    combinations fail at spec construction, not at first request."""
    from repro.core.spec import SchedulerSpec
    with pytest.raises(ValueError, match="requires cache_layout='paged'"):
        MemorySpec(cache_layout="dense", prefix_cache=True)
    cfg = reduced_cfg("qwen1.5-0.5b")
    mem = MemorySpec(cache_layout="paged", max_len=64, block_size=8,
                     prefix_cache=True)
    with pytest.raises(ValueError, match="requires the chunked scheduler"):
        RuntimeSpec(arch=cfg, memory=mem,
                    scheduler=SchedulerSpec(policy="bucketed"))
    # paged + chunked (and the "auto" resolution of it) construct fine
    RuntimeSpec(arch=cfg, memory=mem,
                scheduler=SchedulerSpec(policy="chunked", chunk_size=8))
    RuntimeSpec(arch=cfg, memory=mem, scheduler=SchedulerSpec(policy="auto"))
