"""Serving engine: continuous batching correctness + compile accounting."""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=4, max_len=64,
                        sampling=SamplingParams())  # greedy
    eng.load(model.init(jax.random.PRNGKey(0)))
    return eng


def test_engine_matches_manual_greedy(engine):
    model = engine.model
    params = engine.params
    prompt = [1, 2, 3]
    uid = engine.submit(prompt, max_new_tokens=6)
    done = engine.run_to_completion()
    req = next(r for r in done if r.uid == uid)

    toks = jnp.asarray([prompt + [0] * 29], jnp.int32)
    lg, cache = model.prefill(params, {"tokens": toks}, max_len=64)
    out = [int(jnp.argmax(lg[0, len(prompt) - 1]))]
    idx = len(prompt)
    for _ in range(5):
        lg1, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(idx))
        out.append(int(jnp.argmax(lg1[0, 0])))
        idx += 1
    assert req.generated == out


def test_queueing_and_slot_reuse(engine):
    for n in (3, 7, 12, 5, 9, 4):  # 6 requests > 4 slots
        engine.submit(list(range(1, 1 + n)), max_new_tokens=4)
    done = engine.run_to_completion()
    assert len(done) == 6
    assert all(len(r.generated) == 4 for r in done)


def test_compile_once_accounting(engine):
    """Many requests, mixed lengths: exactly one decode compilation."""
    assert engine.compilations["decode"] == 1
    assert engine.compilations["prefill_buckets"] <= 3


def test_interleaved_matches_isolated(engine):
    """Result for a prompt must not depend on what else shares the batch."""
    p = [5, 6, 7, 8]
    uid = engine.submit(p, max_new_tokens=5)
    done1 = engine.run_to_completion()
    alone = next(r for r in done1 if r.uid == uid).generated

    uid2 = engine.submit(p, max_new_tokens=5)
    for other in ([1, 2], [9, 10, 11], [3]):
        engine.submit(other, max_new_tokens=5)
    done2 = engine.run_to_completion()
    mixed = next(r for r in done2 if r.uid == uid2).generated
    assert alone == mixed


def test_eos_stops_generation():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=2, max_len=64,
                        sampling=SamplingParams())
    eng.load(model.init(jax.random.PRNGKey(0)))
    uid = eng.submit([1, 2, 3], max_new_tokens=50, eos_id=None)
    done = eng.run_to_completion()
    req = next(r for r in done if r.uid == uid)
    # now force EOS on the first generated token
    eng2 = ServingEngine(model, max_batch=2, max_len=64,
                         sampling=SamplingParams())
    eng2.load(eng.params)
    uid2 = eng2.submit([1, 2, 3], max_new_tokens=50,
                       eos_id=req.generated[1])
    done2 = eng2.run_to_completion()
    req2 = next(r for r in done2 if r.uid == uid2)
    assert len(req2.generated) == 2


# ---------------------------------------------------------------------------
# Device-resident loop: bit-identity, host-traffic and compile accounting
# ---------------------------------------------------------------------------
def _per_slot_reference(model, params, prompt, max_new, max_len=64):
    """The seed engine's per-slot greedy loop, replayed at the model level:
    bucket-padded B=1 prefill, then one host-synced decode per token."""
    buckets = [32, 64]
    bucket = next(b for b in buckets if b >= len(prompt))
    toks = jnp.asarray([prompt + [0] * (bucket - len(prompt))], jnp.int32)
    lg, cache = model.prefill(params, {"tokens": toks}, max_len=max_len)
    out = [int(jnp.argmax(lg[0, len(prompt) - 1]))]
    idx = len(prompt)
    while len(out) < max_new and idx < max_len - 1:
        lg1, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(idx))
        out.append(int(jnp.argmax(lg1[0, 0])))
        idx += 1
    return out


def test_mixed_workload_bit_identical_to_per_slot_loop(engine):
    """Mixed prompt lengths, more requests than slots: every token stream
    must be bit-identical to the seed-style per-slot host loop."""
    prompts = [[1, 2, 3], list(range(1, 9)), [4], list(range(2, 40, 3)),
               [7, 7, 7, 7, 7], list(range(1, 20))]
    uids = {engine.submit(p, max_new_tokens=5): p for p in prompts}
    done = engine.run_to_completion()
    assert len(done) == len(prompts)
    for req in done:
        want = _per_slot_reference(engine.model, engine.params,
                                   uids[req.uid], 5)
        assert req.generated == want, req.uid


def test_paged_mixed_workload_bit_identical(engine):
    """The paged layout (block-budget admission, pooled cache, block-table
    decode) must reproduce the dense engine's token streams bit-for-bit —
    same requests, same seed, same greedy sampling — for every pool
    geometry, including one with fewer blocks than the dense worst case."""
    prompts = [[1, 2, 3], list(range(1, 9)), [4], list(range(2, 40, 3)),
               [7, 7, 7, 7, 7], list(range(1, 20))]
    want = {tuple(p): _per_slot_reference(engine.model, engine.params, p, 5)
            for p in prompts}
    for num_blocks in (None, 14):   # worst-case pool / undersized pool
        eng = ServingEngine(engine.model, max_batch=4, max_len=64,
                            sampling=SamplingParams(), cache_layout="paged",
                            block_size=8, num_blocks=num_blocks)
        eng.load(engine.params)
        uids = {eng.submit(p, max_new_tokens=5): tuple(p) for p in prompts}
        done = eng.run_to_completion()
        assert len(done) == len(prompts)
        for req in done:
            assert req.generated == want[uids[req.uid]], (num_blocks, req.uid)
        assert eng.compilations["decode"] == 1


def test_paged_sync_every_matches_per_step_sync(engine):
    """Deferred harvest with block pre-reservation across the window must
    not change streams (blocks are reserved for the whole window up
    front, so the fused steps never outrun the tables)."""
    outs = {}
    for k in (1, 4):
        eng = ServingEngine(engine.model, max_batch=2, max_len=64,
                            sampling=SamplingParams(), cache_layout="paged",
                            block_size=8)
        eng.load(engine.params)
        uid_a = eng.submit([1, 2, 3], max_new_tokens=7)
        uid_b = eng.submit([9, 8, 7, 6], max_new_tokens=5)
        done = {r.uid: r.generated for r in
                eng.run_to_completion(sync_every=k)}
        outs[k] = (done[uid_a], done[uid_b])
    assert outs[1] == outs[4]


def test_compile_accounting_after_mixed_workload(engine):
    """The fused step must still compile exactly once across the whole
    mixed-length history of this module's engine."""
    assert engine.compilations["decode"] == 1
    assert engine.compilations["prefill_buckets"] <= len(engine.buckets)


def test_o1_host_transfers_per_step():
    """Host<->device traffic per decode step must not scale with max_batch
    (the seed engine did O(max_batch) scalar syncs per token), and the
    finished-buffer pull must scale with the tokens produced, not with
    the [mb, max_len] buffer allocation."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    gets_per_step = {}
    for mb in (2, 8):
        eng = ServingEngine(model, max_batch=mb, max_len=64,
                            sampling=SamplingParams())
        eng.load(model.init(jax.random.PRNGKey(0)))
        for i in range(mb):
            eng.submit([1 + i, 2, 3], max_new_tokens=6)
        eng.run_to_completion()
        assert eng.stats["decode_steps"] > 0
        # <= 1 bulk get per step + 1 per harvest event (amortized < 2)
        gets_per_step[mb] = eng.stats["device_gets"] / eng.stats["decode_steps"]
        assert gets_per_step[mb] <= 2.0
        # buffers are sliced to max(count) columns before the device_get:
        # mb requests x 6 tokens, never mb x max_len
        assert eng.stats["harvest_elems"] <= mb * 6
    assert gets_per_step[8] <= gets_per_step[2] + 1e-9


def test_sync_every_matches_per_step_sync():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    outs = {}
    for k in (1, 4):
        eng = ServingEngine(model, max_batch=2, max_len=64,
                            sampling=SamplingParams())
        eng.load(params)
        uid_a = eng.submit([1, 2, 3], max_new_tokens=7)
        uid_b = eng.submit([9, 8, 7, 6], max_new_tokens=5)
        done = {r.uid: r.generated for r in
                eng.run_to_completion(sync_every=k)}
        outs[k] = (done[uid_a], done[uid_b])
        # deferred harvest must sync strictly less often
        if k == 4:
            assert eng.stats["device_gets"] < eng.stats["decode_steps"]
    assert outs[1] == outs[4]


def test_overlong_prompt_rejected_at_submit():
    """Rejection happens at submit(), not mid-drain with requests in
    flight; queued work is unaffected."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=2, max_len=32,
                        sampling=SamplingParams())
    eng.load(model.init(jax.random.PRNGKey(0)))
    uid = eng.submit([1, 2, 3], max_new_tokens=3)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(list(range(1, 40)), max_new_tokens=4)
    done = eng.run_to_completion()
    assert [r.uid for r in done] == [uid]


def test_single_token_budget():
    """max_new_tokens=1 must yield exactly the prefill-sampled token."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=2, max_len=32,
                        sampling=SamplingParams())
    eng.load(model.init(jax.random.PRNGKey(0)))
    uid = eng.submit([1, 2, 3], max_new_tokens=1)
    done = eng.run_to_completion()
    req = next(r for r in done if r.uid == uid)
    assert len(req.generated) == 1


def test_pallas_backend_decode_matches_xla():
    """Engine option routing decode matmuls through the Pallas tiled
    kernels (interpret mode on CPU) must reproduce the XLA stream."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    streams = {}
    for impl in ("xla", "pallas"):
        eng = ServingEngine(model, max_batch=2, max_len=32,
                            sampling=SamplingParams(), matmul_backend=impl)
        eng.load(params)
        uid = eng.submit([3, 1, 4, 1, 5], max_new_tokens=4)
        done = eng.run_to_completion()
        streams[impl] = next(r for r in done if r.uid == uid).generated
        assert len(streams[impl]) == 4
    assert streams["xla"] == streams["pallas"]


def test_engine_backend_overrides_model_backend():
    """An explicit engine matmul_backend must win over the model's own
    ModelOptions setting (tracing goes through the shadow model)."""
    from repro.models.model import ModelOptions
    cfg = reduced_cfg("qwen1.5-0.5b")
    mp = Model(cfg, ModelOptions(matmul_backend="pallas"))
    eng = ServingEngine(mp, max_batch=2, max_len=32,
                        sampling=SamplingParams(), matmul_backend="xla")
    assert eng._traced_model.opt.matmul_backend == "xla"
    # and the inherit path shares the model object (no re-trace risk)
    eng2 = ServingEngine(mp, max_batch=2, max_len=32,
                         sampling=SamplingParams())
    assert eng2._traced_model is mp


# ---------------------------------------------------------------------------
# RuntimeSpec surface + deprecation shims
# ---------------------------------------------------------------------------
def _greedy_stream(eng, params, prompt=(3, 1, 4, 1, 5), n=4):
    eng.load(params)
    uid = eng.submit(list(prompt), max_new_tokens=n)
    done = eng.run_to_completion()
    return next(r for r in done if r.uid == uid).generated


def test_spec_engine_matches_legacy_engine():
    """The new ServingEngine(RuntimeSpec) spelling must behave exactly
    like the legacy model-first spelling."""
    from repro.core.spec import MemorySpec, RuntimeSpec
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    legacy = ServingEngine(model, max_batch=2, max_len=32,
                           sampling=SamplingParams())
    spec = RuntimeSpec(arch=cfg, memory=MemorySpec(max_batch=2, max_len=32))
    new = ServingEngine(spec, sampling=SamplingParams())
    assert new.model.opt.matmul_backend == new.spec.execution.matmul_backend
    assert _greedy_stream(new, params) == _greedy_stream(legacy, params)


def test_legacy_matmul_backend_kwarg_warns_and_matches():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="matmul_backend"):
        old = ServingEngine(model, max_batch=2, max_len=32,
                            sampling=SamplingParams(), matmul_backend="xla")
    # the shim folds the kwarg into the one spec — no second source
    assert old.spec.execution.matmul_backend == "xla"
    assert old.matmul_backend == "xla"
    quiet = ServingEngine(model, max_batch=2, max_len=32,
                          sampling=SamplingParams())
    assert _greedy_stream(old, params) == _greedy_stream(quiet, params)


def test_legacy_cache_layout_kwargs_warn_and_match():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="cache_layout"):
        paged = ServingEngine(model, max_batch=2, max_len=64,
                              sampling=SamplingParams(),
                              cache_layout="paged", block_size=8,
                              num_blocks=16)
    assert paged.spec.memory.cache_layout == "paged"
    assert paged.paging.block_size == 8 and paged.paging.num_blocks == 16
    dense = ServingEngine(model, max_batch=2, max_len=64,
                          sampling=SamplingParams())
    assert _greedy_stream(paged, params) == _greedy_stream(dense, params)


def test_engine_reads_execution_from_one_source():
    """satellite: no dataclasses.replace of the model's options — the
    engine's traced model and the engine itself read spec.execution."""
    from repro.core.spec import ExecutionSpec, MemorySpec, RuntimeSpec
    cfg = reduced_cfg("qwen1.5-0.5b")
    spec = RuntimeSpec(arch=cfg,
                       execution=ExecutionSpec(matmul_backend="pallas"),
                       memory=MemorySpec(max_batch=2, max_len=32))
    eng = ServingEngine(spec)
    assert eng.matmul_backend == "pallas"
    assert eng._traced_model.opt.matmul_backend == "pallas"
    assert eng._traced_model is eng.model


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------
def test_greedy_is_argmax():
    logits = jnp.asarray([[1.0, 5.0, 2.0], [0.0, -1.0, 3.0]])
    toks = sample(logits, jax.random.PRNGKey(0), SamplingParams())
    assert toks.tolist() == [1, 2]


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 30.0]])
    p = SamplingParams(temperature=1.0, top_k=2)
    for i in range(20):
        t = int(sample(logits, jax.random.PRNGKey(i), p)[0])
        assert t in (2, 3)


def test_top_p_restricts_support():
    logits = jnp.asarray([[10.0, 9.0, -10.0, -10.0]])
    p = SamplingParams(temperature=1.0, top_p=0.9)
    for i in range(20):
        t = int(sample(logits, jax.random.PRNGKey(i), p)[0])
        assert t in (0, 1)
