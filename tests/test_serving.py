"""Serving engine: continuous batching correctness + compile accounting."""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=4, max_len=64,
                        sampling=SamplingParams())  # greedy
    eng.load(model.init(jax.random.PRNGKey(0)))
    return eng


def test_engine_matches_manual_greedy(engine):
    model = engine.model
    params = engine.params
    prompt = [1, 2, 3]
    uid = engine.submit(prompt, max_new_tokens=6)
    done = engine.run_to_completion()
    req = next(r for r in done if r.uid == uid)

    toks = jnp.asarray([prompt + [0] * 29], jnp.int32)
    lg, cache = model.prefill(params, {"tokens": toks}, max_len=64)
    out = [int(jnp.argmax(lg[0, len(prompt) - 1]))]
    idx = len(prompt)
    for _ in range(5):
        lg1, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(idx))
        out.append(int(jnp.argmax(lg1[0, 0])))
        idx += 1
    assert req.generated == out


def test_queueing_and_slot_reuse(engine):
    for n in (3, 7, 12, 5, 9, 4):  # 6 requests > 4 slots
        engine.submit(list(range(1, 1 + n)), max_new_tokens=4)
    done = engine.run_to_completion()
    assert len(done) == 6
    assert all(len(r.generated) == 4 for r in done)


def test_compile_once_accounting(engine):
    """Many requests, mixed lengths: exactly one decode compilation."""
    assert engine.compilations["decode"] == 1
    assert engine.compilations["prefill_buckets"] <= 3


def test_interleaved_matches_isolated(engine):
    """Result for a prompt must not depend on what else shares the batch."""
    p = [5, 6, 7, 8]
    uid = engine.submit(p, max_new_tokens=5)
    done1 = engine.run_to_completion()
    alone = next(r for r in done1 if r.uid == uid).generated

    uid2 = engine.submit(p, max_new_tokens=5)
    for other in ([1, 2], [9, 10, 11], [3]):
        engine.submit(other, max_new_tokens=5)
    done2 = engine.run_to_completion()
    mixed = next(r for r in done2 if r.uid == uid2).generated
    assert alone == mixed


def test_eos_stops_generation():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=2, max_len=64,
                        sampling=SamplingParams())
    eng.load(model.init(jax.random.PRNGKey(0)))
    uid = eng.submit([1, 2, 3], max_new_tokens=50, eos_id=None)
    done = eng.run_to_completion()
    req = next(r for r in done if r.uid == uid)
    # now force EOS on the first generated token
    eng2 = ServingEngine(model, max_batch=2, max_len=64,
                         sampling=SamplingParams())
    eng2.load(eng.params)
    uid2 = eng2.submit([1, 2, 3], max_new_tokens=50,
                       eos_id=req.generated[1])
    done2 = eng2.run_to_completion()
    req2 = next(r for r in done2 if r.uid == uid2)
    assert len(req2.generated) == 2


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------
def test_greedy_is_argmax():
    logits = jnp.asarray([[1.0, 5.0, 2.0], [0.0, -1.0, 3.0]])
    toks = sample(logits, jax.random.PRNGKey(0), SamplingParams())
    assert toks.tolist() == [1, 2]


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 30.0]])
    p = SamplingParams(temperature=1.0, top_k=2)
    for i in range(20):
        t = int(sample(logits, jax.random.PRNGKey(i), p)[0])
        assert t in (2, 3)


def test_top_p_restricts_support():
    logits = jnp.asarray([[10.0, 9.0, -10.0, -10.0]])
    p = SamplingParams(temperature=1.0, top_p=0.9)
    for i in range(20):
        t = int(sample(logits, jax.random.PRNGKey(i), p)[0])
        assert t in (0, 1)
