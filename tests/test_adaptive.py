"""The paper's C1 claim, test-enforced: one compiled engine serves every
topology within maxima with zero retraces, matching the unpadded oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine_ref
from repro.core.adaptive import AdaptiveEngine, EngineOptions, pack
from repro.core.registers import Maxima, make_registers, registers_for
from repro.configs import get_config

MX = Maxima(seq_max=32, heads_max=8, layers_enc_max=4, layers_dec_max=2,
            d_model_max=96, d_ff_max=192, out_max=100, head_dim_max=16,
            vocab=100)

TOPOLOGIES = [
    dict(seq=16, d_model=64, heads=4, d_ff=128, layers_enc=2, layers_dec=0,
         act="relu"),
    dict(seq=32, d_model=96, heads=8, d_ff=192, layers_enc=4, layers_dec=0,
         act="gelu"),                                  # the maxima topology
    dict(seq=24, d_model=48, heads=3, d_ff=96, layers_enc=3, layers_dec=2,
         act="relu"),                                  # enc-dec, odd heads
    dict(seq=16, d_model=64, heads=4, d_ff=128, layers_enc=2, layers_dec=0,
         act="relu", kv_heads=2),                      # GQA packing
]


@pytest.fixture(scope="module")
def engine():
    return AdaptiveEngine(MX, EngineOptions(batch=2, decoder=True))


def _run(engine, step, topo, seed):
    net = engine_ref.random_network(
        jax.random.PRNGKey(seed), vocab=100, out=100,
        **{k: v for k, v in topo.items() if k != "act"})
    params = pack(engine, net)
    regs = make_registers(
        sequence=topo["seq"], heads=topo["heads"],
        layers_enc=topo["layers_enc"], layers_dec=topo["layers_dec"],
        embeddings=topo["d_model"], hidden=topo["d_ff"], out=100,
        kv_heads=topo.get("kv_heads", topo["heads"]))
    toks = jax.random.randint(jax.random.PRNGKey(100 + seed),
                              (2, MX.seq_max), 0, 100)
    tgt = jax.random.randint(jax.random.PRNGKey(200 + seed),
                             (2, MX.seq_max), 0, 100)
    act = jnp.int32(1 if topo["act"] == "gelu" else 0)
    out = step(params, regs, act, toks, tgt)
    want = engine_ref.forward(
        net, toks[:, :topo["seq"]], activation=topo["act"],
        tgt_tokens=tgt[:, :topo["seq"]] if topo["layers_dec"] else None)
    return np.asarray(out[:, :topo["seq"], :100]), np.asarray(want)


@pytest.mark.parametrize("i", range(len(TOPOLOGIES)))
def test_engine_matches_oracle(engine, i):
    step = engine.compile()
    got, want = _run(engine, step, TOPOLOGIES[i], seed=i)
    np.testing.assert_allclose(got, want, atol=2e-4 * np.abs(want).max(),
                               rtol=1e-3)


def test_no_retrace_across_topologies(engine):
    """The 36-hour-synthesis amortization claim: N topologies, 1 trace."""
    step = engine.compile()
    for i, t in enumerate(TOPOLOGIES):
        _run(engine, step, t, seed=10 + i)
    assert engine.trace_count() == 1


def test_maxima_violation_rejected():
    MX.validate({"sequence": 32, "heads": 8})
    with pytest.raises(ValueError, match="re-synthesis"):
        MX.validate({"heads": 16})
    with pytest.raises(ValueError, match="re-synthesis"):
        MX.validate({"embeddings": 1024})


def test_registers_for_configs():
    regs = registers_for(get_config("adaptor-bert"), sequence=64)
    assert int(regs.heads) == 12 and int(regs.embeddings) == 768
    assert int(regs.layers_dec) == 0
    regs = registers_for(get_config("whisper-medium"), sequence=64)
    assert int(regs.layers_enc) == 24 and int(regs.layers_dec) == 24


def test_idle_lanes_do_not_leak(engine):
    """Loading a big net then selecting a smaller topology must not let the
    big net's extra lanes contaminate the output (the clock-gating
    equivalence)."""
    step = engine.compile()
    big = engine_ref.random_network(jax.random.PRNGKey(0), seq=32,
                                    d_model=96, heads=8, d_ff=192,
                                    layers_enc=4, vocab=100, out=100)
    params = pack(engine, big)
    regs = make_registers(sequence=16, heads=4, layers_enc=2, layers_dec=0,
                          embeddings=48, hidden=96, out=100)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0, 100)
    out = step(params, regs, jnp.int32(0), toks, toks)
    # oracle: slice the big net down to the small topology
    sliced = {
        "seq": 16, "d_model": 48, "heads": 4, "kv_heads": 4, "head_dim": 12,
        "d_ff": 96, "vocab": 100, "out": 100,
        "embed": big["embed"][:, :48], "pos": big["pos"][:16, :48],
        "w_out": big["w_out"][:48], "b_out": big["b_out"],
        "dec_layers": [],
        "enc_layers": [],
    }
    for lp in big["enc_layers"][:2]:
        a = lp["attn"]
        wq = a["wq"].reshape(96, 8, 12)[:48, :4].reshape(48, 48)
        wk = a["wk"].reshape(96, 8, 12)[:48, :4].reshape(48, 48)
        wv = a["wv"].reshape(96, 8, 12)[:48, :4].reshape(48, 48)
        wo = a["wo"].reshape(8, 12, 96)[:4, :, :48].reshape(48, 48)
        sliced["enc_layers"].append({
            "attn": {"wq": wq, "wk": wk, "wv": wv, "wo": wo,
                     "bq": a["bq"].reshape(8, 12)[:4].reshape(-1),
                     "bk": a["bk"].reshape(8, 12)[:4].reshape(-1),
                     "bv": a["bv"].reshape(8, 12)[:4].reshape(-1),
                     "bo": a["bo"][:48]},
            "ln1_g": lp["ln1_g"][:48], "ln1_b": lp["ln1_b"][:48],
            "w1": lp["w1"][:48, :96], "b1": lp["b1"][:96],
            "w2": lp["w2"][:96, :48], "b2": lp["b2"][:48],
            "ln2_g": lp["ln2_g"][:48], "ln2_b": lp["ln2_b"][:48]})
    want = engine_ref.forward(sliced, toks[:, :16], activation="relu")
    np.testing.assert_allclose(np.asarray(out[:, :16, :100]),
                               np.asarray(want),
                               atol=3e-4 * float(np.abs(want).max()),
                               rtol=1e-3)
