"""int8 serving quantization (paper C6 at deployment): numerics + trees."""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg
from repro.core.quant import QTensor
from repro.core.serve_quant import (quantize_abstract, quantize_axes,
                                    quantize_params)
from repro.models.model import Model


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_params(params, min_size=1024)
    return cfg, model, params, qp


def test_quantizes_kernels_and_tables(setup):
    _, _, _, qp = setup
    n = sum(1 for l in jax.tree_util.tree_leaves(
        qp, is_leaf=lambda x: isinstance(x, QTensor))
        if isinstance(l, QTensor))
    assert n >= 5  # qkv/o/ffn kernels + embed table


def test_int8_forward_close_to_f32(setup):
    cfg, model, params, qp = setup
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12),
                                          0, cfg.vocab_size)}
    ref = model.forward(params, batch)
    got = model.forward(qp, batch)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.08, rel


def test_int8_decode_matches_int8_forward(setup):
    cfg, model, params, qp = setup
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              cfg.vocab_size)
    full = model.forward(qp, {"tokens": toks})
    cache = model.init_cache(2, 8)
    errs = []
    for t in range(8):
        lg, cache = model.decode_step(qp, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 2e-2 * float(jnp.abs(full).max())


def test_abstract_and_axes_trees_match(setup):
    cfg, model, _, qp = setup
    qa = quantize_abstract(model.abstract(), min_size=1024)
    assert jax.tree_util.tree_structure(qp) == \
        jax.tree_util.tree_structure(qa)
    # shapes/dtypes agree leaf-wise
    jax.tree_util.tree_map(
        lambda r, a: None if (r.shape, r.dtype) == (a.shape, a.dtype)
        else pytest.fail(f"{r.shape}/{r.dtype} vs {a.shape}/{a.dtype}"),
        qp, qa)
    # axes tree has one PartitionSpec per abstract leaf
    from jax.sharding import PartitionSpec as P
    qx = quantize_axes(model.axes(), model.abstract(), min_size=1024)
    n_ax = len(jax.tree_util.tree_leaves(
        qx, is_leaf=lambda x: isinstance(x, P)))
    n_ab = len(jax.tree_util.tree_leaves(qa))
    assert n_ax == n_ab


def test_stacked_kernel_scale_keeps_layer_dim(setup):
    _, model, _, qp = setup
    wq = qp["layers"]["attn"]["wq"]["kernel"]
    assert isinstance(wq, QTensor)
    # stacked [L, K, N] kernel -> per-(layer, column) scales [L, 1, N]
    assert wq.scale.shape == (wq.values.shape[0], 1, wq.values.shape[2])