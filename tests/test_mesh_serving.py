"""Mesh-sharded serving: TP/DP equivalence, capacity planning, placement.

The contract under test is the ISSUE-9 tentpole: the fused mixed step
lowered onto a (1, tp) GSPMD mesh and data-parallel engine replicas
behind one admission queue must stream *bit-identical* tokens to the
historical single-device engine, while keeping the compile-once
discipline (one mixed-step compilation per replica).  All runs use
fp32 compute so cross-device reduction order cannot flip an argmax.
"""
import dataclasses

import jax
import pytest

from conftest import reduced_cfg
from repro.core.spec import (ExecutionSpec, MemorySpec, MeshSpec,
                             RuntimeSpec, SchedulerSpec)
from repro.distributed import sharding as shd
from repro.harness import poisson_trace, replay
from repro.models.model import Model
from repro.serving.cluster import EngineCluster
from repro.serving.engine import ServingEngine

CFG = reduced_cfg("qwen1.5-0.5b")


@pytest.fixture(scope="module")
def params():
    return Model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def trace():
    # staggered arrivals: admissions, slot reuse, and steady-state decode
    # all occur (the all-at-once smoke only exercises admission)
    return poisson_trace(10, rate=0.5, max_len=16, max_new=6,
                         vocab=CFG.vocab_size - 1, seed=3)


def _spec(mesh=MeshSpec(), **mem_kw):
    kw = dict(cache_layout="paged", max_batch=4, max_len=64, block_size=8)
    kw.update(mem_kw)
    return RuntimeSpec(arch=CFG, execution=ExecutionSpec(compute_dtype="fp32"),
                       memory=MemorySpec(**kw), mesh=mesh)


def _streams(engine, trace):
    r = replay(engine, trace)
    return {r.uid_to_rid[q.uid]: tuple(q.generated) for q in r.finished}, r


MATRIX = {
    "dense": dict(cache_layout="dense", scheduler=True),
    "paged": {},
    "int8-kv": dict(kv_dtype="int8"),
    "prefix-cache": dict(prefix_cache=True),
}


@pytest.mark.parametrize("point", sorted(MATRIX))
def test_tp2_streams_bit_identical_to_single_device(point, params, trace):
    mem = dict(MATRIX[point])
    sched = mem.pop("scheduler", False)
    sched_kw = {}
    if sched:
        # dense layout resolves policy 'auto' to bucketed; tp > 1 needs
        # the fused chunked step, so pin it explicitly
        sched_kw["scheduler"] = SchedulerSpec(policy="chunked")

    def build(mesh):
        spec = dataclasses.replace(_spec(mesh=mesh, **mem), **sched_kw)
        eng = ServingEngine(spec)
        eng.load(params)
        return eng

    base, _ = _streams(build(MeshSpec()), trace)
    eng2 = build(MeshSpec(tp=2))
    got, _ = _streams(eng2, trace)
    assert got == base
    comp = eng2.compilations
    assert comp["prefill"] == 1 and comp["decode"] == 1


def test_dp2_cluster_streams_bit_identical_and_events_merge(params, trace):
    base_eng = ServingEngine(_spec())
    base_eng.load(params)
    base, rb = _streams(base_eng, trace)

    cl = EngineCluster(_spec(mesh=MeshSpec(tp=1, dp=2)))
    cl.load(params)
    got, rc = _streams(cl, trace)
    assert got == base
    # every replica kept the compile-once discipline
    for comp in cl.compilations:
        assert comp["prefill"] == 1 and comp["decode"] == 1
    # merged EventLog: every request's full lifecycle under cluster uids
    uids = {e.uid for e in rc.events}
    assert uids == set(rc.uid_to_rid)
    for uid in uids:
        kinds = [e.kind for e in rc.events if e.uid == uid]
        assert kinds[0] == "submit" and kinds[-1] == "finish"
        assert "admit" in kinds and "first_token" in kinds
    # the reduced metrics see the same completions as the single engine
    assert len(rc.metrics.per_request) == len(rb.metrics.per_request)


def test_tp2_dp2_cluster_matches_single_device(params, trace):
    base_eng = ServingEngine(_spec())
    base_eng.load(params)
    base, _ = _streams(base_eng, trace)

    cl = EngineCluster(_spec(mesh=MeshSpec(tp=2, dp=2)))
    cl.load(params)
    got, _ = _streams(cl, trace)
    assert got == base


def test_cluster_routes_by_free_capacity(params):
    cl = EngineCluster(_spec(mesh=MeshSpec(tp=1, dp=2)))
    cl.load(params)
    # equal capacity: first submit ties -> replica 0; the second must go
    # to replica 1 (replica 0 now has queued demand)
    cl.submit([1, 2, 3], max_new_tokens=4)
    cl.submit([4, 5, 6], max_new_tokens=4)
    assert len(cl.replicas[0].queue) == 1
    assert len(cl.replicas[1].queue) == 1
    done = cl.run_to_completion()
    # cluster uids are cluster-level (1, 2), not per-replica (1, 1)
    assert sorted(r.uid for r in done) == [1, 2]


def test_capacity_planner_matches_admission(params):
    spec = _spec(mesh=MeshSpec(tp=2, dp=2), max_batch=2)
    cap = spec.capacity()
    assert cap.n_devices == 4
    assert cap.max_concurrent == 4          # dp * max_batch
    assert cap.kv_shards == 2               # 4 kv heads / tp=2
    assert cap.per_device_cache_bytes * cap.kv_shards \
        == cap.cache_bytes_per_replica

    cl = EngineCluster(spec)
    cl.load(params)
    # long decodes hold their slots: admission must seat exactly
    # max_concurrent requests and queue the rest
    for i in range(cap.max_concurrent + 2):
        cl.submit([1 + i, 2, 3], max_new_tokens=32)
    cl.step()
    seated = sum(r is not None for r in cl.slot_req)
    assert seated == cap.max_concurrent
    assert len(cl.queue) == 2


def test_maxima_for_is_mesh_aware():
    from repro.core.registers import Maxima
    from repro.core.spec import maxima_for
    maxima = maxima_for(CFG, seq_max=64)
    sharded = maxima_for(CFG, seq_max=64, mesh=MeshSpec(tp=2))
    assert isinstance(maxima, Maxima)
    # per-device register ceilings halve along every tp-sharded axis
    assert sharded.heads_max * 2 == maxima.heads_max
    assert sharded.d_ff_max * 2 == maxima.d_ff_max


def test_tp2_cache_actually_sharded(params):
    eng = ServingEngine(_spec(mesh=MeshSpec(tp=2)))
    eng.load(params)
    k = jax.tree.leaves(eng.cache)[0]
    # kv-head axis (-2) is split over the model axis: each device holds
    # half the heads, and the global shape is unchanged
    shard = k.addressable_shards[0].data
    assert shard.shape[-2] * 2 == k.shape[-2]
    assert len(k.sharding.device_set) == 2


def test_mesh_divisibility_falls_back_to_replication():
    # 3 kv heads on a tp=2 mesh cannot shard: capacity must report one
    # shard, and the cache sharding helper must replicate the leaf
    odd = dataclasses.replace(CFG, num_heads=3, num_kv_heads=3)
    assert MeshSpec(tp=2).kv_shards(odd) == 1

    devs = jax.devices()[:2]
    mesh = shd.tp_mesh(devs)
    strategy = shd.strategy_for_mesh(mesh)
    import collections
    KV = collections.namedtuple("KV", ["k", "v"])
    import jax.numpy as jnp
    cache = [KV(jnp.zeros((2, 4, 8, 3, 16)), jnp.zeros((2, 4, 8, 3, 16)))]
    sh = shd.kv_cache_shardings(mesh, cache, strategy)
    assert sh[0].k.spec == jax.sharding.PartitionSpec()


def test_mesh_spec_validation():
    with pytest.raises(ValueError, match="tp"):
        MeshSpec(tp=0)
    with pytest.raises(ValueError, match="bucketed"):
        RuntimeSpec(arch=CFG, mesh=MeshSpec(tp=2),
                    scheduler=SchedulerSpec(policy="bucketed"))
    with pytest.raises(ValueError, match="EngineCluster"):
        ServingEngine(_spec(mesh=MeshSpec(tp=1, dp=2)))


def test_submit_rejects_out_of_vocab_prompt(params):
    # an OOB embedding gather clamps differently on a sharded table than
    # an unsharded one — the engine must reject instead of diverging
    eng = ServingEngine(_spec())
    eng.load(params)
    with pytest.raises(ValueError, match="vocab"):
        eng.submit([CFG.vocab_size], max_new_tokens=2)


def test_tuner_explores_meshes_and_pins_single_device():
    from repro.harness.tune import DeviceProfile, WorkloadProfile, tune
    wl = WorkloadProfile(mean_prompt_len=16, max_prompt_len=32, burst_size=16)
    r1 = tune(CFG, DeviceProfile(cache_budget_bytes=1 << 20), wl)
    assert {(c.spec.mesh.tp, c.spec.mesh.dp) for c in r1.ranked} == {(1, 1)}
    r4 = tune(CFG, DeviceProfile(cache_budget_bytes=1 << 20, n_devices=4),
              wl)
    assert {(c.spec.mesh.tp, c.spec.mesh.dp) for c in r4.ranked} \
        == {(1, 4), (2, 2), (4, 1)}
    # fleet capacity scales with dp: the 4-device winner must beat the
    # 1-device winner on predicted goodput
    assert r4.best.score > r1.best.score


def test_analytical_tp_term_monotone():
    from repro.configs.base import ShapeSpec
    from repro.core.analytical import analytical_step_seconds
    shape = ShapeSpec("t", 128, 4, "decode")
    base = analytical_step_seconds(CFG, shape, 1)
    same = analytical_step_seconds(CFG, shape, 1, tp=1)
    assert base.bytes_collective == same.bytes_collective  # pinned
    prev = 0.0
    for tp in (2, 4, 8):
        terms = analytical_step_seconds(CFG, shape, tp, tp=tp)
        assert terms.bytes_collective > prev
        prev = terms.bytes_collective
