"""Data pipeline: determinism, host sharding, resumability, packing."""
import numpy as np

from repro.data.pipeline import MemorizationStream, SyntheticLMStream


def test_deterministic():
    a = SyntheticLMStream(vocab_size=100, seq_len=32, global_batch=4, seed=1)
    b = SyntheticLMStream(vocab_size=100, seq_len=32, global_batch=4, seed=1)
    for _ in range(3):
        ba, bb = a.next(), b.next()
        np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                      np.asarray(bb["tokens"]))


def test_seed_changes_stream():
    a = SyntheticLMStream(vocab_size=100, seq_len=32, global_batch=4, seed=1)
    b = SyntheticLMStream(vocab_size=100, seq_len=32, global_batch=4, seed=2)
    assert not np.array_equal(np.asarray(a.next()["tokens"]),
                              np.asarray(b.next()["tokens"]))


def test_host_sharding_disjoint_union():
    """2 hosts x batch 2 == 1 host x batch 4, rows assigned by global id."""
    whole = SyntheticLMStream(vocab_size=50, seq_len=16, global_batch=4,
                              n_hosts=1, host_id=0, seed=3)
    h0 = SyntheticLMStream(vocab_size=50, seq_len=16, global_batch=4,
                           n_hosts=2, host_id=0, seed=3)
    h1 = SyntheticLMStream(vocab_size=50, seq_len=16, global_batch=4,
                           n_hosts=2, host_id=1, seed=3)
    w, a, b = whole.next(), h0.next(), h1.next()
    np.testing.assert_array_equal(np.asarray(w["tokens"][:2]),
                                  np.asarray(a["tokens"]))
    np.testing.assert_array_equal(np.asarray(w["tokens"][2:]),
                                  np.asarray(b["tokens"]))


def test_restore_resumes_exactly():
    s = SyntheticLMStream(vocab_size=50, seq_len=16, global_batch=2, seed=9)
    s.next()
    s.next()
    saved = s.state()
    want = s.next()
    r = SyntheticLMStream.restore(saved, vocab_size=50, seq_len=16,
                                  global_batch=2)
    got = r.next()
    np.testing.assert_array_equal(np.asarray(want["tokens"]),
                                  np.asarray(got["tokens"]))


def test_targets_are_shifted_tokens():
    s = SyntheticLMStream(vocab_size=50, seq_len=16, global_batch=2, seed=4)
    b = s.next()
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_packing_contains_eos_boundaries():
    s = SyntheticLMStream(vocab_size=50, seq_len=256, global_batch=1, seed=5,
                          mean_doc_len=16)
    b = s.next()
    assert (np.asarray(b["tokens"]) == s.eos_id).sum() > 2


def test_memorization_stream_cycles():
    s = MemorizationStream(vocab_size=50, seq_len=8, batch=4, n_rows=4)
    a = s.next()
    for _ in range(0):
        s.next()
    s2 = MemorizationStream(vocab_size=50, seq_len=8, batch=4, n_rows=4)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(s2.next()["tokens"]))
