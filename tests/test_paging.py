"""Paged KV-cache subsystem: allocator, kernel, admission, preemption."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.paging import (NULL_BLOCK, BlockAllocator, PagingConfig,
                               blocks_for_tokens)
from repro.kernels.paged_attention import paged_decode_attention
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample_per_slot


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------
def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(PagingConfig(block_size=16, num_blocks=8))
    assert a.num_free == 8
    got = a.alloc(3)
    assert len(got) == 3 and a.num_free == 5
    assert NULL_BLOCK not in got            # block 0 is never handed out
    assert len(set(got)) == 3
    a.free(got)
    assert a.num_free == 8


def test_allocator_oom_returns_none_without_side_effects():
    a = BlockAllocator(PagingConfig(block_size=16, num_blocks=4))
    first = a.alloc(3)
    assert a.alloc(2) is None
    assert a.num_free == 1                  # failed alloc took nothing
    a.free(first)
    assert a.alloc(4) is not None


def test_allocator_lifo_reuse_and_double_free():
    a = BlockAllocator(PagingConfig(block_size=16, num_blocks=4))
    got = a.alloc(2)
    a.free(got)
    assert a.alloc(1)[0] == got[0]          # just-freed block comes back first
    with pytest.raises(ValueError, match="double free"):
        a.free([a.alloc(1)[0]] * 2)


def test_fragmentation_stats():
    a = BlockAllocator(PagingConfig(block_size=16, num_blocks=8))
    a.alloc(4)
    a.set_used_tokens(40)                   # 40 of 4*16=64 token capacity
    s = a.stats()
    assert s.used_blocks == 4 and s.free_blocks == 4
    assert s.utilization == pytest.approx(0.5)
    assert s.internal_fragmentation == pytest.approx(1 - 40 / 64)


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2


# ---------------------------------------------------------------------------
# Pallas paged-decode kernel (interpret mode) vs the dense contraction
# ---------------------------------------------------------------------------
def _reference(q, k_pool, v_pool, tables, lengths):
    B, h, hd = q.shape
    kv = k_pool.shape[2]
    T = tables.shape[1] * k_pool.shape[1]
    kg = k_pool[tables].reshape(B, T, kv, hd)
    vg = v_pool[tables].reshape(B, T, kv, hd)
    kf = jnp.repeat(kg, h // kv, axis=2)    # repeat_kv's head ordering
    vf = jnp.repeat(vg, h // kv, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q, kf) / math.sqrt(hd)
    live = (jnp.arange(T)[None] < lengths[:, None])[:, None]
    s = jnp.where(live, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vf)


@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2)])
def test_paged_kernel_matches_dense_path(h, kv):
    rng = np.random.RandomState(0)
    B, hd, bs, nblk = 3, 16, 8, 4
    NB = 1 + B * nblk
    q = jnp.asarray(rng.randn(B, h, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(NB, bs, kv, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(NB, bs, kv, hd), jnp.float32)
    # scattered, non-contiguous physical blocks
    tables = jnp.asarray(
        rng.permutation(np.arange(1, NB)).reshape(B, nblk), jnp.int32)
    lengths = jnp.asarray([5, 17, nblk * bs], jnp.int32)
    out = paged_decode_attention(q, kp, vp, tables, lengths, interpret=True)
    ref = _reference(q, kp, vp, tables, lengths)
    assert jnp.allclose(out, ref, atol=1e-5)


def test_paged_kernel_ignores_null_block_entries():
    """Table entries past the allocated blocks point at the null block;
    masked columns must contribute exactly zero even if block 0 holds
    garbage."""
    rng = np.random.RandomState(1)
    B, h, kv, hd, bs, nblk = 1, 4, 2, 16, 8, 4
    NB = 1 + nblk
    q = jnp.asarray(rng.randn(B, h, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(NB, bs, kv, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(NB, bs, kv, hd), jnp.float32)
    kp = kp.at[NULL_BLOCK].set(1e4)         # poison the null block
    vp = vp.at[NULL_BLOCK].set(1e4)
    tables = jnp.asarray([[1, 2, NULL_BLOCK, NULL_BLOCK]], jnp.int32)
    lengths = jnp.asarray([11], jnp.int32)
    out = paged_decode_attention(q, kp, vp, tables, lengths, interpret=True)
    ref = _reference(q, kp, vp, tables, lengths)
    assert jnp.allclose(out, ref, atol=1e-5)
    assert bool(jnp.all(jnp.abs(out) < 1e3))


# ---------------------------------------------------------------------------
# Model-level cache-layout interface
# ---------------------------------------------------------------------------
def test_init_cache_pool_shapes():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    paging = PagingConfig(block_size=8, num_blocks=12)
    cache = model.init_cache(4, 64, abstract=True, paging=paging)
    assert cache.k.shape == (cfg.num_layers, 13, 8, cfg.num_kv_heads,
                             cfg.resolved_head_dim)   # +1 null block row


def test_init_cache_paged_rejects_ssm():
    cfg = reduced_cfg("falcon-mamba-7b")
    with pytest.raises(ValueError, match="unsupported for family"):
        Model(cfg).init_cache(2, 64, paging=PagingConfig(8, 8))


def test_engine_rejects_paged_for_hybrid():
    cfg = reduced_cfg("recurrentgemma-2b")
    with pytest.raises(ValueError, match="unsupported for family"):
        ServingEngine(Model(cfg), max_batch=2, max_len=64,
                      cache_layout="paged")


def test_engine_rejects_misaligned_block_size():
    cfg = reduced_cfg("qwen1.5-0.5b")
    with pytest.raises(ValueError, match="must divide"):
        ServingEngine(Model(cfg), max_batch=2, max_len=64,
                      cache_layout="paged", block_size=24)


# ---------------------------------------------------------------------------
# Engine: block-budget admission, preemption, decode off-by-one
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _run(model, params, reqs, **engine_kw):
    eng = ServingEngine(model, sampling=SamplingParams(), **engine_kw)
    eng.load(params)
    uids = [eng.submit(*r) for r in reqs]
    done = {r.uid: r for r in eng.run_to_completion()}
    return eng, [done[u] for u in uids]


def test_preemption_resumes_bit_identical(qwen):
    """A pool that cannot sustain two full requests must preempt the
    younger one and still produce both greedy streams unchanged.  The
    pool holds exactly one max_len request (the legal minimum), so two
    in-flight requests always collide."""
    model, params = qwen
    reqs = [(list(range(1, 9)), 20), (list(range(9, 17)), 20)]
    _, ref = _run(model, params, reqs, max_batch=2, max_len=32)
    eng, got = _run(model, params, reqs, max_batch=2, max_len=32,
                    cache_layout="paged", block_size=8, num_blocks=4)
    assert eng.stats["preemptions"] > 0
    assert [r.generated for r in got] == [r.generated for r in ref]


def test_pool_below_max_len_rejected_at_construction(qwen):
    """A pool that could never admit a full-length request used to fail
    mid-flight ('pool exhausted' RuntimeError) or strand prompts at
    submit; the spec now rejects the geometry at construction, which
    makes both of those late failure paths unreachable (any single
    request fits the pool, so preemption always makes progress)."""
    model, _ = qwen
    with pytest.raises(ValueError, match="never be admitted"):
        ServingEngine(model, max_batch=2, max_len=64,
                      sampling=SamplingParams(), cache_layout="paged",
                      block_size=8, num_blocks=4)    # 32 tokens < 64
    from repro.core.spec import MemorySpec
    with pytest.raises(ValueError, match="num_blocks >= 8"):
        MemorySpec(cache_layout="paged", max_len=64, block_size=8,
                   num_blocks=1)


def test_decode_uses_final_cache_position(qwen):
    """Regression for the decode off-by-one: with an unbounded budget a
    prompt of length P must yield max_len - P + 1 tokens (the prefill
    sample plus one per remaining cache position, *including* position
    max_len - 1), in both layouts."""
    model, params = qwen
    for kw in ({}, {"cache_layout": "paged", "block_size": 8}):
        eng, (req,) = _run(model, params, [([1, 2, 3], 100)],
                           max_batch=2, max_len=32, **kw)
        assert len(req.generated) == 32 - 3 + 1, kw


def test_max_len_prompt_with_budget_one(qwen):
    """A max_len-length prompt is admissible when its single token comes
    from the prefill sample (the aligned submit guard)."""
    model, params = qwen
    eng, (req,) = _run(model, params, [(list(range(1, 33)), 1)],
                       max_batch=2, max_len=32)
    assert len(req.generated) == 1
    with pytest.raises(ValueError, match="max_new_tokens must be 1"):
        eng.submit(list(range(1, 33)), max_new_tokens=2)


def test_fragmentation_accounting(qwen):
    model, params = qwen
    eng = ServingEngine(model, max_batch=4, max_len=64,
                        sampling=SamplingParams(), cache_layout="paged",
                        block_size=16, num_blocks=16)
    eng.load(params)
    eng.submit([1, 2, 3], max_new_tokens=8)      # mid-flight after one step
    eng.step()
    s = eng.memory_stats()
    assert s.used_blocks >= 1
    assert 0.0 < s.internal_fragmentation < 1.0
    eng.run_to_completion()
    assert eng.memory_stats().used_blocks == 0   # harvest returned blocks


# ---------------------------------------------------------------------------
# Admission edges — all must stay on the single decode trace
# ---------------------------------------------------------------------------
def test_admission_edges_one_decode_trace(qwen):
    model, params = qwen
    eng = ServingEngine(model, max_batch=4, max_len=64,
                        sampling=SamplingParams(), cache_layout="paged",
                        block_size=8)
    eng.load(params)
    u_bucket = eng.submit(list(range(1, 33)), max_new_tokens=4)  # len == bucket 32
    u_budget1 = eng.submit([9, 8, 7], max_new_tokens=1)
    done = {r.uid: r for r in eng.run_to_completion()}
    assert len(done[u_bucket].generated) == 4
    assert len(done[u_budget1].generated) == 1
    # eos equal to the first prefill-sampled token must stop at one token
    first = done[u_budget1].generated[0]
    u_eos = eng.submit([9, 8, 7], max_new_tokens=50, eos_id=first)
    done2 = {r.uid: r for r in eng.run_to_completion()}
    assert done2[u_eos].generated == [first]
    assert eng.compilations["decode"] == 1


def test_per_request_sampling_no_retrace(qwen):
    """Mixing greedy / top-k / top-p requests in one batch must not add
    decode traces: the sampling knobs are device data, not constants."""
    model, params = qwen
    eng = ServingEngine(model, max_batch=4, max_len=64,
                        sampling=SamplingParams())
    eng.load(params)
    u_greedy = eng.submit([1, 2, 3], max_new_tokens=5)
    eng.submit([4, 5, 6], max_new_tokens=5,
               sampling=SamplingParams(temperature=0.8, top_k=3))
    eng.submit([7, 8, 9], max_new_tokens=5,
               sampling=SamplingParams(temperature=1.2, top_p=0.5))
    done = {r.uid: r for r in eng.run_to_completion()}
    assert all(len(r.generated) == 5 for r in done.values())
    assert eng.compilations["decode"] == 1
    # the greedy stream must equal a greedy-only run (row isolation)
    eng2 = ServingEngine(model, max_batch=4, max_len=64,
                         sampling=SamplingParams())
    eng2.load(params)
    u2 = eng2.submit([1, 2, 3], max_new_tokens=5)
    ref = {r.uid: r for r in eng2.run_to_completion()}
    assert done[u_greedy].generated == ref[u2].generated


def test_sample_per_slot_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 30.0],
                          [10.0, 9.0, -10.0, -10.0],
                          [1.0, 5.0, 2.0, 0.0]])
    temp = jnp.asarray([1.0, 1.0, 0.0], jnp.float32)
    top_k = jnp.asarray([2, 0, 0], jnp.int32)
    top_p = jnp.asarray([1.0, 0.9, 1.0], jnp.float32)
    for i in range(20):
        t = sample_per_slot(logits, jax.random.PRNGKey(i), temp, top_k, top_p)
        assert int(t[0]) in (2, 3)          # top-k row
        assert int(t[1]) in (0, 1)          # top-p row
        assert int(t[2]) == 1               # greedy row == argmax


# ---------------------------------------------------------------------------
# MLA paged layout
# ---------------------------------------------------------------------------
def test_mla_paged_matches_dense():
    cfg = reduced_cfg("deepseek-v3-671b", lossless_moe=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    streams = {}
    for layout in ("dense", "paged"):
        eng = ServingEngine(model, max_batch=2, max_len=64,
                            sampling=SamplingParams(), cache_layout=layout,
                            block_size=8)
        eng.load(params)
        uid = eng.submit([5, 6, 7], max_new_tokens=5)
        done = eng.run_to_completion()
        streams[layout] = next(r for r in done if r.uid == uid).generated
    assert streams["dense"] == streams["paged"]


# ---------------------------------------------------------------------------
# Refcounted blocks + the prefix trie (PR 7)
# ---------------------------------------------------------------------------
def test_allocator_refcounts():
    from repro.core.paging import PagingConfig
    a = BlockAllocator(PagingConfig(block_size=8, num_blocks=8))
    got = a.alloc(2)
    assert [a.ref(b) for b in got] == [1, 1]
    a.incref(got)                       # a second request maps the blocks
    assert a.decref(got) == []          # first release: nothing hits zero
    assert a.num_free == 6              # ...so nothing was freed
    zeros = a.decref(got)
    assert zeros == got                 # second release: both at zero
    a.free(zeros)
    assert a.num_free == 8
    with pytest.raises(ValueError, match="unreferenced"):
        a.decref(got)                   # blocks are free again
    b = a.alloc(1)
    a.incref(b)
    with pytest.raises(ValueError, match="still mapped"):
        a.free(b)                       # refcount 2: free is an error
    with pytest.raises(ValueError, match="incref of free"):
        a.incref([a._free[0]])


def test_allocator_free_set_stays_consistent():
    """The persistent free-set must mirror the free list through any
    interleaving of alloc/free (the O(1) double-free check)."""
    from repro.core.paging import PagingConfig
    a = BlockAllocator(PagingConfig(block_size=8, num_blocks=16))
    x, y = a.alloc(5), a.alloc(3)
    a.free(x[:2])
    z = a.alloc(4)
    a.free(x[2:] + y + z)
    assert a._free_set == set(a._free)
    assert a.num_free == 16
    with pytest.raises(ValueError, match="double free"):
        a.free([a._free[0]])


def test_prefix_trie_roundtrip_and_partial_match():
    from repro.core.paging import PagingConfig, PrefixCache
    a = BlockAllocator(PagingConfig(block_size=4, num_blocks=16))
    pc = PrefixCache(a)
    toks = list(range(10, 22))                 # 12 tokens = 3 full blocks
    blocks = a.alloc(3)
    assert pc.insert(0, toks, blocks) == 3
    # full-prefix hit, capped below the last token
    hit = pc.lookup(0, toks + [99], limit=12)
    assert hit.blocks == blocks and hit.tokens == 12
    # divergence inside block 2 -> partial (CoW fork) match
    div = toks[:6] + [77, 78, 79, 80]
    hit = pc.lookup(0, div, limit=len(div) - 1)
    assert hit.blocks == blocks[:1] and hit.tokens == 4
    assert hit.fork_block == blocks[1] and hit.fork_tokens == 2
    # a different namespace shares nothing
    assert pc.lookup(1, toks, limit=12).cached_tokens == 0


def test_prefix_trie_park_evict_lru():
    from repro.core.paging import PagingConfig, PrefixCache
    a = BlockAllocator(PagingConfig(block_size=4, num_blocks=16))
    pc = PrefixCache(a)
    b1 = a.alloc(2)
    pc.insert(0, [1, 2, 3, 4, 5, 6, 7, 8], b1)
    b2 = a.alloc(1)
    pc.insert(0, [9, 9, 9, 9], b2)
    # release both chains: trie-owned blocks park instead of freeing
    assert pc.park(a.decref(b1 + b2)) == []
    assert a.num_free == 13 and pc.num_parked == 3
    assert a.stats().cached_blocks == 3
    # oldest chain evicts first, leaf before parent, never a live block
    hit = pc.lookup(0, [9, 9, 9, 9, 0], limit=4)
    pc.acquire(hit)                            # pin the younger chain
    freed = pc.evict(3)
    assert freed == 2 and a.num_free == 15     # b1's two blocks only
    assert pc.lookup(0, [1, 2, 3, 4], limit=4).cached_tokens == 0
    assert pc.lookup(0, [9, 9, 9, 9, 0], limit=4).tokens == 4
    pc.release(hit)


def test_prefix_trie_insert_existing_node_wins():
    """Registering a duplicate chain must keep the original block; the
    caller's copy stays private (freed at its own release)."""
    from repro.core.paging import PagingConfig, PrefixCache
    a = BlockAllocator(PagingConfig(block_size=4, num_blocks=8))
    pc = PrefixCache(a)
    b1 = a.alloc(1)
    assert pc.insert(0, [5, 6, 7, 8], b1) == 1
    b2 = a.alloc(1)
    assert pc.insert(0, [5, 6, 7, 8], b2) == 0
    assert pc.lookup(0, [5, 6, 7, 8, 0], limit=4).blocks == b1
    assert not pc.owns(b2[0])


def _prefix_engine(params, *, prefix=True, max_batch=4, max_len=64,
                   block_size=8, num_blocks=None, kv_dtype="compute"):
    from repro.core.spec import MemorySpec, RuntimeSpec, SchedulerSpec
    cfg = reduced_cfg("qwen1.5-0.5b")
    spec = RuntimeSpec(
        arch=cfg,
        memory=MemorySpec(cache_layout="paged", max_batch=max_batch,
                          max_len=max_len, block_size=block_size,
                          num_blocks=num_blocks, kv_dtype=kv_dtype,
                          prefix_cache=prefix),
        scheduler=SchedulerSpec(policy="chunked", chunk_size=block_size))
    eng = ServingEngine(spec, sampling=SamplingParams())
    eng.load(params)
    return eng


@pytest.mark.parametrize("kv_dtype", ["compute", "int8"])
def test_prefix_sharing_bit_identical_streams(qwen, kv_dtype):
    """Cache-hit requests (full-block hits and a CoW fork) must stream
    exactly what the sharing-off engine streams, in both cache codecs,
    on one decode compilation."""
    _, params = qwen
    shared = list(range(1, 25))                # 3 full 8-token blocks
    waves = [[(shared + [30], 4)],
             [(shared + [40, 41], 4),          # full-block hit
              (shared[:20] + [99, 98], 4),     # CoW fork mid-block 3
              ([70, 71], 4)]]                  # unrelated miss
    streams = {}
    for prefix in (False, True):
        eng = _prefix_engine(params, prefix=prefix, kv_dtype=kv_dtype)
        outs = []
        for wave in waves:
            uids = [eng.submit(p, max_new_tokens=b) for p, b in wave]
            done = {r.uid: r.generated for r in eng.run_to_completion()}
            outs += [done[u] for u in uids]       # submission order
        streams[prefix] = outs
        assert eng.compilations["decode"] == 1
        if prefix:
            assert eng.stats["prefix_hits"] == 2
            assert eng.stats["cow_forks"] == 1
            s = eng.memory_stats()
            assert s.cached_blocks == 3        # parked after the drain
    assert streams[True] == streams[False]


def test_prefix_sharing_shared_block_accounting(qwen):
    """Concurrent holders of one prefix: the pool charges the shared
    blocks once and FragmentationStats reports them as shared."""
    _, params = qwen
    eng = _prefix_engine(params, max_batch=4, max_len=64, block_size=8)
    shared = list(range(1, 17))                # 2 full blocks
    eng.submit(shared + [5], max_new_tokens=2)
    eng.run_to_completion()                    # register the chain
    eng.submit(shared + [6], max_new_tokens=30)
    eng.submit(shared + [7], max_new_tokens=30)
    eng.step()
    s = eng.memory_stats()
    assert s.shared_blocks == 2                # both map the 2-block chain
    assert eng.allocator.ref(eng._slot_blocks[0][0]) == 2
    # physical residency: 2 shared + one private tail block each
    assert s.used_blocks < sum(len(b) for b in eng._slot_blocks)
    eng.run_to_completion()
    assert eng.memory_stats().shared_blocks == 0


def test_prefix_mid_prefill_preemption_rehits_trie(qwen):
    """Satellite: preempting a request mid-prefill while it HOLDS shared
    blocks must decref (never double-free), and its re-admission must
    re-hit the trie and stream bit-identically."""
    _, params = qwen
    shared = list(range(1, 17))                # 2 full 8-token blocks
    # A fills block 3 exactly, so its FIRST decode token needs a fourth
    # block; B's 44-token uncached suffix keeps it prefilling for many
    # steps.  The pool (9 blocks) is dry by then, nothing is parked
    # (both chain blocks are mapped), so A's growth preempts B —
    # youngest — mid-prefill while B holds the shared chain.
    reqs = [(shared + list(range(40, 48)), 8),
            (shared + list(range(50, 94)), 4)]
    streams = {}
    for prefix in (False, True):
        eng = _prefix_engine(params, prefix=prefix, max_batch=2,
                             max_len=64, block_size=8, num_blocks=9)
        if prefix:
            eng.submit(shared + [9], max_new_tokens=2)
            eng.run_to_completion()            # warm: register the chain
        uids = [eng.submit(p, max_new_tokens=b) for p, b in reqs]
        done = {r.uid: r.generated for r in eng.run_to_completion()}
        streams[prefix] = [done[u] for u in uids]
        if prefix:
            assert eng.stats["preemptions"] >= 1
            # A, B, and B's re-admission all hit the registered chain
            assert eng.stats["prefix_hits"] >= 3
            assert eng.memory_stats().used_blocks == eng.memory_stats() \
                .cached_blocks   # drained: only parked blocks resident
    assert streams[True] == streams[False]


# ---------------------------------------------------------------------------
# Speculative rollback: decref-aware block-tail truncate (PR 10)
# ---------------------------------------------------------------------------
def test_allocator_truncate_decref_aware():
    a = BlockAllocator(PagingConfig(block_size=8, num_blocks=8))
    got = a.alloc(4)
    kept, zeros = a.truncate(got, 2)
    assert kept == got[:2] and zeros == got[2:]
    a.free(zeros)
    assert a.num_free == 6
    with pytest.raises(ValueError, match="cannot keep"):
        a.truncate(got[:2], -1)
    kept, zeros = a.truncate(got[:2], 5)       # keep >= len: no-op
    assert kept == got[:2] and zeros == []


def test_rollback_while_shared_parks_trie_blocks():
    """Regression: a speculative rollback that truncates a slot's block
    tail while another request (or the trie) still holds the blocks must
    decref — never free.  Trie-owned blocks whose refcount hits zero
    park (stay resident for future prefix hits); only unowned remainders
    reach the free list."""
    from repro.core.paging import PrefixCache
    a = BlockAllocator(PagingConfig(block_size=4, num_blocks=8))
    pc = PrefixCache(a)
    chain = a.alloc(2)                     # slot A's blocks, registered
    pc.insert(0, list(range(1, 9)), chain)
    a.incref(chain)                        # slot B maps the same chain
    # slot A rewinds past block 2: refcount 2 -> 1, the block stays
    # mapped for B and must not surface in the zero list
    kept, zeros = a.truncate(chain, 1)
    assert kept == chain[:1] and zeros == []
    assert a.ref(chain[1]) == 1
    # slot B rewinds too: refcount hits zero, but the trie owns the
    # block — it parks instead of freeing
    kept, zeros = a.truncate(chain, 1)
    assert zeros == chain[1:]
    assert pc.park(zeros) == []            # trie-owned: parked, not freed
    assert pc.num_parked == 1
    assert chain[1] not in a._free
    assert a.stats().cached_blocks == 1
    # the parked tail is still a live prefix hit for future requests
    hit = pc.lookup(0, list(range(1, 9)) + [0], limit=8)
    assert hit.tokens == 8 and hit.blocks == chain


def test_prefix_cache_requires_paged_layout():
    from repro.core.spec import MemorySpec
    with pytest.raises(ValueError, match="requires cache_layout='paged'"):
        MemorySpec(cache_layout="dense", prefix_cache=True)
