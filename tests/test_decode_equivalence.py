"""The serving gold invariant: prefill(prompt) + token-by-token decode
must reproduce the full-sequence forward, for every cached family."""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg
from repro.models.model import Model

FAMS = ["qwen1.5-0.5b", "granite-moe-1b-a400m", "deepseek-v3-671b",
        "falcon-mamba-7b", "recurrentgemma-2b", "whisper-medium",
        "phi-3-vision-4.2b"]

# absorbed-MLA decode is a different (more accurate) contraction order;
# bf16 rounding differs from the naive prefill path by ~1%
TOL = {"deepseek-v3-671b": 5e-2}


def _inputs(cfg, B=2, S=10):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encdec is not None:
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (B, cfg.encdec.encoder_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    elif cfg.frontend is not None:
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (B, cfg.frontend.num_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return batch


# default tier-1 runs a reduced sweep (fast cache families, 2 decode
# steps); the full 7-family x 5-step sweep runs under ``-m slow``
FAST_FAMS = ["qwen1.5-0.5b", "falcon-mamba-7b", "granite-moe-1b-a400m",
             "recurrentgemma-2b"]


def _check_prefill_then_decode(name: str, steps: int) -> None:
    cfg = reduced_cfg(name, lossless_moe=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 2, 5
    if cfg.frontend is not None and cfg.encdec is None:
        # vlm: the prompt must cover the patch-embedding positions
        P = max(P, cfg.frontend.num_tokens)
    S = P + steps
    batch = _inputs(cfg, B, S)
    full = model.forward(params, batch)
    scale = float(jnp.abs(full).max()) + 1e-6

    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :P]
    logits, cache = model.prefill(params, pb, max_len=S)
    tol = TOL.get(name, 2e-2) * scale
    assert float(jnp.max(jnp.abs(logits - full[:, :P]))) < tol
    for t in range(P, S):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < tol, f"{name} step {t}: {err} vs {tol}"


@pytest.mark.parametrize("name", FAST_FAMS)
def test_prefill_then_decode_matches_forward(name):
    _check_prefill_then_decode(name, steps=2)


@pytest.mark.slow
@pytest.mark.parametrize("name", FAMS)
def test_prefill_then_decode_matches_forward_full(name):
    _check_prefill_then_decode(name, steps=5)


def test_per_slot_vector_indices():
    """Decode with a [B] index vector at different depths must equal two
    independent single-sequence decodes."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0,
                              cfg.vocab_size)
    # sequence 0 prefilled to 6, sequence 1 prefilled to 3
    _, c0 = model.prefill(params, {"tokens": toks[:1, :6]}, max_len=S)
    _, c1 = model.prefill(params, {"tokens": toks[1:, :3]}, max_len=S)
    cache = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1), c0, c1)
    step_toks = jnp.stack([toks[0, 6:7], toks[1, 3:4]])
    lg, _ = model.decode_step(params, cache, step_toks,
                              jnp.array([6, 3], jnp.int32))
    full = model.forward(params, {"tokens": toks})
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(lg[0, 0] - full[0, 6]).max()) < 2e-2 * scale
    assert float(jnp.abs(lg[1, 0] - full[1, 3]).max()) < 2e-2 * scale


def test_rolling_window_longer_than_buffer():
    """Hybrid local attention: decode past the window must match forward
    (the rolling buffer drops exactly the out-of-window tokens)."""
    cfg = reduced_cfg("recurrentgemma-2b")  # window 32 in reduced
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, S), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, S)
    errs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[0, 0] - full[0, t]))))
    scale = float(jnp.abs(full).max())
    assert max(errs) < 2e-2 * scale
