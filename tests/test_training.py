"""Training substrate: convergence, checkpoint/restart, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.models.model import Model
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import (ClusterMonitor, TrainController,
                                            plan_remesh)
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      clip_by_global_norm, cosine_lr)
from repro.training.train_step import (TrainStepConfig, init_state,
                                       make_step_fn)


def _fixed_batch(cfg, B=4, S=32, key=7):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S + 1), 0,
                              cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_memorization_converges():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    oc = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=80,
                     weight_decay=0.0)
    state = init_state(model, jax.random.PRNGKey(0), oc)
    step = jax.jit(make_step_fn(model, TrainStepConfig(optimizer=oc)))
    batch = _fixed_batch(cfg)
    losses = []
    for _ in range(60):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], (losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_grad_accumulation_changes_little():
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    s0 = init_state(model, jax.random.PRNGKey(0), oc)
    batch = _fixed_batch(cfg, B=4)
    s1, m1 = jax.jit(make_step_fn(model, TrainStepConfig(optimizer=oc)))(
        s0, batch)
    s2, m2 = jax.jit(make_step_fn(
        model, TrainStepConfig(optimizer=oc, accum_steps=2)))(s0, batch)
    # same data, same update direction: losses match, params close
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(s1.params),
                            jax.tree.leaves(s2.params)))
    assert d < 5e-2


def test_cosine_schedule_and_clip():
    oc = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                     min_lr_ratio=0.1)
    assert float(cosine_lr(jnp.int32(0), oc)) == 0.0
    assert abs(float(cosine_lr(jnp.int32(10), oc)) - 1.0) < 1e-6
    assert float(cosine_lr(jnp.int32(100), oc)) == pytest.approx(0.1, 1e-3)
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), 1e-4)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, 1e-4)


def test_adamw_decays_only_matrices():
    params = {"w": jnp.ones((8, 8)), "bias": jnp.ones((8,))}
    grads = jax.tree.map(jnp.zeros_like, params)
    oc = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0, total_steps=10)
    st = adamw_init(params, oc)
    p2, _, _ = adamw_update(params, grads, st, oc)
    assert float(p2["w"][0, 0]) < 1.0          # decayed
    assert float(p2["bias"][0]) == 1.0         # not decayed


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = reduced_cfg("granite-moe-1b-a400m")
    model = Model(cfg)
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    state = init_state(model, jax.random.PRNGKey(0), oc)
    step = jax.jit(make_step_fn(model, TrainStepConfig(optimizer=oc)))
    batch = _fixed_batch(cfg)
    for _ in range(3):
        state, _ = step(state, batch)
    ckpt.save(str(tmp_path), 3, state, meta={"data_state": {"step": 3}})
    got, meta = ckpt.restore_latest(str(tmp_path), state)
    assert meta["step"] == 3 and meta["data_state"]["step"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues identically after restore
    s_direct, m_direct = step(state, batch)
    s_restored, m_restored = step(got, batch)
    assert float(m_direct["loss"]) == pytest.approx(
        float(m_restored["loss"]), abs=1e-6)


def test_checkpoint_atomicity(tmp_path):
    state = {"x": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 1, state)
    # a partial (uncommitted) later step must be invisible
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_gc(tmp_path):
    state = {"x": jnp.ones((2,))}
    for s in range(1, 6):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


# ---------------------------------------------------------------------------
# Fault tolerance / elasticity
# ---------------------------------------------------------------------------
def test_heartbeat_failure_triggers_remesh():
    mon = ClusterMonitor(n_hosts=8, heartbeat_timeout=30.0)
    ctl = TrainController(mon, mesh_shape=(2, 16, 16),
                          axis_names=("pod", "data", "model"),
                          devices_per_host=4)
    for h in range(8):
        mon.heartbeat(h, now=0.0)
    ctl.on_checkpoint(1200)
    for h in range(7):
        mon.heartbeat(h, now=40.0)  # host 7 silent
    plan = ctl.poll(now=65.0)   # hosts 0-6 fresh (25s), host 7 stale (65s)
    assert plan is not None and plan.dropped_hosts == (7,)
    assert plan.restore_step == 1200
    # model axis preserved; data capacity shrunk to fit survivors
    assert plan.new_mesh[2] == 16
    assert plan.new_device_count <= 512 - 4


def test_straggler_detection_and_eviction():
    mon = ClusterMonitor(n_hosts=4, straggler_factor=2.0, min_samples=3)
    for h in range(4):
        mon.heartbeat(h, 0.0)
        for _ in range(5):
            mon.record_step(h, 1.0 if h != 2 else 5.0)
    assert mon.stragglers() == [2]
    ctl = TrainController(mon, mesh_shape=(16, 16),
                          axis_names=("data", "model"), devices_per_host=8)
    plan = ctl.poll(now=1.0)
    assert plan is not None and plan.reason == "straggler eviction"
    assert plan.new_mesh[1] == 16  # model axis intact


def test_remesh_never_kills_model_axis():
    plan = plan_remesh((2, 16, 16), ("pod", "data", "model"),
                       devices_per_host=8, failed_hosts=[0, 1, 2],
                       last_checkpoint_step=10)
    assert plan.new_mesh[2] == 16
    assert plan.new_device_count <= 512 - 24


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Checkpoint -> restore with different shardings (re-shard on load)."""
    cfg = reduced_cfg("qwen1.5-0.5b")
    model = Model(cfg)
    oc = AdamWConfig()
    state = init_state(model, jax.random.PRNGKey(0), oc)
    ckpt.save(str(tmp_path), 1, state)
    got, _ = ckpt.restore_latest(str(tmp_path), state)  # CPU: same device
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(got.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
