"""Chunked prefill fused into the decode step.

The acceptance bar for the token-budget scheduler:

* greedy streams bit-identical to the bucketed baseline — dense, paged
  and fleet modes — with prefill compilations == 1 and decode == 1 after
  a mixed-length workload (sampled streams draw the same distributions
  on a different rng schedule),
* the per-step prompt-token total never exceeds ``token_budget`` and a
  short request's first token never waits for a long prompt's prefill,
* a slot preempted mid-prompt re-enters through the chunk scheduler and
  its stream stays bit-identical to an unpreempted run,
* the fused step donates the cache and SlotState buffers (no copy),
* the chunked-prefill Pallas kernel matches the gather reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.spec import (ExecutionSpec, MemorySpec, RuntimeSpec,
                             SchedulerSpec, maxima_for)
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams

CFG = reduced_cfg("qwen1.5-0.5b")
CFG_B = dataclasses.replace(
    CFG, name="fleet-member-b", num_layers=1, d_model=48,
    num_heads=3, num_kv_heads=3, d_ff=96, vocab_size=96)

PROMPTS = [[1, 2, 3], list(range(1, 9)), [4], list(range(2, 40, 3)),
           [7, 7, 7, 7, 7], list(range(1, 20))]


@pytest.fixture(scope="module")
def params():
    return Model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def params_b():
    return Model(CFG_B).init(jax.random.PRNGKey(1))


def _engine(params, policy="auto", cache_layout="dense", maxima=None,
            max_batch=4, max_len=64, execution=None, **sched_kw):
    spec = RuntimeSpec(
        arch=CFG, maxima=maxima,
        execution=execution or ExecutionSpec(),
        memory=MemorySpec(cache_layout=cache_layout, max_batch=max_batch,
                          max_len=max_len, block_size=8),
        scheduler=SchedulerSpec(policy=policy, **sched_kw))
    eng = ServingEngine(spec, sampling=SamplingParams(),
                        **({"max_models": 2} if maxima is not None else {}))
    eng.load(params)
    return eng


def _drain(eng, prompts=PROMPTS, max_new=5, **submit_kw):
    uids = {eng.submit(p, max_new_tokens=max_new, **submit_kw): tuple(p)
            for p in prompts}
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    return {uids[r.uid]: r.generated for r in done}


# ---------------------------------------------------------------------------
# The headline claim: O(1) compilations, streams == bucketed baseline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cache_layout", ["dense", "paged"])
def test_chunked_matches_bucketed(params, cache_layout):
    bucketed = _drain(_engine(params, "bucketed", cache_layout))
    eng = _engine(params, "chunked", cache_layout)
    chunked = _drain(eng)
    assert chunked == bucketed
    comp = eng.compilations()
    assert comp["prefill"] == 1 and comp["decode"] == 1
    assert comp["prefill_buckets"] == 0


def test_steady_state_uses_one_lane_decode(params):
    """Once no slot carries prompt work, the engine must dispatch the
    W == 1 fused decode (no chunk-lane overhead), still one compilation
    per program and one dispatch per step."""
    eng = _engine(params, "chunked")
    eng.submit([1, 2, 3], max_new_tokens=6)
    done = eng.run_to_completion()
    assert len(done[0].generated) == 6
    assert eng._step._cache_size() == 1      # the mixed step
    assert eng._decode._cache_size() == 1    # the steady-state decode
    comp = eng.compilations()
    assert comp["prefill"] == 1 and comp["decode"] == 1


def test_auto_policy_defaults_to_chunked(params):
    eng = _engine(params, "auto")
    assert eng.scheduler == "chunked"
    _drain(eng, prompts=PROMPTS[:2])
    assert eng.compilations["prefill"] == 1


def test_fleet_chunked_matches_bucketed(params, params_b):
    maxima = maxima_for(CFG, CFG_B, seq_max=64)
    for cache_layout in ("dense", "paged"):
        streams = {}
        for policy in ("bucketed", "chunked"):
            eng = _engine(params, policy, cache_layout, maxima=maxima)
            b_id = eng.add_model(params_b, CFG_B)
            uids = {eng.submit(p, max_new_tokens=5): ("a", tuple(p))
                    for p in PROMPTS[:3]}
            uids.update({eng.submit(p, max_new_tokens=5, model=b_id):
                         ("b", tuple(p)) for p in ([4, 5], [6, 7, 8])})
            done = eng.run_to_completion()
            assert len(done) == 5
            streams[policy] = {uids[r.uid]: r.generated for r in done}
            if policy == "chunked":
                comp = eng.compilations()
                assert comp["decode"] == 1 and comp["prefill"] == 1
        assert streams["chunked"] == streams["bucketed"], cache_layout


# ---------------------------------------------------------------------------
# Token budget + head-of-line behavior
# ---------------------------------------------------------------------------
def test_token_budget_bounds_per_step_prefill_tokens(params):
    eng = _engine(params, "chunked", chunk_size=8, token_budget=8)
    bucketed = _drain(_engine(params, "bucketed"))
    assert _drain(eng) == bucketed          # throttling never changes math
    assert eng.stats["max_step_prefill_tokens"] <= 8


def test_short_request_not_blocked_by_long_prompt(params):
    """A short prompt submitted after a long one must finish its whole
    stream while the long prompt is still prefilling — head-of-line
    blocking is what the bucketed path could not avoid."""
    eng = _engine(params, "chunked", chunk_size=8, token_budget=16)
    long_uid = eng.submit(list(range(1, 49)), max_new_tokens=4)
    short_uid = eng.submit([9, 8, 7], max_new_tokens=3)
    finished = []
    for _ in range(200):
        finished += eng.step()
        if any(r.uid == short_uid for r in finished):
            break
    assert any(r.uid == short_uid for r in finished)
    long_slot = next(s for s, r in enumerate(eng.slot_req)
                     if r is not None and r.uid == long_uid)
    assert eng._pf[long_slot] < 48          # long prompt still mid-prefill
    done = finished + eng.run_to_completion()
    assert {r.uid for r in done} == {long_uid, short_uid}


# ---------------------------------------------------------------------------
# Preemption x chunked prefill
# ---------------------------------------------------------------------------
def _ab_workload(eng):
    ua = eng.submit(list(range(1, 8)), max_new_tokens=6)    # 7 tokens, grows
    ub = eng.submit(list(range(10, 34)), max_new_tokens=4)  # 24 tokens
    done = {r.uid: r.generated for r in eng.run_to_completion()}
    return done[ua], done[ub]


def _tight_engine(params, maxima=None):
    spec = RuntimeSpec(
        arch=CFG, maxima=maxima,
        memory=MemorySpec(cache_layout="paged", max_batch=2, max_len=32,
                          block_size=8, num_blocks=4),
        scheduler=SchedulerSpec(policy="chunked", chunk_size=8))
    eng = ServingEngine(spec, sampling=SamplingParams(),
                        **({"max_models": 2} if maxima is not None else {}))
    eng.load(params)
    return eng


def test_mid_prefill_preemption_paged_bit_identical(params):
    """A pool of exactly 4 blocks seats A (1 block) + B (3 blocks); A's
    decode growth runs the pool dry while B is still mid-prompt, so B is
    preempted *before it ever produced a token* and must re-enter through
    the chunk scheduler — with both streams unchanged."""
    ref = _ab_workload(_engine(params, "chunked", "paged", max_batch=2,
                               max_len=32, chunk_size=8))
    eng = _tight_engine(params)
    got = _ab_workload(eng)
    assert eng.stats["preemptions"] > 0
    assert got == ref


def test_mid_prefill_preemption_fleet(params, params_b):
    maxima = maxima_for(CFG, CFG_B, seq_max=32)
    ref_eng = _engine(params, "chunked", "paged", maxima=maxima,
                      max_batch=2, max_len=32, chunk_size=8)
    ref = _ab_workload(ref_eng)
    eng = _tight_engine(params, maxima=maxima)
    got = _ab_workload(eng)
    assert eng.stats["preemptions"] > 0
    assert got == ref


def test_mid_prefill_preemption_dense_forced(params):
    """Dense layout has no organic preemption trigger; force one mid-chunk
    and check the stream is unchanged (single-request runs, so greedy
    recompute-resume must be exact)."""
    clean = _engine(params, "chunked", chunk_size=8)
    uid = clean.submit(list(range(10, 34)), max_new_tokens=4)
    want = {r.uid: r.generated for r in clean.run_to_completion()}[uid]

    eng = _engine(params, "chunked", chunk_size=8)
    uid2 = eng.submit(list(range(10, 34)), max_new_tokens=4)
    eng.step()                                   # one 8-token chunk in
    slot = next(s for s, r in enumerate(eng.slot_req)
                if r is not None and r.uid == uid2)
    assert 0 < eng._pf[slot] < 24                # genuinely mid-prefill
    eng._preempt(slot)
    assert eng.stats["preemptions"] == 1
    done = {r.uid: r.generated for r in eng.run_to_completion()}
    assert done[uid2] == want


# ---------------------------------------------------------------------------
# Donation: the fused step updates cache + SlotState in place
# ---------------------------------------------------------------------------
def _platform_donates() -> bool:
    x = jnp.arange(16, dtype=jnp.float32)
    f = jax.jit(lambda a: a + 1.0, donate_argnums=(0,))
    p = x.unsafe_buffer_pointer()
    return f(x).unsafe_buffer_pointer() == p


def test_fused_step_donates_cache_and_state(params):
    if not _platform_donates():
        pytest.skip("backend does not alias donated buffers")
    eng = _engine(params, "chunked")
    eng.submit([1, 2, 3], max_new_tokens=10)
    eng.step()   # admission + compile
    eng.step()
    ptrs = (eng.cache.k.unsafe_buffer_pointer(),
            eng.cache.v.unsafe_buffer_pointer(),
            eng.state.buf.unsafe_buffer_pointer(),
            eng.state.prompt_buf.unsafe_buffer_pointer())
    eng.step()   # steady state: no admissions, pure fused step
    assert (eng.cache.k.unsafe_buffer_pointer(),
            eng.cache.v.unsafe_buffer_pointer(),
            eng.state.buf.unsafe_buffer_pointer(),
            eng.state.prompt_buf.unsafe_buffer_pointer()) == ptrs


# ---------------------------------------------------------------------------
# SchedulerSpec validation + fallback
# ---------------------------------------------------------------------------
def test_scheduler_spec_validation():
    with pytest.raises(ValueError, match="policy"):
        SchedulerSpec(policy="eager")
    with pytest.raises(ValueError, match="token_budget"):
        SchedulerSpec(chunk_size=32, token_budget=16)
    with pytest.raises(ValueError, match="chunk_size"):
        SchedulerSpec(chunk_size=0)
    # block-geometry validation: explicit chunked must be satisfiable
    with pytest.raises(ValueError, match="block-aligned"):
        RuntimeSpec(arch=CFG,
                    memory=MemorySpec(cache_layout="paged", max_len=64,
                                      block_size=16),
                    scheduler=SchedulerSpec(policy="chunked", chunk_size=8))
    with pytest.raises(ValueError, match="sequential prefill"):
        RuntimeSpec(arch=reduced_cfg("falcon-mamba-7b"),
                    scheduler=SchedulerSpec(policy="chunked"))


def test_auto_falls_back_for_unchunkable(params):
    # ssm family: sequential prefill state -> bucketed
    cfg = reduced_cfg("falcon-mamba-7b")
    model = Model(cfg)
    eng = ServingEngine(RuntimeSpec(
        arch=cfg, memory=MemorySpec(max_batch=2, max_len=32)),
        sampling=SamplingParams())
    assert eng.scheduler == "bucketed"
    eng.load(model.init(jax.random.PRNGKey(0)))
    uid = eng.submit([1, 2, 3], max_new_tokens=3)
    done = eng.run_to_completion()
    assert len(next(r for r in done if r.uid == uid).generated) == 3
    # chunk/block misalignment -> bucketed under auto
    eng2 = _engine(params, "auto", "paged", max_len=64, chunk_size=12)
    assert eng2.scheduler == "bucketed"


# ---------------------------------------------------------------------------
# MLA + the Pallas chunk kernel
# ---------------------------------------------------------------------------
def test_mla_chunked_dense_matches_paged():
    cfg = reduced_cfg("deepseek-v3-671b", lossless_moe=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    streams = {}
    for layout in ("dense", "paged"):
        spec = RuntimeSpec(arch=cfg,
                           memory=MemorySpec(cache_layout=layout,
                                             max_batch=2, max_len=64,
                                             block_size=8),
                           scheduler=SchedulerSpec(policy="chunked",
                                                   chunk_size=8))
        eng = ServingEngine(spec, sampling=SamplingParams())
        eng.load(params)
        uid = eng.submit(list(range(5, 25)), max_new_tokens=5)
        done = eng.run_to_completion()
        streams[layout] = next(r for r in done if r.uid == uid).generated
        assert eng.compilations["prefill"] == 1
    assert streams["dense"] == streams["paged"]


def test_pallas_chunked_paged_smoke(params):
    eng = _engine(params, "chunked", "paged",
                  execution=ExecutionSpec(paged_attn_impl="pallas"),
                  max_batch=2, chunk_size=8)
    out = _drain(eng, prompts=[[1, 2, 3], list(range(1, 14))], max_new=4)
    assert all(len(v) == 4 for v in out.values())
    assert all(0 <= t < CFG.vocab_size for v in out.values() for t in v)
    assert eng.compilations["decode"] == 1


def test_chunked_kernel_matches_gather_reference():
    from repro.kernels.chunked_prefill import chunked_prefill_attention
    B, W, h, kv, hd = 3, 4, 8, 2, 16
    bs, nblk, NB = 8, 8, 12
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, W, h, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (NB, bs, kv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (NB, bs, kv, hd), jnp.float32)
    bt = jax.random.randint(ks[3], (B, nblk), 1, NB).astype(jnp.int32)
    start = jnp.asarray([5, 0, 20], jnp.int32)
    out = chunked_prefill_attention(q, kp, vp, bt, start, interpret=True)

    t_max = nblk * bs
    n_rep = h // kv
    kf = jnp.repeat(kp[bt].reshape(B, t_max, kv, hd), n_rep, axis=2)
    vf = jnp.repeat(vp[bt].reshape(B, t_max, kv, hd), n_rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(hd)
    qpos = start[:, None] + jnp.arange(W)[None]
    mask = jnp.arange(t_max)[None, None, :] <= qpos[:, :, None]
    s = jnp.where(mask[:, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)
    # fleet head-lane masking: dead KV-head groups are exact zeros
    live = jnp.asarray([1, 2, 2], jnp.int32)
    out2 = chunked_prefill_attention(q, kp, vp, bt, start, live_kv=live,
                                     interpret=True)
    assert bool(jnp.all(out2[0, :, n_rep:] == 0.0))
    np.testing.assert_array_equal(np.asarray(out2[0, :, :n_rep]),
                                  np.asarray(out[0, :, :n_rep]))


# ---------------------------------------------------------------------------
# Prefix cache x chunked prefill (PR 7)
# ---------------------------------------------------------------------------
def _pfx_engine(params, maxima=None, *, prefix=True, max_batch=4,
                max_len=64, num_blocks=None):
    spec = RuntimeSpec(
        arch=CFG, maxima=maxima,
        memory=MemorySpec(cache_layout="paged", max_batch=max_batch,
                          max_len=max_len, block_size=8,
                          num_blocks=num_blocks, prefix_cache=prefix),
        scheduler=SchedulerSpec(policy="chunked", chunk_size=8))
    eng = ServingEngine(spec, sampling=SamplingParams(),
                        **({"max_models": 2} if maxima is not None else {}))
    eng.load(params)
    return eng


def test_prefix_hit_charges_budget_only_for_uncached_suffix(params):
    """A 33-token prompt whose first 32 tokens are cached must prefill
    in ONE chunk step (1 remaining token), where a cold engine needs
    ceil(33/8) grants — and the resumed slot starts at the cached span."""
    shared = list(range(1, 33))                  # 4 full blocks
    eng = _pfx_engine(params)
    eng.submit(shared + [40], max_new_tokens=2)
    eng.run_to_completion()                      # warm + register
    uid = eng.submit(shared + [41], max_new_tokens=4)
    eng.step()
    slot = next(s for s, r in enumerate(eng.slot_req)
                if r is not None and r.uid == uid)
    assert eng._pf[slot] == 33                   # 32 cached + 1 granted
    assert eng.stats["prefix_hit_tokens"] >= 32
    done = eng.run_to_completion()
    assert [r.uid for r in done] == [uid]


def test_prefix_forced_preemption_while_holding_shared_blocks(params):
    """Force-preempt a request mid-prefill while its block table maps
    the registered chain: release must decref (not double-free), the
    chain must survive for the re-admission to re-hit, and the stream
    must match a never-preempted engine."""
    shared = list(range(1, 17))                  # 2 full blocks
    prompt = shared + list(range(40, 64))        # + 24 uncached tokens

    clean = _pfx_engine(params)
    clean.submit(shared + [9], max_new_tokens=2)
    clean.run_to_completion()
    uid = clean.submit(prompt, max_new_tokens=4)
    want = {r.uid: r.generated for r in clean.run_to_completion()}[uid]

    eng = _pfx_engine(params)
    eng.submit(shared + [9], max_new_tokens=2)
    eng.run_to_completion()
    uid2 = eng.submit(prompt, max_new_tokens=4)
    eng.step()                                   # resumes at pf=16, +8
    slot = next(s for s, r in enumerate(eng.slot_req)
                if r is not None and r.uid == uid2)
    assert 16 < eng._pf[slot] < len(prompt)      # genuinely mid-prefill
    assert eng.allocator.ref(eng._slot_blocks[slot][0]) == 1  # chain held
    hits_before = eng.stats["prefix_hits"]
    eng._preempt(slot)                           # decref path, no free
    assert eng.stats["preemptions"] == 1
    done = {r.uid: r.generated for r in eng.run_to_completion()}
    assert eng.stats["prefix_hits"] == hits_before + 1   # re-hit on re-admit
    assert done[uid2] == want


def test_prefix_fleet_namespaces_isolate_models(params, params_b):
    """Identical token ids under different models must NOT share blocks:
    the trie is namespaced per (fleet, model, arch).  Same-model repeats
    still hit."""
    maxima = maxima_for(CFG, CFG_B, seq_max=64)
    eng = _pfx_engine(params, maxima=maxima)
    mb = eng.add_model(params_b, CFG_B)
    shared = list(range(1, 17))
    eng.submit(shared + [7], max_new_tokens=2, model=0)
    eng.run_to_completion()                      # registers under model 0
    eng.submit(shared + [8], max_new_tokens=2, model=mb)
    eng.run_to_completion()
    assert eng.stats["prefix_hits"] == 0         # cross-model: no sharing
    eng.submit(shared + [8], max_new_tokens=2, model=0)
    eng.submit(shared + [9], max_new_tokens=2, model=mb)
    done = eng.run_to_completion()
    assert len(done) == 2
    assert eng.stats["prefix_hits"] == 2         # each namespace hits itself
    # streams must equal a fleet engine with sharing off
    ref = _pfx_engine(params, maxima=maxima, prefix=False)
    ref.add_model(params_b, CFG_B)
    for m in (0, mb):
        ua = eng.submit(shared + [5, 6], max_new_tokens=3, model=m)
        ub = ref.submit(shared + [5, 6], max_new_tokens=3, model=m)
        ga = {r.uid: r.generated for r in eng.run_to_completion()}[ua]
        gb = {r.uid: r.generated for r in ref.run_to_completion()}[ub]
        assert ga == gb
