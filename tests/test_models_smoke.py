"""Per-arch smoke tests: a reduced same-family config runs one forward +
one train step on CPU; output shapes correct, no NaNs.  Covers all 10
assigned architectures plus the paper's own three networks."""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg
from repro.configs import ASSIGNED, PAPER_NETWORKS
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainStepConfig, init_state,
                                       make_step_fn)

ALL_NAMES = [c.name for c in ASSIGNED] + [c.name for c in PAPER_NETWORKS]


def _batch(cfg, B=2, S=16, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.encdec is not None:
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (B, cfg.encdec.encoder_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    elif cfg.frontend is not None:
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (B, cfg.frontend.num_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ALL_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = reduced_cfg(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", ALL_NAMES)
def test_train_step_finite(name):
    cfg = reduced_cfg(name)
    model = Model(cfg)
    oc = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_state(model, jax.random.PRNGKey(0), oc)
    step = jax.jit(make_step_fn(model, TrainStepConfig(optimizer=oc)))
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"])), f"{name}: non-finite loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree.leaves(state.params),
        jax.tree.leaves(Model(cfg).init(jax.random.PRNGKey(0)))))
    assert moved


@pytest.mark.parametrize("name", [c.name for c in ASSIGNED
                                  if c.family != "encoder"])
def test_decode_step_finite(name):
    cfg = reduced_cfg(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 16)
    logits, cache2 = model.decode_step(
        params, cache, jnp.ones((2, 1), jnp.int32), jnp.int32(3))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure is stable across steps (jit-compatible)
    jax.tree.map(lambda a, b: None if (a.shape, a.dtype) == (b.shape, b.dtype)
                 else pytest.fail("cache changed structure"), cache, cache2)


def test_abstract_matches_init_shapes():
    """ShapeDtypeStruct tree (dry-run) is structurally identical to real
    params for every assigned arch."""
    for c in ASSIGNED:
        cfg = reduced_cfg(c.name)
        model = Model(cfg)
        real = model.init(jax.random.PRNGKey(0))
        abstract = model.abstract()
        jax.tree.map(
            lambda r, a, name=c.name: None
            if (r.shape, r.dtype) == (a.shape, a.dtype)
            else pytest.fail(f"{name}: abstract/init mismatch"),
            real, abstract)
