"""Analytical model (paper §5): param counts vs known sizes, FLOPs and
roofline classification sanity."""
import pytest

from repro.configs import REGISTRY, SHAPES_BY_NAME, get_config
from repro.core.analytical import (V5E, analytical_step_seconds,
                                   arch_param_count, kv_cache_bytes,
                                   model_flops, roofline,
                                   scan_undercount_correction, step_flops,
                                   train_state_bytes)

# advertised sizes (B params), generous tolerance: embeddings/heads differ
KNOWN = {
    "qwen1.5-0.5b": (0.464, 0.1),
    "qwen2-72b": (72.7, 0.05),
    "phi3-mini-3.8b": (3.8, 0.1),
    "codeqwen1.5-7b": (7.25, 0.15),
    "falcon-mamba-7b": (7.27, 0.1),
    "recurrentgemma-2b": (2.7, 0.15),
    "granite-moe-1b-a400m": (1.3, 0.1),
    "deepseek-v3-671b": (671.0, 0.05),
}


@pytest.mark.parametrize("name,spec", KNOWN.items())
def test_param_counts_match_advertised(name, spec):
    want, tol = spec
    got = arch_param_count(REGISTRY[name]) / 1e9
    assert abs(got - want) / want < tol, (name, got, want)


def test_active_params_moe():
    g = REGISTRY["granite-moe-1b-a400m"]
    active = arch_param_count(g, active_only=True) / 1e9
    assert 0.3 < active < 0.55  # "a400m" + attention + embeddings
    d = REGISTRY["deepseek-v3-671b"]
    active = arch_param_count(d, active_only=True) / 1e9
    assert 33 < active < 42  # 37B advertised


def test_step_flops_modules_positive():
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        f = step_flops(get_config("qwen2-72b"), SHAPES_BY_NAME[shape_name])
        assert f["total"] > 0
        assert f["qkv"] > 0 and f["ffn"] > 0


def test_decode_flops_much_smaller_than_prefill():
    cfg = get_config("qwen2-72b")
    d = step_flops(cfg, SHAPES_BY_NAME["decode_32k"])["total"]
    p = step_flops(cfg, SHAPES_BY_NAME["prefill_32k"])["total"]
    assert d < p / 50


def test_mla_cache_much_smaller_than_gqa_equivalent():
    ds = get_config("deepseek-v3-671b")
    qw = get_config("qwen2-72b")
    mla = kv_cache_bytes(ds, 32_768, 1)
    gqa = kv_cache_bytes(qw, 32_768, 1)
    # MLA latent (576/tok/layer) beats even 8-way GQA (2*8*128)
    assert mla / ds.num_layers < gqa / qw.num_layers


def test_roofline_classification():
    r = roofline(flops=1e15, bytes_hbm=1e9, bytes_collective=1e6,
                 n_chips=256)
    assert r.dominant == "compute"
    r = roofline(flops=1e9, bytes_hbm=1e15, bytes_collective=1e6,
                 n_chips=256)
    assert r.dominant == "memory"
    r = roofline(flops=1e9, bytes_hbm=1e9, bytes_collective=1e15,
                 n_chips=256)
    assert r.dominant == "collective"
    assert 0 < r.compute_fraction <= 1.0


def test_model_flops_scales_with_tokens():
    cfg = get_config("qwen1.5-0.5b")
    t4 = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    p32 = model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    # train: 6ND on 1M tokens; prefill: 2ND on 1M tokens -> 3x
    assert t4 / p32 == pytest.approx(3.0, rel=1e-6)


def test_scan_correction_only_where_expected():
    assert scan_undercount_correction(
        get_config("falcon-mamba-7b"), SHAPES_BY_NAME["prefill_32k"]) > 0
    assert scan_undercount_correction(
        get_config("qwen1.5-0.5b"), SHAPES_BY_NAME["train_4k"]) == 0  # S<8192
    assert scan_undercount_correction(
        get_config("qwen2-72b"), SHAPES_BY_NAME["decode_32k"]) == 0


def test_train_state_bytes_flags_memory_pressure():
    ds = REGISTRY["deepseek-v3-671b"]
    per_chip_512 = train_state_bytes(ds) / 512
    # documented: full f32 Adam does NOT fit 512 v5e chips -> the dry-run
    # uses bf16 moments for >100B models
    assert per_chip_512 > V5E.hbm_bytes


def test_analytical_step_seconds_sane():
    r = analytical_step_seconds(get_config("qwen2-72b"),
                                SHAPES_BY_NAME["train_4k"], n_chips=256)
    assert 0.001 < r.t_total < 1000.0
