"""Analytical model (paper §5): param counts vs known sizes, FLOPs and
roofline classification sanity."""
import pytest

from repro.configs import REGISTRY, SHAPES_BY_NAME, get_config
from repro.core.analytical import (V5E, analytical_step_seconds,
                                   arch_param_count, kv_cache_bytes,
                                   model_flops, roofline,
                                   scan_undercount_correction, step_flops,
                                   train_state_bytes)

# advertised sizes (B params), generous tolerance: embeddings/heads differ
KNOWN = {
    "qwen1.5-0.5b": (0.464, 0.1),
    "qwen2-72b": (72.7, 0.05),
    "phi3-mini-3.8b": (3.8, 0.1),
    "codeqwen1.5-7b": (7.25, 0.15),
    "falcon-mamba-7b": (7.27, 0.1),
    "recurrentgemma-2b": (2.7, 0.15),
    "granite-moe-1b-a400m": (1.3, 0.1),
    "deepseek-v3-671b": (671.0, 0.05),
}


@pytest.mark.parametrize("name,spec", KNOWN.items())
def test_param_counts_match_advertised(name, spec):
    want, tol = spec
    got = arch_param_count(REGISTRY[name]) / 1e9
    assert abs(got - want) / want < tol, (name, got, want)


def test_active_params_moe():
    g = REGISTRY["granite-moe-1b-a400m"]
    active = arch_param_count(g, active_only=True) / 1e9
    assert 0.3 < active < 0.55  # "a400m" + attention + embeddings
    d = REGISTRY["deepseek-v3-671b"]
    active = arch_param_count(d, active_only=True) / 1e9
    assert 33 < active < 42  # 37B advertised


def test_step_flops_modules_positive():
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        f = step_flops(get_config("qwen2-72b"), SHAPES_BY_NAME[shape_name])
        assert f["total"] > 0
        assert f["qkv"] > 0 and f["ffn"] > 0


def test_decode_flops_much_smaller_than_prefill():
    cfg = get_config("qwen2-72b")
    d = step_flops(cfg, SHAPES_BY_NAME["decode_32k"])["total"]
    p = step_flops(cfg, SHAPES_BY_NAME["prefill_32k"])["total"]
    assert d < p / 50


def test_mla_cache_much_smaller_than_gqa_equivalent():
    ds = get_config("deepseek-v3-671b")
    qw = get_config("qwen2-72b")
    mla = kv_cache_bytes(ds, 32_768, 1)
    gqa = kv_cache_bytes(qw, 32_768, 1)
    # MLA latent (576/tok/layer) beats even 8-way GQA (2*8*128)
    assert mla / ds.num_layers < gqa / qw.num_layers


def test_roofline_classification():
    r = roofline(flops=1e15, bytes_hbm=1e9, bytes_collective=1e6,
                 n_chips=256)
    assert r.dominant == "compute"
    r = roofline(flops=1e9, bytes_hbm=1e15, bytes_collective=1e6,
                 n_chips=256)
    assert r.dominant == "memory"
    r = roofline(flops=1e9, bytes_hbm=1e9, bytes_collective=1e15,
                 n_chips=256)
    assert r.dominant == "collective"
    assert 0 < r.compute_fraction <= 1.0


def test_model_flops_scales_with_tokens():
    cfg = get_config("qwen1.5-0.5b")
    t4 = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    p32 = model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    # train: 6ND on 1M tokens; prefill: 2ND on 1M tokens -> 3x
    assert t4 / p32 == pytest.approx(3.0, rel=1e-6)


def test_scan_correction_only_where_expected():
    assert scan_undercount_correction(
        get_config("falcon-mamba-7b"), SHAPES_BY_NAME["prefill_32k"]) > 0
    assert scan_undercount_correction(
        get_config("qwen1.5-0.5b"), SHAPES_BY_NAME["train_4k"]) == 0  # S<8192
    assert scan_undercount_correction(
        get_config("qwen2-72b"), SHAPES_BY_NAME["decode_32k"]) == 0


def test_train_state_bytes_flags_memory_pressure():
    ds = REGISTRY["deepseek-v3-671b"]
    per_chip_512 = train_state_bytes(ds) / 512
    # documented: full f32 Adam does NOT fit 512 v5e chips -> the dry-run
    # uses bf16 moments for >100B models
    assert per_chip_512 > V5E.hbm_bytes


def test_analytical_step_seconds_sane():
    r = analytical_step_seconds(get_config("qwen2-72b"),
                                SHAPES_BY_NAME["train_4k"], n_chips=256)
    assert 0.001 < r.t_total < 1000.0


def _spearman(xs, ys):
    """Spearman rank correlation, hand-rolled (no scipy in the image)."""
    def ranks(vs):
        order = sorted(range(len(vs)), key=lambda i: vs[i])
        r = [0] * len(vs)
        for rank, i in enumerate(order):
            r[i] = rank
        return r
    rx, ry = ranks(xs), ranks(ys)
    n = len(xs)
    d2 = sum((a - b) ** 2 for a, b in zip(rx, ry))
    return 1.0 - 6.0 * d2 / (n * (n * n - 1))


def test_analytical_rank_correlates_with_measured_steps():
    """Autotuner calibration: the roofline model's *ranking* of fused-step
    costs must match wall measurements — ``harness.tune`` only ever
    compares candidates, so rank order is the property that matters.

    Four points on a tiny arch, adjacent predicted costs separated by
    >=2x (total spread >=4x) so host noise cannot flip the order; the
    measured side is min-of-5 jitted full-sequence forwards."""
    import dataclasses
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import REGISTRY, reduced
    from repro.configs.base import ShapeSpec
    from repro.models.model import Model

    base = reduced(REGISTRY["qwen1.5-0.5b"])
    points = [(1, 64), (1, 512), (2, 1024), (4, 2048)]  # (layers, seq_len)
    predicted, measured = [], []
    for layers, seq in points:
        cfg = dataclasses.replace(base, num_layers=layers)
        shape = ShapeSpec(f"cal_{layers}_{seq}", seq, 1, "prefill")
        predicted.append(analytical_step_seconds(cfg, shape,
                                                 n_chips=1).t_total)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        fwd = jax.jit(model.forward)
        toks = jnp.ones((1, seq), dtype=jnp.int32)
        fwd(params, {"tokens": toks}).block_until_ready()   # compile
        best = min(_timed(fwd, params, toks, time) for _ in range(5))
        measured.append(best)
    # the points are engineered to be well separated in predicted cost
    ps = sorted(predicted)
    assert all(b / a >= 2.0 for a, b in zip(ps, ps[1:])), predicted
    rho = _spearman(predicted, measured)
    assert rho >= 0.8, (rho, predicted, measured)


def _timed(fwd, params, toks, time):
    t0 = time.perf_counter()
    fwd(params, {"tokens": toks}).block_until_ready()
    return time.perf_counter() - t0
