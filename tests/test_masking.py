"""Masked primitives == dense primitives on the live slice (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # guard: optional test extra
from hypothesis import given, settings, strategies as st

from repro.core import masking


@settings(max_examples=25, deadline=None)
@given(d_max=st.integers(4, 64), frac=st.floats(0.2, 1.0),
       seed=st.integers(0, 999))
def test_masked_layernorm_matches_dense_slice(d_max, frac, seed):
    d_live = max(2, int(d_max * frac))
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 5, d_max))
    g = 1 + 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (d_max,))
    b = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 2), (d_max,))
    got = masking.masked_layernorm(x, g, b, jnp.int32(d_live))
    xs = x[..., :d_live]
    mu = xs.mean(-1, keepdims=True)
    var = ((xs - mu) ** 2).mean(-1, keepdims=True)
    want = (xs - mu) * jax.lax.rsqrt(var + 1e-5) * g[:d_live] + b[:d_live]
    np.testing.assert_allclose(np.asarray(got[..., :d_live]),
                               np.asarray(want), atol=1e-4, rtol=1e-4)
    assert np.all(np.asarray(got[..., d_live:]) == 0.0)


@settings(max_examples=25, deadline=None)
@given(d_max=st.integers(4, 64), frac=st.floats(0.2, 1.0),
       seed=st.integers(0, 999))
def test_masked_rmsnorm(d_max, frac, seed):
    d_live = max(2, int(d_max * frac))
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, d_max))
    g = jnp.ones(d_max)
    got = masking.masked_rmsnorm(x, g, jnp.int32(d_live))
    xs = x[..., :d_live]
    want = xs * jax.lax.rsqrt((xs ** 2).mean(-1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(np.asarray(got[..., :d_live]),
                               np.asarray(want), atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 40), frac=st.floats(0.1, 1.0),
       seed=st.integers(0, 999))
def test_masked_softmax(n, frac, seed):
    live = max(1, int(n * frac))
    s = jax.random.normal(jax.random.PRNGKey(seed), (2, n)) * 3
    got = masking.masked_softmax(s, jnp.int32(live))
    want = jax.nn.softmax(s[:, :live], axis=-1)
    np.testing.assert_allclose(np.asarray(got[:, :live]), np.asarray(want),
                               atol=1e-5)
    assert np.all(np.asarray(got[:, live:]) == 0.0)
    np.testing.assert_allclose(np.asarray(got.sum(-1)), 1.0, atol=1e-5)


def test_masked_mean_pool():
    x = jnp.arange(24, dtype=jnp.float32).reshape(1, 6, 4)
    got = masking.masked_mean_pool(x, jnp.int32(3))
    want = x[:, :3].mean(1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
