import dataclasses
import os

# 8 fake CPU devices so the multi-device tests can build real meshes on a
# single host.  Must be set before jax initializes; single-device tests
# are unaffected (unsharded jit still runs on device 0).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# The whole suite runs with strict donation: any "donated buffers were
# not usable" warning from a strict_jit site raises instead of silently
# doubling cache/optimizer memory (core.jitutil).
os.environ.setdefault("REPRO_STRICT", "1")

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.configs import REGISTRY, reduced  # noqa: E402


def no_drop(cfg):
    """Reduced MoE configs with lossless capacity (for equivalence tests)."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.num_experts)))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_cfg(name, lossless_moe=False):
    cfg = reduced(REGISTRY[name])
    return no_drop(cfg) if lossless_moe else cfg
