import dataclasses

import jax
import pytest

from repro.configs import REGISTRY, reduced


def no_drop(cfg):
    """Reduced MoE configs with lossless capacity (for equivalence tests)."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.num_experts)))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_cfg(name, lossless_moe=False):
    cfg = reduced(REGISTRY[name])
    return no_drop(cfg) if lossless_moe else cfg
