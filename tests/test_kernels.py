"""Per-kernel allclose vs the ref.py oracles, swept over shapes/dtypes,
plus hypothesis property tests (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # guard: optional test extra
from hypothesis import given, settings, strategies as st

from repro.core.quant import quantize
from repro.kernels import ops, ref
from repro.kernels import tiled_matmul as mmk
from repro.kernels import flash_attention as fak


def _rnd(key, *shape, dt=jnp.bfloat16):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dt)


def _assert_close(got, want, rtol):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = np.abs(want).max() + 1e-9
    np.testing.assert_allclose(got, want, atol=rtol * scale, rtol=rtol)


# ---------------------------------------------------------------------------
# tiled_matmul (Fig. 4)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N", [(64, 200, 800), (256, 384, 512),
                                   (17, 33, 65), (128, 128, 128),
                                   (1, 1024, 256)])
@pytest.mark.parametrize("dt", [jnp.bfloat16, jnp.float32])
def test_tiled_matmul_shapes(M, K, N, dt):
    a, b = _rnd(1, M, K, dt=dt), _rnd(2, K, N, dt=dt)
    _assert_close(ops.tiled_matmul(a, b), ref.tiled_matmul_ref(a, b), 2e-2)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 512),
                                    (512, 512, 512)])
def test_tiled_matmul_block_invariance(blocks):
    """Fig. 4 invariant: the K-tiled accumulation result is independent of
    the tile sizes chosen at 'synthesis'."""
    a, b = _rnd(3, 300, 500, dt=jnp.float32), _rnd(4, 500, 200, dt=jnp.float32)
    got = mmk.tiled_matmul(a, b, bm=blocks[0], bk=blocks[1], bn=blocks[2],
                           interpret=True)
    _assert_close(got, ref.tiled_matmul_ref(a, b), 1e-5)


@settings(max_examples=20, deadline=None)
@given(M=st.integers(1, 70), K=st.integers(1, 70), N=st.integers(1, 70),
       seed=st.integers(0, 2**30))
def test_tiled_matmul_property(M, K, N, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (M, K), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (K, N), jnp.float32)
    got = mmk.tiled_matmul(a, b, bm=32, bk=32, bn=32, interpret=True)
    _assert_close(got, ref.tiled_matmul_ref(a, b), 1e-4)


# ---------------------------------------------------------------------------
# qkv_proj (QKV_PM, Alg. 9) — incl. GQA narrower K/V
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,D,Nq,Nkv", [(96, 256, 512, 128),
                                        (64, 200, 198, 66),
                                        (32, 128, 256, 256)])
def test_qkv_proj(M, D, Nq, Nkv):
    x, wq = _rnd(5, M, D), _rnd(6, D, Nq)
    wk, wv = _rnd(7, D, Nkv), _rnd(8, D, Nkv)
    q, k, v = ops.qkv_proj(x, wq, wk, wv)
    q2, k2, v2 = ref.qkv_proj_ref(x, wq, wk, wv)
    _assert_close(q, q2, 2e-2)
    _assert_close(k, k2, 2e-2)
    _assert_close(v, v2, 2e-2)


# ---------------------------------------------------------------------------
# flash_attention (QK_PM + softmax + SV_PM fused)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,hd,causal,bq,bkv", [
    (100, 32, True, 32, 32), (100, 32, False, 64, 32),
    (64, 64, True, 64, 64), (130, 16, True, 32, 64)])
def test_flash_attention(S, hd, causal, bq, bkv):
    BH = 3
    q, k, v = (_rnd(9 + i, BH, S, hd) for i in range(3))
    got = fak.flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    _assert_close(got, want, 3e-2)


def test_flash_attention_block_invariance():
    q, k, v = (_rnd(20 + i, 2, 96, 32, dt=jnp.float32) for i in range(3))
    outs = [fak.flash_attention(q, k, v, causal=True, bq=bq, bkv=bkv,
                                interpret=True)
            for bq, bkv in [(32, 32), (96, 32), (32, 96), (96, 96)]]
    for o in outs[1:]:
        _assert_close(o, outs[0], 1e-4)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(2, 48), skv=st.integers(2, 48), seed=st.integers(0, 99))
def test_flash_attention_property(sq, skv, seed):
    """Cross-attention shapes (Sq != Skv), non-causal: rows are convex
    combinations of V rows -> output within [min(V), max(V)] per dim."""
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, sq, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, skv, 16),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, skv, 16),
                          jnp.float32)
    got = fak.flash_attention(q, k, v, causal=False, bq=16, bkv=16,
                              interpret=True)
    _assert_close(got, ref.flash_attention_ref(q, k, v, causal=False), 1e-3)
    assert np.all(np.asarray(got) <= np.asarray(v).max(axis=1, keepdims=True)
                  + 1e-4)
    assert np.all(np.asarray(got) >= np.asarray(v).min(axis=1, keepdims=True)
                  - 1e-4)


# ---------------------------------------------------------------------------
# ffn (FFN_PM + bias + activation)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("act", ["relu", "gelu"])
def test_ffn1(act):
    x, w1 = _rnd(30, 64, 96), _rnd(31, 96, 200)
    b1 = _rnd(32, 200, dt=jnp.float32)
    _assert_close(ops.ffn1(x, w1, b1, act), ref.ffn1_ref(x, w1, b1, act),
                  2e-2)


@pytest.mark.parametrize("act", ["swiglu", "geglu"])
def test_ffn1_gated(act):
    x, w1, wg = _rnd(33, 64, 96), _rnd(34, 96, 200), _rnd(35, 96, 200)
    _assert_close(ops.ffn1_gated(x, w1, wg, act),
                  ref.ffn1_gated_ref(x, w1, wg, act), 3e-2)


# ---------------------------------------------------------------------------
# layernorm / rmsnorm (LN unit, Alg. 8)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,D", [(50, 200), (8, 1024), (3, 65)])
def test_layernorm(R, D):
    x = _rnd(40, R, D, dt=jnp.float32)
    g = 1 + 0.1 * _rnd(41, D, dt=jnp.float32)
    b = 0.1 * _rnd(42, D, dt=jnp.float32)
    _assert_close(ops.layernorm(x, g, b), ref.layernorm_ref(x, g, b), 1e-4)
    _assert_close(ops.rmsnorm(x, g), ref.rmsnorm_ref(x, g), 1e-4)


@settings(max_examples=15, deadline=None)
@given(R=st.integers(1, 20), D=st.integers(2, 100), seed=st.integers(0, 99))
def test_layernorm_property(R, D, seed):
    """Normalized rows have ~zero mean and ~unit variance when g=1,b=0."""
    x = 5 * jax.random.normal(jax.random.PRNGKey(seed), (R, D), jnp.float32)
    y = np.asarray(ops.layernorm(x, jnp.ones(D), jnp.zeros(D)), np.float64)
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-3)
    np.testing.assert_allclose(y.var(-1), 1.0, atol=2e-2)


# ---------------------------------------------------------------------------
# int8_matmul (fixed-point path, C6)
# ---------------------------------------------------------------------------
def test_int8_matmul_vs_float():
    w = _rnd(50, 128, 96, dt=jnp.float32)
    x = _rnd(51, 32, 128)
    got = ops.quantized_dense(x, quantize(w))
    want = ref.tiled_matmul_ref(x, w.astype(jnp.bfloat16))
    _assert_close(got, want, 5e-2)


def test_int8_matmul_vs_int_ref():
    """Kernel must match the integer reference bit-for-bit in accumulation."""
    from repro.kernels import int8_matmul as i8
    qx = jax.random.randint(jax.random.PRNGKey(52), (32, 64), -127, 128,
                            jnp.int8)
    qw = jax.random.randint(jax.random.PRNGKey(53), (64, 48), -127, 128,
                            jnp.int8)
    sx = jnp.float32(0.013)
    sw = jax.random.uniform(jax.random.PRNGKey(54), (48,), jnp.float32,
                            0.001, 0.02)
    got = i8.int8_matmul(qx, sx, qw, sw, bm=32, bk=32, bn=32, interpret=True,
                         out_dtype=jnp.float32)
    want = ref.int8_matmul_ref(qx, sx, qw, sw, out_dtype=jnp.float32)
    _assert_close(got, want, 1e-6)
