"""Quantization (C6) and the tile planner (C2/C5) invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # guard: optional test extra
from hypothesis import given, settings, strategies as st

from repro.core.quant import (dequantize, quantization_error, quantize,
                              quantize_tree)
from repro.core.tiling import TilePlan, plan_matmul, sweep
from repro.core.analytical import V5E


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 64),
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 999))
def test_quant_roundtrip_bounded(rows, cols, scale, seed):
    w = scale * jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    q = quantize(w)
    back = dequantize(q)
    amax = np.abs(np.asarray(w)).max(axis=0)
    err = np.abs(np.asarray(back) - np.asarray(w))
    # per-channel symmetric int8: |err| <= scale/2 = amax/254 per column
    assert np.all(err <= amax[None, :] / 254.0 + 1e-7)


def test_quant_relative_error_small():
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 256))
    assert quantization_error(w) < 0.01


def test_quantize_tree_skips_small_leaves():
    params = {"w": jnp.ones((128, 64)), "bias": jnp.ones((64,)),
              "norm": {"scale": jnp.ones((8,))}}
    qt, meta = quantize_tree(params, min_size=1024)
    assert meta["w"] is True and meta["bias"] is False
    assert meta["norm"]["scale"] is False


# ---------------------------------------------------------------------------
# Tile planner (§3.10)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(M=st.integers(1, 8192), K=st.integers(1, 8192), N=st.integers(1, 8192))
def test_plan_fits_vmem_budget(M, K, N):
    p = plan_matmul(M, K, N)
    assert p.vmem_bytes <= V5E.vmem_bytes or (p.bm, p.bk, p.bn) == (128,) * 3
    assert p.bm % 8 == 0 and p.bn % 8 == 0 and p.bk % 8 == 0


def test_plan_beats_or_ties_all_fitting_candidates():
    """The planner's §3.10 objective: no fitting candidate is faster."""
    M, K, N = 4096, 768, 3072
    best = plan_matmul(M, K, N)
    for cand in sweep(M, K, N):
        if cand.vmem_bytes <= V5E.vmem_bytes:
            assert best.t_total <= cand.t_total + 1e-12


def test_bigger_tiles_less_hbm_traffic():
    """Fig. 13's monotonicity: growing bm/bn cuts re-streaming."""
    small = TilePlan(bm=128, bk=128, bn=128, M=4096, K=4096, N=4096)
    big = TilePlan(bm=512, bk=128, bn=512, M=4096, K=4096, N=4096)
    assert big.hbm_traffic < small.hbm_traffic


def test_misaligned_occupancy_penalty():
    """The paper's odd custom-encoder dims (200/3 heads) must show the
    alignment penalty the planner is built around."""
    odd = TilePlan(bm=128, bk=128, bn=128, M=64, K=200, N=66)
    aligned = TilePlan(bm=128, bk=128, bn=128, M=128, K=256, N=128)
    assert odd.mxu_occupancy < aligned.mxu_occupancy
    assert aligned.mxu_occupancy == 1.0
