"""Multi-topology serving: ONE compiled decode step, a mixed model fleet.

The acceptance bar for the register-driven fabric:

* a fleet engine serving two differently-shaped models concurrently
  produces token streams bit-identical to two single-topology engines,
* with exactly one decode compilation (zero retraces after warmup),
* in both cache layouts (dense rows and the paged pool),
* and the fabric's masked math matches the zoo ``Model`` numerically.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.spec import MemorySpec, RuntimeSpec, maxima_for
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fabric import DecodeFabric
from repro.serving.sampling import SamplingParams

# Member A: qwen1.5-0.5b-shaped (reduced).  Member B: a smaller, odd-headed
# topology standing in for an adaptor-bert-shaped fleet member — same
# structural template (rmsnorm / swiglu / rope / head_dim 16), different
# registers on every axis the fabric adapts over.
CFG_A = reduced_cfg("qwen1.5-0.5b")
CFG_B = dataclasses.replace(
    CFG_A, name="adaptor-bert-shaped", num_layers=1, d_model=48,
    num_heads=3, num_kv_heads=3, d_ff=96, vocab_size=96)
MAXIMA = maxima_for(CFG_A, CFG_B, seq_max=64)

PROMPTS_A = [[1, 2, 3], list(range(1, 12)), [7, 7, 7]]
PROMPTS_B = [[4, 5], list(range(2, 20, 2))]


def _params():
    return (Model(CFG_A).init(jax.random.PRNGKey(0)),
            Model(CFG_B).init(jax.random.PRNGKey(1)))


def _engine(cache_layout="dense", **mem_kw):
    spec = RuntimeSpec(arch=CFG_A, maxima=MAXIMA,
                       memory=MemorySpec(cache_layout=cache_layout,
                                         max_batch=4, max_len=64,
                                         block_size=8, **mem_kw))
    return ServingEngine(spec, max_models=2, sampling=SamplingParams())


def _run_fleet(eng, params_a, params_b, only=None):
    """Submit the standard mixed workload (or one side of it); returns
    {(model_name, prompt): generated}."""
    ids = {}
    if only in (None, "a"):
        ids["a"] = eng.add_model(params_a, CFG_A)
    if only in (None, "b"):
        ids["b"] = eng.add_model(params_b, CFG_B)
    want = []
    if "a" in ids:
        want += [("a", p) for p in PROMPTS_A]
    if "b" in ids:
        want += [("b", p) for p in PROMPTS_B]
    # interleave submissions so fleet members genuinely share batches
    uid_to_key = {}
    for name, p in sorted(want, key=lambda kp: len(kp[1])):
        uid = eng.submit(p, max_new_tokens=6, model=ids[name])
        uid_to_key[uid] = (name, tuple(p))
    done = eng.run_to_completion()
    assert len(done) == len(want)
    return {uid_to_key[r.uid]: r.generated for r in done}


# ---------------------------------------------------------------------------
# The headline claim
# ---------------------------------------------------------------------------
def test_mixed_fleet_bit_identical_to_single_topology_engines():
    params_a, params_b = _params()
    eng_ab = _engine()
    mixed = _run_fleet(eng_ab, params_a, params_b)
    # zero retraces after warmup: one fused mixed step serves both
    # topologies' prefill AND decode (chunked scheduler — no bucketed
    # prefill dispatch exists anymore)
    assert eng_ab.compilations["decode"] == 1
    assert eng_ab.compilations["prefill"] == 1
    assert eng_ab.compilations["prefill_buckets"] == 0

    solo_a = _run_fleet(_engine(), params_a, params_b, only="a")
    solo_b = _run_fleet(_engine(), params_a, params_b, only="b")
    solo = {**solo_a, **solo_b}
    assert set(mixed) == set(solo)
    for key in mixed:
        assert mixed[key] == solo[key], key


def test_paged_fleet_matches_dense_fleet():
    params_a, params_b = _params()
    dense = _run_fleet(_engine(), params_a, params_b)
    for num_blocks in (None, 14):   # worst-case pool / undersized pool
        eng = _engine("paged", num_blocks=num_blocks)
        paged = _run_fleet(eng, params_a, params_b)
        assert paged == dense, num_blocks
        assert eng.compilations["decode"] == 1


def test_pallas_paged_attn_fleet_smoke():
    """The flash-decode kernel path (padded-head-lane masking) must run
    the mixed fleet to completion with zero retraces."""
    from repro.core.spec import ExecutionSpec
    params_a, params_b = _params()
    spec = RuntimeSpec(arch=CFG_A, maxima=MAXIMA,
                       execution=ExecutionSpec(paged_attn_impl="pallas"),
                       memory=MemorySpec(cache_layout="paged", max_batch=2,
                                         max_len=64, block_size=8))
    eng = ServingEngine(spec, max_models=2, sampling=SamplingParams())
    a = eng.add_model(params_a, CFG_A)
    b = eng.add_model(params_b, CFG_B)
    ua = eng.submit([1, 2, 3], max_new_tokens=3, model=a)
    ub = eng.submit([4, 5], max_new_tokens=3, model=b)
    done = {r.uid: r for r in eng.run_to_completion()}
    assert len(done[ua].generated) == 3 and len(done[ub].generated) == 3
    assert all(0 <= t < CFG_B.vocab_size for t in done[ub].generated)
    assert eng.compilations["decode"] == 1


# ---------------------------------------------------------------------------
# Fabric math vs the zoo Model (oracle)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg,seed", [(CFG_A, 0), (CFG_B, 1)])
def test_fabric_matches_zoo_model_numerically(cfg, seed):
    """Padded maximal compute + registers == the dedicated unpadded model,
    through prefill AND several decode steps (the idle lanes of the
    fabric never contaminate live lanes)."""
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    fab = DecodeFabric(MAXIMA, 1, cfg)
    table = fab.insert_model(fab.init_table(), fab.pack_member(cfg, params),
                             0)
    topo = jnp.asarray(fab.topo_row(cfg, 0), jnp.int32)

    prompt = [1, 2, 3, 4, 5]
    toks = jnp.asarray([prompt + [0] * (16 - len(prompt))], jnp.int32)
    max_len = 32
    lg_f, cache_f = fab.prefill(table, topo, toks, max_len)
    lg_m, cache_m = model.prefill(params, {"tokens": toks}, max_len=max_len)
    v = cfg.vocab_size
    np.testing.assert_allclose(
        np.asarray(lg_f[:, :len(prompt), :v]),
        np.asarray(lg_m[:, :len(prompt)]), atol=5e-2, rtol=5e-2)

    tok = int(jnp.argmax(lg_m[0, len(prompt) - 1]))
    idx = len(prompt)
    for _ in range(3):
        t = jnp.asarray([[tok]], jnp.int32)
        lg_f, cache_f = fab.decode_step(table, cache_f, t,
                                        jnp.asarray([idx], jnp.int32),
                                        topo[None])
        lg_m, cache_m = model.decode_step(params, cache_m, t, jnp.int32(idx))
        np.testing.assert_allclose(np.asarray(lg_f[:, :, :v]),
                                   np.asarray(lg_m), atol=5e-2, rtol=5e-2)
        # dead vocab lanes must be unsampleable
        assert v == lg_f.shape[-1] or float(jnp.max(lg_f[:, :, v:])) < -1e30
        tok = int(jnp.argmax(lg_m[0, 0]))
        idx += 1


# ---------------------------------------------------------------------------
# Fleet admission errors (actionable, at load/submit time)
# ---------------------------------------------------------------------------
def test_structural_mismatch_rejected():
    params_a, _ = _params()
    eng = _engine()
    eng.add_model(params_a, CFG_A)
    wrong_norm = dataclasses.replace(CFG_A, name="ln-model", norm="layernorm")
    with pytest.raises(ValueError, match="frozen at compile"):
        eng.add_model(params_a, wrong_norm)
    too_big = dataclasses.replace(CFG_A, name="big", d_model=128, d_ff=256)
    with pytest.raises(ValueError, match="re-synthesis"):
        eng.add_model(params_a, too_big)


def test_submit_unloaded_model_rejected():
    params_a, _ = _params()
    eng = _engine()
    eng.add_model(params_a, CFG_A)
    with pytest.raises(ValueError, match="not loaded"):
        eng.submit([1, 2], model=1)
    with pytest.raises(ValueError, match="vocab"):
        eng.submit([CFG_A.vocab_size + 5], model=0)


def test_single_topology_submit_rejects_model_kwarg():
    model = Model(CFG_A)
    eng = ServingEngine(model, max_batch=2, max_len=32)
    eng.load(model.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="multi-topology"):
        eng.submit([1, 2], model=1)
