"""Fig. 12 analogue + the assignment's §Roofline table.

Reads the dry-run records (experiments/dryrun/*.json) and prints, per
(arch x shape) cell on the single-pod mesh: the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and operational
intensity (the paper's Fig. 12 x-axis).
"""
from __future__ import annotations

from benchmarks.roofline import load_records


def run() -> list[str]:
    out = ["fig12,arch,shape,t_compute_s,t_memory_s,t_collective_s,"
           "dominant,compute_frac,model_over_hlo,oper_intensity"]
    for r in load_records(mesh="single"):
        if r.get("status") != "ok":
            out.append(f"fig12,{r['arch']},{r['shape']},-,-,-,"
                       f"{r.get('status')},{r.get('reason', '')},-,-")
            continue
        rl = r["roofline"]
        oi = r["hlo_flops"] / max(r["hlo_bytes"], 1.0)
        out.append(
            f"fig12,{r['arch']},{r['shape']},"
            f"{rl['t_compute_s']:.4g},{rl['t_memory_s']:.4g},"
            f"{rl['t_collective_s']:.4g},{rl['dominant']},"
            f"{rl['compute_fraction']:.4f},"
            f"{r.get('model_over_hlo')},{oi:.2f}")
    return out


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
