"""Fig. 5/9/13 analogue: tile-size sweep.

The paper sweeps TS_MHA x TS_FFN against frequency/latency/resources.
TPU version: sweep (bm, bk, bn) BlockSpec shapes for the two workload
matmuls (MHA projection and FFN1 of BERT at SL 4096) and report modeled
latency, VMEM fit and MXU occupancy — the frequency cliff becomes the
VMEM-overflow cliff.
"""
from __future__ import annotations

from repro.core.analytical import V5E
from repro.core.tiling import TilePlan, plan_matmul

# BERT-base MHA projection and FFN1 at SL 4096 (the paper's workload family)
WORKLOADS = [("mha_proj", 4096, 768, 768), ("ffn1", 4096, 768, 3072)]
BLOCKS = (128, 256, 512, 1024)


def run() -> list[str]:
    out = ["fig5,workload,bm,bk,bn,vmem_mib,fits,occupancy,t_model_us,"
           "dominant"]
    for name, M, K, N in WORKLOADS:
        for bm in BLOCKS:
            for bn in BLOCKS:
                p = TilePlan(bm=bm, bk=256, bn=bn, M=M, K=K, N=N)
                tc, tm = p.latency()
                fits = p.vmem_bytes <= V5E.vmem_bytes
                out.append(
                    f"fig5,{name},{bm},256,{bn},"
                    f"{p.vmem_bytes / 2**20:.1f},{int(fits)},"
                    f"{p.mxu_occupancy:.3f},{max(tc, tm) * 1e6:.1f},"
                    f"{'compute' if tc > tm else 'memory'}")
        best = plan_matmul(M, K, N)
        out.append(f"fig5_best,{name},{best.bm},{best.bk},{best.bn},"
                   f"{best.vmem_bytes / 2**20:.1f},1,"
                   f"{best.mxu_occupancy:.3f},{best.t_total * 1e6:.1f},-")
    return out


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
