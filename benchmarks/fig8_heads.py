"""Fig. 8 analogue: performance vs number of attention heads, swept at
RUNTIME on one compiled adaptive engine (the heads register).

The paper's frequency-degradation effect is FPGA-specific; the TPU
analogue reported here is (a) measured wall time per call on this host —
constant, because the padded fabric computes the maxima regardless, and
(b) the *live* FLOP fraction, which is what a Pallas-masked deployment
recovers.  One compile, six topologies.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaptiveEngine, EngineOptions
from repro.core.registers import Maxima, make_registers


def run() -> list[str]:
    mx = Maxima(seq_max=64, heads_max=12, layers_enc_max=4, layers_dec_max=0,
                d_model_max=768, d_ff_max=3072, out_max=768,
                head_dim_max=64, vocab=1000)
    eng = AdaptiveEngine(mx, EngineOptions(batch=1))
    step = eng.compile()
    params = eng.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, 1000)
    out = ["fig8,heads,wall_us_per_call,live_flop_frac,traces"]
    for h in (2, 4, 6, 8, 10, 12):
        regs = make_registers(sequence=64, heads=h, layers_enc=4,
                              layers_dec=0, embeddings=64 * h,
                              hidden=4 * 64 * h, out=768)
        step(params, regs, jnp.int32(0), toks).block_until_ready()
        t0 = time.perf_counter()
        n = 3
        for _ in range(n):
            step(params, regs, jnp.int32(0), toks).block_until_ready()
        dt = (time.perf_counter() - t0) / n
        live = (h / mx.heads_max) ** 2  # d_model and d_ff scale with h here
        out.append(f"fig8,{h},{dt * 1e6:.0f},{live:.3f},{eng.trace_count()}")
    assert eng.trace_count() == 1
    return out


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
