"""Shared roofline helpers: read the dry-run JSON records."""
from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    if not os.path.isdir(DRYRUN_DIR):
        return recs
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN_DIR, f)) as fh:
            r = json.load(fh)
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"
