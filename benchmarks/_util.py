"""Shared helpers for the benchmark scripts.

``BENCH_serving.json`` is a committed artifact: benchmark name ->
payload.  Payloads are versioned — every writer goes through
:func:`write_payload`, which stamps ``schema`` and validates both the
new payload and the existing file before merging, so a malformed or
legacy entry fails loudly instead of being silently overwritten (or
silently kept) next to well-formed ones.
"""
from __future__ import annotations

import json
import os

SCHEMA = 1

# every payload must carry these; "results" holds the measured numbers,
# "config" the knobs that produced them
_REQUIRED = ("schema", "benchmark", "arch", "config", "results")


def validate_payload(key: str, payload: dict) -> None:
    """Raise ``ValueError`` unless ``payload`` is a well-formed schema-1
    benchmark entry for ``key``."""
    if not isinstance(payload, dict):
        raise ValueError(f"payload for {key!r} is {type(payload).__name__}, "
                         "not a dict")
    missing = [k for k in _REQUIRED if k not in payload]
    if missing:
        raise ValueError(f"payload for {key!r} is missing required keys "
                         f"{missing} (have {sorted(payload)})")
    if payload["schema"] != SCHEMA:
        raise ValueError(f"payload for {key!r} has schema="
                         f"{payload['schema']!r}; this writer speaks "
                         f"schema={SCHEMA}")
    if payload["benchmark"] != key:
        raise ValueError(f"payload under key {key!r} names benchmark="
                         f"{payload['benchmark']!r}; key and benchmark "
                         "must agree")
    for k in ("config", "results"):
        if not isinstance(payload[k], dict):
            raise ValueError(f"payload[{k!r}] for {key!r} must be a dict, "
                             f"got {type(payload[k]).__name__}")


def write_payload(path: str, key: str, *, arch: str, config: dict,
                  results: dict, extra: dict | None = None) -> dict:
    """Build, validate, and merge one benchmark's schema-1 payload into
    the shared results file.  Returns the payload written."""
    payload = {"schema": SCHEMA, "benchmark": key, "arch": arch,
               "config": config, "results": results}
    if extra:
        clash = set(extra) & set(payload)
        if clash:
            raise ValueError(f"extra keys {sorted(clash)} collide with the "
                             "schema's required keys")
        payload.update(extra)
    validate_payload(key, payload)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            try:
                data = json.load(f)
            except ValueError as e:
                raise ValueError(
                    f"{path} exists but is not valid JSON ({e}); refusing "
                    "to overwrite — delete it to start fresh") from e
        if not isinstance(data, dict):
            raise ValueError(f"{path} holds a {type(data).__name__}, not "
                             "the benchmark-name -> payload map")
        for k, v in data.items():
            if k != key:
                validate_payload(k, v)   # a malformed neighbour fails loudly
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return payload
