"""Shared helpers for the benchmark scripts."""
from __future__ import annotations

import json
import os


def append_json(path: str, key: str, payload: dict) -> None:
    """Merge one benchmark's payload into the shared results file
    (``BENCH_serving.json`` maps benchmark name -> payload, so each
    script appends its section instead of overwriting the others)."""
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            try:
                data = json.load(f)
            except ValueError:
                data = {}
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
