"""Mesh-sharded serving vs a single device at equal per-device cache.

The ISSUE-9 tentpole claim, measured: tensor parallelism shards the
paged KV pool over the ``model`` axis, so each replica can hold ``tp``
times the blocks at the SAME per-device byte footprint, and data
parallelism multiplies that by ``dp`` independent replicas behind one
admission queue.  At equal per-device cache bytes the dp x tp cluster
must therefore seat more of every burst (peak concurrency) and drain
the trace in fewer engine steps (goodput per 1k steps) than the
historical single-device engine — while streaming *bit-identical*
tokens (fp32 compute, greedy sampling: a sharded matmul must not flip
an argmax).

Geometry: the baseline spends N pool blocks on its one device; the
sharded spec spends tp*N blocks per replica, split tp ways by GSPMD, so
``RuntimeSpec.capacity().per_device_cache_bytes`` is identical on both
sides (asserted, not assumed).  Every gated number is step-based and
deterministic; the tuned replay is repeated on a fresh cluster and must
serialize to identical bytes.

    PYTHONPATH=src python benchmarks/sharded_serving.py
    PYTHONPATH=src python benchmarks/sharded_serving.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import dataclasses

try:                                   # package form (benchmarks.run)
    from benchmarks._util import write_payload
except ModuleNotFoundError:            # direct script invocation
    from _util import write_payload

from repro.launch.mesh import ensure_host_devices


def _measure(spec, params, trace, slo):
    from repro.harness import replay
    from repro.serving.cluster import EngineCluster
    from repro.serving.engine import ServingEngine

    if spec.mesh.dp > 1:
        eng = EngineCluster(spec)
    else:
        eng = ServingEngine(spec)
    eng.load(params)
    res = replay(eng, trace, slo=slo)
    streams = {res.uid_to_rid[r.uid]: tuple(r.generated)
               for r in res.finished}
    return res, streams


def run(arch: str, layers: int | None, tp: int, dp: int, num_blocks: int,
        block_size: int, max_batch: int, n_requests: int, burst_size: int,
        gap_steps: int, max_len: int, max_new: int, slo_ttft_steps: int,
        require_peak_gain: float | None, require_goodput_gain: float | None,
        out_json: str | None, seed: int = 17) -> dict:
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.core.spec import (ExecutionSpec, MemorySpec, MeshSpec,
                                 RuntimeSpec, SchedulerSpec)
    from repro.harness import SLO, bursty_trace
    from repro.models.model import Model

    cfg = reduced(REGISTRY[arch])
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    params = Model(cfg).init(jax.random.PRNGKey(0))

    # _tokens samples ids in [1, vocab] INCLUSIVE — stay inside the
    # table.  short_frac=0: every prompt is near max_len, so the pool
    # (the thing TP doubles per device-byte), not the slot count, is
    # what bounds admission on both sides
    trace = bursty_trace(n_requests, burst_size=burst_size,
                         gap_steps=gap_steps, max_len=max_len,
                         max_new=max_new, short_frac=0.0,
                         vocab=cfg.vocab_size - 1, seed=seed)
    slo = SLO(ttft_steps=slo_ttft_steps)

    def spec_for(mesh: MeshSpec, blocks: int) -> RuntimeSpec:
        return RuntimeSpec(
            arch=cfg,
            execution=ExecutionSpec(compute_dtype="fp32"),
            memory=MemorySpec(cache_layout="paged", max_batch=max_batch,
                              max_len=-(-(max_len + max_new) // block_size)
                              * block_size,
                              block_size=block_size, num_blocks=blocks),
            scheduler=SchedulerSpec(policy="chunked"),
            mesh=mesh).validate()

    base_spec = spec_for(MeshSpec(), num_blocks)
    mesh_spec = spec_for(MeshSpec(tp=tp, dp=dp), tp * num_blocks)

    # the whole comparison hinges on this: per-replica pools are tp x
    # bigger but split tp ways, so no device spends an extra cache byte
    base_cap = base_spec.capacity()
    mesh_cap = mesh_spec.capacity()
    assert mesh_cap.kv_shards == tp, (
        f"kv pool sharded {mesh_cap.kv_shards} ways, wanted {tp} — "
        "indivisible kv heads would replicate and break the equal-bytes "
        "premise")
    assert mesh_cap.per_device_cache_bytes == base_cap.per_device_cache_bytes

    base_res, base_streams = _measure(base_spec, params, trace, slo)
    mesh_res, mesh_streams = _measure(mesh_spec, params, trace, slo)
    # reproducibility: a fresh cluster replaying the same trace must
    # serialize to byte-identical deterministic metrics and streams
    again_res, again_streams = _measure(mesh_spec, params, trace, slo)

    bm, mm = base_res.metrics, mesh_res.metrics
    identical = mesh_streams == base_streams
    reproducible = (
        mesh_res.metrics.deterministic_json()
        == again_res.metrics.deterministic_json()
        and mesh_streams == again_streams)
    peak_gain = mm.peak_concurrency / max(bm.peak_concurrency, 1)
    goodput_gain = mm.goodput_req_per_1k_steps \
        / max(bm.goodput_req_per_1k_steps, 1e-9)

    print(f"arch={cfg.name}  mesh tp={tp} dp={dp} on "
          f"{mesh_cap.n_devices} devices  trace: {n_requests} requests "
          f"in bursts of {burst_size} every {gap_steps} steps, "
          f"SLO ttft<={slo_ttft_steps} steps")
    print(f"  per-device cache {base_cap.per_device_cache_bytes / 2**10:.1f} "
          f"KiB on both sides; pool tokens {base_cap.pool_tokens} -> "
          f"{mesh_cap.pool_tokens} ({mesh_cap.kv_shards}-way sharded, "
          f"{mesh_cap.n_devices} devices)")
    for k, m in (("1-dev", bm), (f"tp{tp}xdp{dp}", mm)):
        print(f"  {k:9s} finished {m.n_finished:3d}/{m.n_requests}   "
              f"slo_met {m.n_slo_met:3d}   goodput "
              f"{m.goodput_req_per_1k_steps:7.1f} req/1k-steps   peak "
              f"{m.peak_concurrency:3d}   steps {m.steps:4d}   preempt "
              f"{m.n_preemptions}")
    print(f"  peak gain {peak_gain:.2f}x, goodput gain {goodput_gain:.2f}x "
          f"at equal per-device cache; streams identical: {identical}; "
          f"replay bit-reproducible: {reproducible}")

    assert bm.n_finished == n_requests and mm.n_finished == n_requests, (
        "replay left requests unfinished — gains would compare different "
        "work")
    assert identical, (
        "sharded streams diverged from the single-device engine — the "
        "mesh lowering changed the numerics past argmax stability")
    assert reproducible, (
        "two fresh cluster replays of the same trace differ — "
        "nondeterminism leaked into the step-based path")
    if require_peak_gain is not None:
        assert peak_gain >= require_peak_gain, (
            f"peak concurrency gain {peak_gain:.2f}x below the required "
            f"{require_peak_gain:.2f}x at equal per-device cache")
    if require_goodput_gain is not None:
        assert goodput_gain >= require_goodput_gain, (
            f"goodput gain {goodput_gain:.2f}x below the required "
            f"{require_goodput_gain:.2f}x at equal per-device cache")

    results_out = {
        "capacity": {
            "per_device_cache_bytes": base_cap.per_device_cache_bytes,
            "pool_tokens": {"single": base_cap.pool_tokens,
                            "sharded": mesh_cap.pool_tokens},
            "kv_shards": mesh_cap.kv_shards,
            "n_devices": mesh_cap.n_devices,
            "max_concurrent": {"single": base_cap.max_concurrent,
                               "sharded": mesh_cap.max_concurrent}},
        "metrics": {"single": bm.deterministic(),
                    "sharded": mm.deterministic()},
        "peak_gain": peak_gain,
        "goodput_gain": goodput_gain,
        "identical_streams": identical,
        "bit_reproducible": reproducible,
    }
    payload = {"benchmark": "sharded", "results": results_out}
    if out_json:
        payload = write_payload(
            out_json, "sharded", arch=cfg.name,
            config={"tp": tp, "dp": dp, "num_blocks": num_blocks,
                    "block_size": block_size, "max_batch": max_batch,
                    "n_requests": n_requests, "burst_size": burst_size,
                    "gap_steps": gap_steps, "max_len": max_len,
                    "max_new": max_new, "slo_ttft_steps": slo_ttft_steps,
                    "trace_seed": seed},
            results=results_out)
        print(f"  appended to {out_json}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--num-blocks", type=int, default=12,
                    help="baseline pool blocks; the sharded replica gets "
                         "tp x this, split tp ways (equal bytes/device)")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=24,
                    help="slots per engine — oversized so pool blocks, "
                         "not slots, bound admission on both sides")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--burst", type=int, default=24)
    ap.add_argument("--gap", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=20)
    ap.add_argument("--max-new", type=int, default=5)
    ap.add_argument("--slo-ttft-steps", type=int, default=16)
    ap.add_argument("--trace-seed", type=int, default=17)
    ap.add_argument("--require-peak-gain", type=float, default=2.0)
    ap.add_argument("--require-goodput-gain", type=float, default=1.3)
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, short trace (gates kept — "
                         "they are deterministic step arithmetic)")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.requests, args.burst, args.gap = 1, 16, 16, 10
    if args.devices < args.tp * args.dp:
        raise SystemExit(f"--devices {args.devices} < tp*dp = "
                         f"{args.tp * args.dp}")
    # must land in XLA_FLAGS before run() imports jax
    ensure_host_devices(args.devices)
    run(args.arch, args.layers, args.tp, args.dp, args.num_blocks,
        args.block_size, args.max_batch, args.requests, args.burst,
        args.gap, args.max_len, args.max_new, args.slo_ttft_steps,
        args.require_peak_gain, args.require_goodput_gain, args.json,
        seed=args.trace_seed)


if __name__ == "__main__":
    main()
