"""Steady-state decode throughput: device-resident engine vs seed loop.

The seed engine's ``step()`` did O(max_batch) host<->device round trips
per decoded token: a Python loop of ``tokens.at[i, 0].set`` dispatches to
assemble the feed tokens, then ``int(next_toks[i])`` and
``int(self.indices[i])`` blocking scalar syncs per slot.  The
device-resident engine dispatches one fused step and reads back one
(done, count) vector pair per sync.  This benchmark measures the gap at
``max_batch`` in {1, 8, 32} with all slots saturated (pure decode
steady state, prefill excluded).

    PYTHONPATH=src python benchmarks/serving_throughput.py
    PYTHONPATH=src python benchmarks/serving_throughput.py --arch qwen1.5-0.5b --layers 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample


class SeedPerSlotLoop:
    """The seed engine's decode loop, reproduced verbatim for comparison:
    per-slot host state, per-slot scalar syncs every step."""

    def __init__(self, model: Model, max_batch: int, max_len: int):
        self.model = model
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampling = SamplingParams()
        self.rng = jax.random.PRNGKey(0)
        self.last = [0] * max_batch          # host-side per-slot state
        self.indices = jnp.zeros((max_batch,), jnp.int32)
        self._decode = jax.jit(self._decode_impl)

    def _decode_impl(self, params, cache, tokens, indices, rng):
        logits, cache = self.model.decode_step(params, cache, tokens, indices)
        toks = sample(logits[:, 0], rng, self.sampling)
        return toks, cache

    def seat(self, params, prompts):
        self.params = params
        self.cache = self.model.init_cache(self.max_batch, self.max_len)
        for i, prompt in enumerate(prompts):
            toks = jnp.asarray(prompt, jnp.int32)[None]
            logits, one = self.model.prefill(self.params, {"tokens": toks},
                                             max_len=self.max_len)
            self.cache = jax.tree.map(
                lambda g, o: g.at[:, i].set(o[:, 0])
                if g.ndim >= 2 and g.shape[1] == self.max_batch
                else g.at[i].set(o[0]), self.cache, one)
            self.indices = self.indices.at[i].set(len(prompt))
            self.last[i] = int(jnp.argmax(logits[0, len(prompt) - 1]))

    def step(self) -> None:
        # --- the seed serialization trap, faithfully reproduced ---------
        tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        for i in range(self.max_batch):          # O(B) set dispatches
            tokens = tokens.at[i, 0].set(self.last[i])
        self.rng, k = jax.random.split(self.rng)
        next_toks, self.cache = self._decode(self.params, self.cache,
                                             tokens, self.indices, k)
        self.indices = self.indices + jnp.ones((self.max_batch,), jnp.int32)
        for i in range(self.max_batch):          # O(B) blocking syncs
            self.last[i] = int(next_toks[i])
            _ = int(self.indices[i])


def _bench(fn, steps: int, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(steps):
        fn()
    return time.perf_counter() - t0


def run(arch: str, layers: int | None, steps: int,
        batches: tuple[int, ...]) -> dict[int, tuple[float, float]]:
    cfg = reduced(REGISTRY[arch])
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 512
    results: dict[int, tuple[float, float]] = {}
    for B in batches:
        prompts = [[1 + (j % 7), 2, 3, 4, 5, 6, 7, 8] for j in range(B)]

        seed = SeedPerSlotLoop(model, B, max_len)
        seed.seat(params, prompts)
        dt_seed = _bench(seed.step, steps)

        eng = ServingEngine(model, max_batch=B, max_len=max_len,
                            sampling=SamplingParams())
        eng.load(params)
        for p in prompts:   # saturate every slot, budget beyond the bench
            eng.submit(p, max_new_tokens=steps * 4)
        eng.step()          # admit + first fused step (compile)
        dt_dev = _bench(lambda: eng.step(), steps)

        tok_seed = B * steps / dt_seed
        tok_dev = B * steps / dt_dev
        results[B] = (tok_seed, tok_dev)
        print(f"max_batch={B:3d}  seed per-slot loop {tok_seed:9.1f} tok/s   "
              f"device-resident {tok_dev:9.1f} tok/s   "
              f"speedup {tok_dev / tok_seed:4.2f}x")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count of the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8, 32])
    args = ap.parse_args()
    run(args.arch, args.layers, args.steps, tuple(args.batches))


if __name__ == "__main__":
    main()
