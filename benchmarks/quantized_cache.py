"""int8 KV cache vs bf16 KV cache under the same HBM budget.

The paged-cache benchmark showed that admitted concurrency is bound by
cache *bytes*, not compute.  The int8 cache codec
(``MemorySpec(kv_dtype="int8")``, ``core.kv_quant``) attacks the bytes
directly: a cached row of width ``hd`` costs ``hd + 4`` bytes (int8
values + one f32 scale) instead of ``2 hd`` bf16 bytes — 1.88x fewer at
head_dim 64.  Spending the *same* HBM budget on an int8 pool therefore
buys ~1.9x more blocks, and a saturating trace admits ~1.9x more
concurrent requests.

Both engines replay the same trace with the same seed.  The codec is
lossy (<0.5% per-row error), so greedy streams are *equivalent within
quantization tolerance*: the report asserts the identical-stream
fraction — 100% on the CI-sized config (the default trace moves no
argmax), >=90% required everywhere — then compares peak admitted
concurrency and steps-to-drain at equal bytes.

    PYTHONPATH=src python benchmarks/quantized_cache.py
    PYTHONPATH=src python benchmarks/quantized_cache.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

try:                                   # package form (benchmarks.run)
    from benchmarks._util import write_payload
except ModuleNotFoundError:            # direct script invocation
    from _util import write_payload

from repro.configs import REGISTRY, reduced
from repro.core.kv_quant import CacheCodec
from repro.core.spec import MemorySpec, RuntimeSpec
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def mixed_trace(n: int, max_len: int, seed: int = 0
                ) -> list[tuple[list[int], int]]:
    """Mostly-short prompts with a long tail (the paged-cache traffic
    shape) — enough of them to saturate either pool."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        if i % 5 == 4:
            plen = int(rng.randint(max_len // 2, 3 * max_len // 4))
        else:
            plen = int(rng.randint(3, max_len // 8))
        budget = int(rng.randint(2, max_len // 8))
        prompt = [1 + int(t) for t in rng.randint(0, 50, size=plen)]
        reqs.append((prompt, budget))
    return reqs


def drive(eng: ServingEngine, reqs) -> dict:
    for prompt, budget in reqs:
        eng.submit(prompt, max_new_tokens=budget)
    peak, steps, done = 0, 0, []
    while eng.queue or eng._occupied():
        done += eng.step()
        peak = max(peak, len(eng._occupied()))
        steps += 1
    return {"peak": peak, "steps": steps,
            "done": {r.uid: r.generated for r in done}}


def cache_nbytes(cache) -> int:
    """Actual HBM bytes of a cache pytree (values + codec scales)."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(cache))


def run(arch: str, layers: int | None, head_dim: int, max_len: int,
        budget_blocks: int, block_size: int, n_requests: int,
        max_batch: int, require_gain: float | None,
        out_json: str | None, trace_seed: int = 3,
        require_identical: float = 0.9) -> dict:
    over = {} if layers is None else {"num_layers": layers}
    cfg = reduced(REGISTRY[arch], head_dim=head_dim, **over)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = mixed_trace(n_requests, max_len, trace_seed)

    # one HBM budget, two codecs: the bf16 engine gets budget_blocks
    # blocks; the int8 engine gets however many blocks the same bytes buy
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    bytes_per_row = {"compute": 2 * hd, "int8": hd + 4}   # k or v, per head
    block_bytes = {k: 2 * block_size * kv * v * cfg.num_layers
                   for k, v in bytes_per_row.items()}
    budget_bytes = budget_blocks * block_bytes["compute"]
    num_blocks = {"compute": budget_blocks,
                  "int8": budget_bytes // block_bytes["int8"]}

    results, engines = {}, {}
    for kd in ("compute", "int8"):
        spec = RuntimeSpec(arch=cfg, memory=MemorySpec(
            cache_layout="paged", max_batch=max_batch, max_len=max_len,
            block_size=block_size, num_blocks=int(num_blocks[kd]),
            kv_dtype=kd))
        eng = ServingEngine(spec, sampling=SamplingParams())
        eng.load(params)
        results[kd] = drive(eng, reqs)
        engines[kd] = eng

    f, q = results["compute"], results["int8"]
    n_same = sum(f["done"][u] == q["done"][u] for u in f["done"])
    same_frac = n_same / max(len(f["done"]), 1)
    gain = q["peak"] / max(f["peak"], 1)
    drain = f["steps"] / max(q["steps"], 1)
    pool_bytes = {kd: cache_nbytes(engines[kd].cache)
                  for kd in ("compute", "int8")}

    print(f"arch={cfg.name}  head_dim={hd}  max_len={max_len}  "
          f"HBM budget {budget_bytes / 2**20:.2f} MiB of KV pool")
    print(f"  trace: {len(reqs)} requests, prompt lengths "
          f"{min(len(p) for p, _ in reqs)}..{max(len(p) for p, _ in reqs)}")
    for kd in ("compute", "int8"):
        r = results[kd]
        print(f"  {kd:8s} [{int(num_blocks[kd]):4d} blocks x {block_size}, "
              f"{pool_bytes[kd] / 2**20:6.2f} MiB resident]  "
              f"peak concurrency {r['peak']:3d}   steps to drain "
              f"{r['steps']:4d}   preemptions "
              f"{engines[kd].stats['preemptions']}")
    codec = CacheCodec("int8")
    print(f"  bytes/row: {2 * hd} bf16 -> "
          f"{codec.bytes_per_feature_row(hd)} int8+scale "
          f"({2 * hd / (hd + 4):.2f}x); identical streams: "
          f"{n_same}/{len(f['done'])}; "
          f"concurrency gain {gain:.2f}x; drain speedup {drain:.2f}x")
    assert same_frac >= require_identical, (
        f"only {n_same}/{len(f['done'])} int8-cache streams matched the "
        f"bf16 cache (required fraction {require_identical})")
    if require_gain is not None:
        assert gain >= require_gain, (
            f"int8 cache peak concurrency gain {gain:.2f}x below the "
            f"required {require_gain:.2f}x at equal HBM")

    results_out = {
        "peak_concurrency": {"compute": f["peak"], "int8": q["peak"]},
        "steps_to_drain": {"compute": f["steps"], "int8": q["steps"]},
        "concurrency_gain": gain,
        "drain_speedup": drain,
        "identical_stream_fraction": same_frac,
    }
    payload = {"benchmark": "quantized_cache", "results": results_out}
    if out_json:
        payload = write_payload(
            out_json, "quantized_cache", arch=cfg.name,
            config={"head_dim": hd, "max_len": max_len,
                    "block_size": block_size, "budget_bytes": budget_bytes,
                    "num_blocks": {k: int(v) for k, v in num_blocks.items()},
                    "requests": n_requests},
            results=results_out)
        print(f"  appended to {out_json}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--head-dim", type=int, default=64,
                    help="reduced-config head_dim (64 = the realistic "
                         "regime where int8+scale is 1.88x smaller)")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--budget-blocks", type=int, default=None,
                    help="HBM budget expressed as bf16 blocks (default "
                         "3 * max_len / block_size)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--trace-seed", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=48)
    ap.add_argument("--require-gain", type=float, default=1.8,
                    help="fail unless int8 peak concurrency gains this "
                         "much at equal HBM")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, short trace, small max_len")
    args = ap.parse_args()
    require_identical = 0.9
    if args.smoke:
        args.layers, args.max_len, args.requests = 1, 64, 36
        args.block_size, args.max_batch = 8, 48
        require_identical = 1.0   # verified: the default trace moves no argmax
    budget = args.budget_blocks or 3 * args.max_len // args.block_size
    run(args.arch, args.layers, args.head_dim, args.max_len, budget,
        args.block_size, args.requests, args.max_batch, args.require_gain,
        args.json, trace_seed=args.trace_seed,
        require_identical=require_identical)


if __name__ == "__main__":
    main()
