"""Benchmark driver: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` prints CSV blocks:
  table1  — throughput/efficiency per network (GOPS analogues)
  table2  — analytical model vs compiled HLO (% error)
  fig5    — tile-size sweep (VMEM fit / occupancy / modeled latency)
  fig8    — runtime heads-register sweep on one compiled engine
  fig11   — portability: tile re-planning across memory budgets
  fig12   — the 40-cell roofline table from the dry-run records
  fleet   — multi-topology serving vs per-model engines (equal memory)
  serving — chunked prefill vs bucketed (TTFT / tok/s; BENCH_serving.json)
  qcache  — int8 vs bf16 KV cache at equal HBM (concurrency / drain)
  prefix  — prefix-cached pool vs no sharing (warm TTFT / concurrency)
  harness — tuned spec vs naive default at equal memory (load harness)
  sharded — dp x tp mesh cluster vs 1 device at equal cache/device
  spec    — speculative vs target-only decode (tok/step at equal bytes)

``--devices N`` forces N host-platform devices; it must be applied
before anything imports jax, so the benchmark modules are imported
inside ``main`` after the flag is parsed.
"""
from __future__ import annotations

import argparse
import time
import traceback

from repro.launch.mesh import ensure_host_devices


def _fleet():
    from benchmarks import multi_topology
    r = multi_topology.run(max_batch=4, max_len=64, n_per_model=5,
                           max_new=4, layers=1)
    yield "metric,fleet,two_engines"
    yield f"fused_steps,{r['fleet_steps']},{r['solo_steps']}"
    yield f"wall_s,{r['fleet_wall']:.2f},{r['solo_wall']:.2f}"


def _serving():
    from benchmarks import chunked_prefill
    r = chunked_prefill.run(arch="qwen1.5-0.5b", layers=1, max_batch=4,
                            max_len=64, chunk=16, budget=32, max_new=4,
                            require_speedup=None,
                            out_json="BENCH_serving.json")
    res = r["results"]
    yield "metric,bucketed,chunked"
    for key in ("ttft_short", "ttft_long"):
        yield (f"{key}_warm,{res['phases']['bucketed']['warm'][key]:.4f},"
               f"{res['phases']['chunked']['warm'][key]:.4f}")
    yield ("drain_toks_per_s,"
           f"{res['drain_toks_per_s']['bucketed']:.1f},"
           f"{res['drain_toks_per_s']['chunked']:.1f}")
    yield ("prefill_compilations,"
           f"{res['compilations']['bucketed']['prefill']},"
           f"{res['compilations']['chunked']['prefill']}")


def _qcache():
    from benchmarks import quantized_cache
    r = quantized_cache.run(arch="qwen1.5-0.5b", layers=1, head_dim=64,
                            max_len=64, budget_blocks=24, block_size=8,
                            n_requests=36, max_batch=48, require_gain=1.8,
                            out_json="BENCH_serving.json",
                            require_identical=1.0)
    res = r["results"]
    yield "metric,bf16_cache,int8_cache"
    yield (f"peak_concurrency,{res['peak_concurrency']['compute']},"
           f"{res['peak_concurrency']['int8']}")
    yield (f"steps_to_drain,{res['steps_to_drain']['compute']},"
           f"{res['steps_to_drain']['int8']}")
    yield f"concurrency_gain,1.00,{res['concurrency_gain']:.2f}"


def _prefix():
    from benchmarks import prefix_cache
    r = prefix_cache.run(arch="qwen1.5-0.5b", layers=1, max_len=128,
                         block_size=8, num_blocks=40, n_requests=15,
                         max_batch=24, require_ttft=2.0, require_peak=1.5,
                         out_json="BENCH_serving.json")
    res = r["results"]
    yield "metric,sharing_off,sharing_on"
    yield (f"warm_ttft_s,{res['warm_ttft']['sharing-off']['seconds']:.4f},"
           f"{res['warm_ttft']['sharing-on']['seconds']:.4f}")
    yield (f"peak_concurrency,{res['peak_concurrency']['sharing-off']},"
           f"{res['peak_concurrency']['sharing-on']}")
    yield (f"steps_to_drain,{res['steps_to_drain']['sharing-off']},"
           f"{res['steps_to_drain']['sharing-on']}")
    yield f"identical_streams,{res['identical_streams']},="


def _harness():
    from benchmarks import load_harness
    r = load_harness.run(arch="qwen1.5-0.5b", layers=1, n_requests=24,
                         burst_size=12, gap_steps=16, max_len=64, max_new=4,
                         naive_batch=8, slo_ttft_steps=12,
                         require_goodput_gain=1.2,
                         out_json="BENCH_serving.json")
    res = r["results"]
    m = res["metrics"]
    yield "metric,naive,tuned"
    yield (f"goodput_req_per_1k_steps,"
           f"{m['naive']['goodput_req_per_1k_steps']:.1f},"
           f"{m['tuned']['goodput_req_per_1k_steps']:.1f}")
    yield (f"slo_met,{m['naive']['n_slo_met']}/{m['naive']['n_requests']},"
           f"{m['tuned']['n_slo_met']}/{m['tuned']['n_requests']}")
    yield (f"ttft_steps_p99,{m['naive']['ttft_steps_p99']},"
           f"{m['tuned']['ttft_steps_p99']}")
    yield (f"peak_concurrency,{m['naive']['peak_concurrency']},"
           f"{m['tuned']['peak_concurrency']}")
    yield f"goodput_gain,1.00,{res['goodput_gain']:.2f}"
    yield f"bit_reproducible,=,{res['bit_reproducible']}"


# the sharded section's mesh geometry; main() overwrites from --tp/--dp
MESH = {"tp": 2, "dp": 2}


def _sharded():
    from benchmarks import sharded_serving
    r = sharded_serving.run(arch="qwen1.5-0.5b", layers=1,
                            tp=MESH["tp"], dp=MESH["dp"], num_blocks=12,
                            block_size=8, max_batch=24, n_requests=16,
                            burst_size=16, gap_steps=10, max_len=20,
                            max_new=5, slo_ttft_steps=16,
                            require_peak_gain=2.0,
                            require_goodput_gain=1.3,
                            out_json="BENCH_serving.json")
    res = r["results"]
    yield "metric,single_device,sharded"
    yield (f"peak_concurrency,"
           f"{res['metrics']['single']['peak_concurrency']},"
           f"{res['metrics']['sharded']['peak_concurrency']}")
    yield (f"goodput_req_per_1k_steps,"
           f"{res['metrics']['single']['goodput_req_per_1k_steps']:.1f},"
           f"{res['metrics']['sharded']['goodput_req_per_1k_steps']:.1f}")
    yield (f"pool_tokens,{res['capacity']['pool_tokens']['single']},"
           f"{res['capacity']['pool_tokens']['sharded']}")
    yield (f"per_device_cache_bytes,"
           f"{res['capacity']['per_device_cache_bytes']},=")
    yield f"peak_gain,1.00,{res['peak_gain']:.2f}"
    yield f"goodput_gain,1.00,{res['goodput_gain']:.2f}"
    yield f"identical_streams,=,{res['identical_streams']}"
    yield f"bit_reproducible,=,{res['bit_reproducible']}"


def _spec():
    from benchmarks import speculative
    r = speculative.run(arch="qwen1.5-0.5b", layers=1, spec_k=3,
                        max_len=128, block_size=8, num_blocks=96,
                        n_requests=8, max_new=24, max_batch=6,
                        require_gain=1.5, out_json="BENCH_serving.json")
    res = r["results"]
    yield "metric,target_only,speculative"
    yield (f"tokens_per_step,{res['tokens_per_step']['target_only']:.2f},"
           f"{res['tokens_per_step']['speculative']:.2f}")
    yield (f"steps,{res['steps']['target_only']},"
           f"{res['steps']['speculative']}")
    yield f"mean_accepted_len,=,{res['mean_accepted_len']:.2f}"
    yield f"gain,1.00,{res['gain']:.2f}"
    yield f"identical_streams,=,{res['identical_streams']}"
    yield f"deterministic_replay,=,{res['deterministic_replay']}"


def _figure(module: str):
    def fn():
        import importlib
        return importlib.import_module(f"benchmarks.{module}").run()
    return fn


SECTIONS = [
    ("table1", _figure("table1_throughput")),
    ("table2", _figure("table2_analytical")),
    ("fig5", _figure("fig5_tilesize")),
    ("fig8", _figure("fig8_heads")),
    ("fig11", _figure("fig11_portability")),
    ("fig12", _figure("fig12_roofline")),
    ("fleet", _fleet),
    ("serving", _serving),
    ("qcache", _qcache),
    ("prefix", _prefix),
    ("harness", _harness),
    ("sharded", _sharded),
    ("spec", _spec),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run just this section")
    ap.add_argument("--devices", type=int, default=4,
                    help="host-platform device count to force before jax "
                         "initializes (the sharded section needs tp*dp)")
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--dp", type=int, default=2)
    args = ap.parse_args()
    MESH["tp"], MESH["dp"] = args.tp, args.dp
    ensure_host_devices(max(args.devices, args.tp * args.dp))
    failures = 0
    for name, fn in SECTIONS:
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"== {name} ==", flush=True)
        try:
            for line in fn():
                print(line)
        except Exception:
            failures += 1
            print(f"{name},ERROR")
            traceback.print_exc()
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
