"""Benchmark driver: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` prints CSV blocks:
  table1  — throughput/efficiency per network (GOPS analogues)
  table2  — analytical model vs compiled HLO (% error)
  fig5    — tile-size sweep (VMEM fit / occupancy / modeled latency)
  fig8    — runtime heads-register sweep on one compiled engine
  fig11   — portability: tile re-planning across memory budgets
  fig12   — the 40-cell roofline table from the dry-run records
  fleet   — multi-topology serving vs per-model engines (equal memory)
  serving — chunked prefill vs bucketed (TTFT / tok/s; BENCH_serving.json)
  qcache  — int8 vs bf16 KV cache at equal HBM (concurrency / drain)
  prefix  — prefix-cached pool vs no sharing (warm TTFT / concurrency)
  harness — tuned spec vs naive default at equal memory (load harness)
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (chunked_prefill, fig5_tilesize, fig8_heads,
                        fig11_portability, fig12_roofline, load_harness,
                        multi_topology, prefix_cache, quantized_cache,
                        table1_throughput, table2_analytical)


def _fleet():
    r = multi_topology.run(max_batch=4, max_len=64, n_per_model=5,
                           max_new=4, layers=1)
    yield "metric,fleet,two_engines"
    yield f"fused_steps,{r['fleet_steps']},{r['solo_steps']}"
    yield f"wall_s,{r['fleet_wall']:.2f},{r['solo_wall']:.2f}"


def _serving():
    r = chunked_prefill.run(arch="qwen1.5-0.5b", layers=1, max_batch=4,
                            max_len=64, chunk=16, budget=32, max_new=4,
                            require_speedup=None,
                            out_json="BENCH_serving.json")
    res = r["results"]
    yield "metric,bucketed,chunked"
    for key in ("ttft_short", "ttft_long"):
        yield (f"{key}_warm,{res['phases']['bucketed']['warm'][key]:.4f},"
               f"{res['phases']['chunked']['warm'][key]:.4f}")
    yield ("drain_toks_per_s,"
           f"{res['drain_toks_per_s']['bucketed']:.1f},"
           f"{res['drain_toks_per_s']['chunked']:.1f}")
    yield ("prefill_compilations,"
           f"{res['compilations']['bucketed']['prefill']},"
           f"{res['compilations']['chunked']['prefill']}")


def _qcache():
    r = quantized_cache.run(arch="qwen1.5-0.5b", layers=1, head_dim=64,
                            max_len=64, budget_blocks=24, block_size=8,
                            n_requests=36, max_batch=48, require_gain=1.8,
                            out_json="BENCH_serving.json",
                            require_identical=1.0)
    res = r["results"]
    yield "metric,bf16_cache,int8_cache"
    yield (f"peak_concurrency,{res['peak_concurrency']['compute']},"
           f"{res['peak_concurrency']['int8']}")
    yield (f"steps_to_drain,{res['steps_to_drain']['compute']},"
           f"{res['steps_to_drain']['int8']}")
    yield f"concurrency_gain,1.00,{res['concurrency_gain']:.2f}"


def _prefix():
    r = prefix_cache.run(arch="qwen1.5-0.5b", layers=1, max_len=128,
                         block_size=8, num_blocks=40, n_requests=15,
                         max_batch=24, require_ttft=2.0, require_peak=1.5,
                         out_json="BENCH_serving.json")
    res = r["results"]
    yield "metric,sharing_off,sharing_on"
    yield (f"warm_ttft_s,{res['warm_ttft']['sharing-off']['seconds']:.4f},"
           f"{res['warm_ttft']['sharing-on']['seconds']:.4f}")
    yield (f"peak_concurrency,{res['peak_concurrency']['sharing-off']},"
           f"{res['peak_concurrency']['sharing-on']}")
    yield (f"steps_to_drain,{res['steps_to_drain']['sharing-off']},"
           f"{res['steps_to_drain']['sharing-on']}")
    yield f"identical_streams,{res['identical_streams']},="


def _harness():
    r = load_harness.run(arch="qwen1.5-0.5b", layers=1, n_requests=24,
                         burst_size=12, gap_steps=16, max_len=64, max_new=4,
                         naive_batch=8, slo_ttft_steps=12,
                         require_goodput_gain=1.2,
                         out_json="BENCH_serving.json")
    res = r["results"]
    m = res["metrics"]
    yield "metric,naive,tuned"
    yield (f"goodput_req_per_1k_steps,"
           f"{m['naive']['goodput_req_per_1k_steps']:.1f},"
           f"{m['tuned']['goodput_req_per_1k_steps']:.1f}")
    yield (f"slo_met,{m['naive']['n_slo_met']}/{m['naive']['n_requests']},"
           f"{m['tuned']['n_slo_met']}/{m['tuned']['n_requests']}")
    yield (f"ttft_steps_p99,{m['naive']['ttft_steps_p99']},"
           f"{m['tuned']['ttft_steps_p99']}")
    yield (f"peak_concurrency,{m['naive']['peak_concurrency']},"
           f"{m['tuned']['peak_concurrency']}")
    yield f"goodput_gain,1.00,{res['goodput_gain']:.2f}"
    yield f"bit_reproducible,=,{res['bit_reproducible']}"


SECTIONS = [
    ("table1", table1_throughput.run),
    ("table2", table2_analytical.run),
    ("fig5", fig5_tilesize.run),
    ("fig8", fig8_heads.run),
    ("fig11", fig11_portability.run),
    ("fig12", fig12_roofline.run),
    ("fleet", _fleet),
    ("serving", _serving),
    ("qcache", _qcache),
    ("prefix", _prefix),
    ("harness", _harness),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for name, fn in SECTIONS:
        if only and name != only:
            continue
        t0 = time.perf_counter()
        print(f"== {name} ==", flush=True)
        try:
            for line in fn():
                print(line)
        except Exception:
            failures += 1
            print(f"{name},ERROR")
            traceback.print_exc()
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
