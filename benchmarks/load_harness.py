"""Close the resource-allocation loop: tuned spec vs naive default,
measured by the trace-driven load harness at equal cache memory.

The autotuner (``repro.harness.tune``, surfaced as
``RuntimeSpec.tuned``) ranks runtime configurations with the
``core.analytical`` roofline model — no engine is built while tuning.
This benchmark is the check the paper performs with its AXI timers: give
the tuner exactly the cache bytes the naive hand-picked spec spends, let
both replay the same bursty mixed-length trace through the harness
driver, and compare goodput under a step-based SLO.  Every gated number
is step-arithmetic (deterministic); wall numbers are reported alongside.

The same replay also doubles as the harness reproducibility check: the
tuned configuration is replayed twice on fresh engines and the
deterministic metrics view must serialize to identical bytes.

    PYTHONPATH=src python benchmarks/load_harness.py
    PYTHONPATH=src python benchmarks/load_harness.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

try:                                   # package form (benchmarks.run)
    from benchmarks._util import write_payload
except ModuleNotFoundError:            # direct script invocation
    from _util import write_payload

from repro.configs import REGISTRY, reduced
from repro.core.spec import MemorySpec, RuntimeSpec, SchedulerSpec
from repro.harness import (SLO, DeviceProfile, WorkloadProfile,
                           bursty_trace, replay, tune)
from repro.harness.tune import cache_bytes
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def _measure(spec: RuntimeSpec, params, trace, slo: SLO):
    eng = ServingEngine(spec, sampling=SamplingParams())
    eng.load(params)
    return replay(eng, trace, slo=slo)


def run(arch: str, layers: int | None, n_requests: int, burst_size: int,
        gap_steps: int, max_len: int, max_new: int, naive_batch: int,
        slo_ttft_steps: int, require_goodput_gain: float | None,
        out_json: str | None, seed: int = 11) -> dict:
    cfg = reduced(REGISTRY[arch])
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    params = Model(cfg).init(jax.random.PRNGKey(0))

    trace = bursty_trace(n_requests, burst_size=burst_size,
                         gap_steps=gap_steps, max_len=3 * max_len // 4,
                         max_new=max_new, seed=seed)
    slo = SLO(ttft_steps=slo_ttft_steps)

    # the naive hand-picked spec: dense layout, stock batch, default
    # scheduler — what every benchmark in this repo used to hard-code
    naive = RuntimeSpec(arch=cfg,
                        memory=MemorySpec(cache_layout="dense",
                                          max_batch=naive_batch,
                                          max_len=max_len),
                        scheduler=SchedulerSpec(policy="auto"))
    budget = cache_bytes(naive)

    # the tuner gets the trace's own statistics and EXACTLY the naive
    # spec's cache bytes — any win is allocation, not extra HBM
    result = tune(cfg, DeviceProfile(cache_budget_bytes=budget),
                  WorkloadProfile.from_trace(trace), max_len=max_len)
    tuned = result.spec
    assert tuned.validate() is tuned
    assert cache_bytes(tuned) <= budget, (
        f"tuned spec spends {cache_bytes(tuned)} cache bytes over the "
        f"naive budget {budget}")

    res = {"naive": _measure(naive, params, trace, slo),
           "tuned": _measure(tuned, params, trace, slo)}
    # reproducibility: a second fresh-engine replay of the tuned spec
    # must produce byte-identical deterministic metrics
    repro_json = _measure(tuned, params, trace, slo).metrics
    bit_identical = (res["tuned"].metrics.deterministic_json()
                     == repro_json.deterministic_json())

    mm = {k: r.metrics for k, r in res.items()}
    gain = mm["tuned"].goodput_req_per_1k_steps \
        / max(mm["naive"].goodput_req_per_1k_steps, 1e-9)

    print(f"arch={cfg.name}  trace: {n_requests} requests in bursts of "
          f"{burst_size} every {gap_steps} steps, mixed prompts, "
          f"SLO ttft<={slo_ttft_steps} steps, equal cache budget "
          f"{budget / 2**20:.2f} MiB")
    t = tuned.memory
    print(f"  tuned pick: {t.cache_layout} max_batch={t.max_batch} "
          f"block={t.block_size if t.cache_layout == 'paged' else '-'} "
          f"policy={tuned.scheduler.policy} "
          f"chunk={tuned.scheduler.chunk_size} "
          f"budget={tuned.scheduler.resolved_token_budget} "
          f"(ranked {len(result.ranked)} candidates)")
    for k in ("naive", "tuned"):
        m = mm[k]
        print(f"  {k:6s} slo_met {m.n_slo_met:3d}/{m.n_requests}   "
              f"goodput {m.goodput_req_per_1k_steps:7.1f} req/1k-steps "
              f"({m.goodput_req_s:6.2f} req/s)   TTFT p50/p99 "
              f"{m.ttft_steps_p50}/{m.ttft_steps_p99} steps   peak "
              f"{m.peak_concurrency:3d}   preempt {m.n_preemptions}")
    print(f"  goodput gain {gain:.2f}x at equal memory; deterministic "
          f"metrics bit-identical across replays: {bit_identical}")

    assert bit_identical, (
        "two fresh-engine replays of the same trace+spec produced "
        "different deterministic metrics — the harness step clock leaked "
        "wall time")
    if require_goodput_gain is not None:
        assert gain >= require_goodput_gain, (
            f"tuned goodput gain {gain:.2f}x below the required "
            f"{require_goodput_gain:.2f}x at equal cache memory")

    results_out = {
        "budget_bytes": budget,
        "tuned_pick": result.best.summary(),
        "candidates_ranked": len(result.ranked),
        "metrics": {k: mm[k].deterministic() for k in mm},
        "wall": {k: {"goodput_req_s": mm[k].goodput_req_s,
                     "ttft_s_p50": mm[k].ttft_s_p50,
                     "wall_s": mm[k].wall_s} for k in mm},
        "goodput_gain": gain,
        "bit_reproducible": bit_identical,
    }
    payload = {"benchmark": "harness", "results": results_out}
    if out_json:
        payload = write_payload(
            out_json, "harness", arch=cfg.name,
            config={"n_requests": n_requests, "burst_size": burst_size,
                    "gap_steps": gap_steps, "max_len": max_len,
                    "max_new": max_new, "naive_batch": naive_batch,
                    "slo_ttft_steps": slo_ttft_steps, "trace_seed": seed},
            results=results_out)
        print(f"  appended to {out_json}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--burst", type=int, default=16)
    ap.add_argument("--gap", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--naive-batch", type=int, default=8)
    ap.add_argument("--slo-ttft-steps", type=int, default=16)
    ap.add_argument("--trace-seed", type=int, default=11)
    ap.add_argument("--require-goodput-gain", type=float, default=1.2,
                    help="fail unless tuned goodput beats naive this much "
                         "at equal cache memory (step-based, deterministic)")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, short trace (gates kept — "
                         "they are deterministic step arithmetic)")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.requests, args.burst, args.gap = 1, 24, 12, 16
        args.max_len, args.max_new = 64, 4
        args.slo_ttft_steps = 12
    run(args.arch, args.layers, args.requests, args.burst, args.gap,
        args.max_len, args.max_new, args.naive_batch, args.slo_ttft_steps,
        args.require_goodput_gain, args.json, seed=args.trace_seed)


if __name__ == "__main__":
    main()
