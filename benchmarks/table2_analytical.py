"""Table 2 analogue: analytical model vs compiled artifact.

The paper validates its Eq. 9-39 latency model against AXI-timer
measurements (1.8% error).  Here the analytical per-module FLOP model
(core/analytical.step_flops) is validated against the compiled HLO's
cost_analysis for the paper's own evaluation networks at Table 2's
(sequence, embedding) points — forward pass, unrolled layers, 1 chip.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.analytical import step_flops
from repro.models import backend
from repro.models.model import Model, ModelOptions

# Table 2 rows: (network, seq_len, d_model override)
ROWS = [
    ("adaptor-bert", 64, 768),
    ("adaptor-bert", 128, 768),
    ("adaptor-bert", 64, 512),
    ("shallow-transformer", 64, 512),
    ("custom-encoder", 64, 200),
]


def run() -> list[str]:
    out = ["table2,network,seq,d_model,analytical_gflops,hlo_gflops,err_pct"]
    for name, seq, dm in ROWS:
        cfg = get_config(name)
        if dm != cfg.d_model:
            heads = cfg.num_heads
            cfg = dataclasses.replace(cfg, d_model=dm, head_dim=dm // heads,
                                      d_ff=4 * dm)
        shape = ShapeSpec("bench", seq, 1, "prefill")
        model = Model(cfg, ModelOptions(unroll_layers=True))
        t0 = time.perf_counter()
        with backend.faithful():
            lowered = jax.jit(model.forward).lower(
                model.abstract(),
                {"tokens": jax.ShapeDtypeStruct((1, seq), jax.numpy.int32)})
            compiled = lowered.compile()
        hlo = float(compiled.cost_analysis().get("flops", 0.0))
        ana = step_flops(cfg, shape)["total"]
        err = 100.0 * abs(ana - hlo) / max(hlo, 1.0)
        out.append(f"table2,{name},{seq},{dm},{ana / 1e9:.3f},"
                   f"{hlo / 1e9:.3f},{err:.1f}")
        out.append(f"# compile {time.perf_counter() - t0:.1f}s")
    return out


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
