"""Mixed-fleet serving vs per-model engines, at equal memory.

One register-driven fabric (``serving.fabric``) serves two
differently-shaped models from ONE compiled decode step; the baseline
runs one single-topology engine per model *sequentially* (so at any
instant both setups hold one maxima-shaped KV cache — equal memory).

What the fabric buys:

* **one compilation** — the sequential baseline traces a decode step per
  model; the fleet engine traces once and reprograms registers.
* **merged drain tails** — each per-model engine ends its run with
  partially-empty batches; the mixed fleet back-fills those slots with
  the other model's requests, so the same token work takes fewer fused
  steps.
* **bit-identical streams** — asserted per request: multi-topology
  batching is a scheduling win, not an approximation.

    PYTHONPATH=src python benchmarks/multi_topology.py
    PYTHONPATH=src python benchmarks/multi_topology.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import REGISTRY, reduced
from repro.core.spec import MemorySpec, RuntimeSpec, maxima_for
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def _fleet_archs(max_len: int, layers: int | None):
    a = reduced(REGISTRY["qwen1.5-0.5b"])
    if layers is not None:
        a = dataclasses.replace(a, num_layers=layers)
    # a second, smaller topology on every adaptive axis (heads / layers /
    # d_model / d_ff / vocab) sharing the structural template
    b = dataclasses.replace(
        a, name="half-width", d_model=48, num_heads=3, num_kv_heads=3,
        d_ff=96, vocab_size=96, num_layers=max(1, a.num_layers - 1))
    return a, b, maxima_for(a, b, seq_max=max_len)


def _requests(n: int, vocab: int, max_len: int, max_new: int):
    return [(list(range(1 + i % 7, 4 + i % 7 + i % (max_len // 8))),
             2 + (i * 3) % max_new) for i in range(n)]


def _engine(arch, maxima, max_batch, max_len):
    spec = RuntimeSpec(arch=arch, maxima=maxima,
                       memory=MemorySpec(max_batch=max_batch,
                                         max_len=max_len))
    return ServingEngine(spec, max_models=2, sampling=SamplingParams())


def _drain(eng, submitted):
    t0 = time.perf_counter()
    done = eng.run_to_completion()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    assert len(done) == len(submitted)
    return ({submitted[r.uid]: r.generated for r in done}, toks, wall,
            eng.stats["decode_steps"])


def run(max_batch: int, max_len: int, n_per_model: int, max_new: int,
        layers: int | None) -> dict:
    cfg_a, cfg_b, maxima = _fleet_archs(max_len, layers)
    params_a = Model(cfg_a).init(jax.random.PRNGKey(0))
    params_b = Model(cfg_b).init(jax.random.PRNGKey(1))
    reqs_a = _requests(n_per_model, cfg_a.vocab_size, max_len, max_new)
    reqs_b = _requests(n_per_model, cfg_b.vocab_size, max_len, max_new)

    # -- mixed fleet: one engine, one compiled step, interleaved models --
    fleet = _engine(cfg_a, maxima, max_batch, max_len)
    ids = {"a": fleet.add_model(params_a, cfg_a),
           "b": fleet.add_model(params_b, cfg_b)}
    sub = {}
    for i in range(n_per_model):
        for name, (p, budget) in (("a", reqs_a[i]), ("b", reqs_b[i])):
            uid = fleet.submit(p, max_new_tokens=budget, model=ids[name])
            sub[uid] = (name, i)
    fleet_done, fleet_toks, fleet_wall, fleet_steps = _drain(fleet, sub)

    # -- baseline: one single-topology engine per model, run sequentially
    # (equal memory: one maxima-shaped cache live at a time) --------------
    solo_done, solo_toks, solo_wall, solo_steps, compiles = {}, 0, 0.0, 0, 0
    for name, cfg, params, reqs in (("a", cfg_a, params_a, reqs_a),
                                    ("b", cfg_b, params_b, reqs_b)):
        eng = _engine(cfg, maxima, max_batch, max_len)
        mid = eng.add_model(params, cfg)
        sub = {eng.submit(p, max_new_tokens=budget, model=mid): (name, i)
               for i, (p, budget) in enumerate(reqs)}
        done, toks, wall, steps = _drain(eng, sub)
        solo_done.update(done)
        solo_toks += toks
        solo_wall += wall
        solo_steps += steps
        compiles += eng.compilations["decode"]

    same = fleet_done == solo_done
    print(f"fleet: {cfg_a.name} + {cfg_b.name} under shared maxima "
          f"(d={maxima.d_model_max}, H={maxima.heads_max}, "
          f"L={maxima.layers_enc_max}, V={maxima.vocab}); "
          f"max_batch={max_batch}, {2 * n_per_model} requests")
    print(f"  mixed fleet : {fleet_toks:4d} tokens  {fleet_steps:4d} fused "
          f"steps  {fleet_wall:6.2f}s  "
          f"({fleet_toks / max(fleet_wall, 1e-9):7.1f} tok/s)  "
          f"decode compiles = {fleet.compilations['decode']}")
    print(f"  2 engines   : {solo_toks:4d} tokens  {solo_steps:4d} fused "
          f"steps  {solo_wall:6.2f}s  "
          f"({solo_toks / max(solo_wall, 1e-9):7.1f} tok/s)  "
          f"decode compiles = {compiles}")
    print(f"  streams bit-identical: {same}   "
          f"step reduction {solo_steps / max(fleet_steps, 1):.2f}x   "
          f"throughput {solo_wall / max(fleet_wall, 1e-9):.2f}x")
    assert same, "fleet streams diverged from single-topology engines"
    assert fleet.compilations["decode"] == 1
    assert fleet_steps <= solo_steps, (
        f"mixed fleet took {fleet_steps} steps vs {solo_steps} sequential")
    return {"fleet_steps": fleet_steps, "solo_steps": solo_steps,
            "fleet_wall": fleet_wall, "solo_wall": solo_wall}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests-per-model", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, tiny trace")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.requests_per_model, args.max_new = 1, 5, 4
    run(args.max_batch, args.max_len, args.requests_per_model, args.max_new,
        args.layers)


if __name__ == "__main__":
    main()
