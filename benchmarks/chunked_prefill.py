"""Chunked prefill vs bucketed prefill: TTFT and decode stalls when a
long prompt arrives at a busy engine.

The bucketed engine prefills an admitted prompt in one indivisible B=1
dispatch (padded to its power-of-two bucket, one compilation per
bucket): when a long prompt arrives, every decoding slot stalls for the
whole dispatch, and a short prompt admitted behind it waits for it too.
The chunked scheduler feeds the same prompt through the ONE fused mixed
step in ``chunk_size`` chunks under a per-step ``token_budget`` with a
fair share per prefilling slot, so the short prompt's first token and
the background streams' next tokens are only ever one fused step away.

Scenario (per measured phase): two background requests decode steadily;
at t0 a long prompt (3/4 max_len) and a short prompt arrive together.
Measured: TTFT of both arrivals and the worst inter-token gap of the
background streams while the long prompt prefills.  The cold phase
includes compilations triggered by the arrivals — for the bucketed
engine that is the long prompt's fresh bucket, for the chunked engine
nothing (the paper's no-recompilation claim); the warm phase repeats the
arrivals with everything compiled.  A separate correctness pass replays
a mixed trace on both engines and asserts bit-identical greedy streams.
Results land in ``BENCH_serving.json`` so the perf trajectory stays
machine-readable.

    PYTHONPATH=src python benchmarks/chunked_prefill.py
    PYTHONPATH=src python benchmarks/chunked_prefill.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

try:                                   # package form (benchmarks.run)
    from benchmarks._util import write_payload
except ModuleNotFoundError:            # direct script invocation
    from _util import write_payload

from repro.configs import REGISTRY, reduced
from repro.core.spec import MemorySpec, RuntimeSpec, SchedulerSpec
from repro.harness import replay, scripted_trace
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def _prompt(rng, n):
    return [1 + int(t) for t in rng.randint(0, 50, size=n)]


def build(cfg, params, policy, max_batch, max_len, chunk, budget):
    spec = RuntimeSpec(
        arch=cfg,
        memory=MemorySpec(max_batch=max_batch, max_len=max_len),
        scheduler=SchedulerSpec(policy=policy, chunk_size=chunk,
                                token_budget=budget))
    eng = ServingEngine(spec, sampling=SamplingParams())
    eng.load(params)
    return eng


def _wall_ttft(events, uid: int) -> float:
    """Completion-honest wall TTFT: first ``progress`` with a token minus
    ``submit`` (the harness's TTFT-seconds definition)."""
    sub = next(e for e in events if e.uid == uid and e.kind == "submit")
    first = next(e for e in events if e.uid == uid and e.kind == "progress"
                 and e.data["count"] >= 1)
    return first.t - sub.t


def arrival_phase(eng: ServingEngine, max_len: int, max_new: int,
                  seed: int) -> dict:
    """Seed two background decoders, then land a long + short arrival and
    time their first tokens plus the background streams' worst stall.
    The scenario is a scripted harness trace (background at step 0,
    arrivals at step 3); every measurement reads the engine's lifecycle
    events instead of hand-polling device counts."""
    rng = np.random.RandomState(seed)
    rows = [(0, _prompt(rng, 6), 4 * max_new),
            (0, _prompt(rng, 6), 4 * max_new),
            (3, _prompt(rng, 3 * max_len // 4), max_new),
            (3, _prompt(rng, max(max_len // 16, 4)), max_new)]
    res = replay(eng, scripted_trace(rows, name="arrival"))
    uid_of = {rid: uid for uid, rid in res.uid_to_rid.items()}
    u_long, u_short = uid_of[2], uid_of[3]
    ttft = {u: _wall_ttft(res.events, u) for u in (u_long, u_short)}
    firsts = [next(e for e in res.events if e.uid == u
                   and e.kind == "progress" and e.data["count"] >= 1)
              for u in (u_long, u_short)]
    t_first = max(e.t for e in firsts)
    arrival = next(e for e in res.events if e.uid == u_long
                   and e.kind == "submit")
    t_arrival = arrival.t
    # background stall: widest gap between consecutive token-count
    # advances of a background stream while the arrivals prefill
    gaps = []
    for bg_rid in (0, 1):
        u = uid_of[bg_rid]
        stamps = [t_arrival]
        prev = None
        for e in res.events:
            if e.uid != u or e.kind != "progress":
                continue
            if prev is None or e.data["count"] != prev:
                prev = e.data["count"]
                if e.t <= t_first:
                    stamps.append(e.t)
        gaps += [b - a for a, b in zip(stamps, stamps[1:])]
    return {"ttft_short": ttft[u_short], "ttft_long": ttft[u_long],
            "bg_itl_max": max(gaps),
            "steps_to_first_tokens":
                max(e.step for e in firsts) - arrival.step}


def correctness_pass(cfg, params, policies, max_batch, max_len, chunk,
                     budget, max_new, seed: int = 7) -> dict:
    """Replay one mixed trace on both engines: greedy streams must be
    bit-identical; also yields drain throughput at equal memory."""
    rng = np.random.RandomState(seed)
    prompts = [_prompt(rng, 3 * max_len // 4), _prompt(rng, 5),
               _prompt(rng, max_len // 4), _prompt(rng, 9),
               _prompt(rng, max_len // 2), _prompt(rng, 12)]
    trace = scripted_trace([(0, p, max_new) for p in prompts],
                           name="correctness")
    out = {}
    for policy in policies:
        eng = build(cfg, params, policy, max_batch, max_len, chunk, budget)
        res = replay(eng, trace)
        done = {res.uid_to_rid[r.uid]: r.generated for r in res.finished}
        out[policy] = {"streams": [done[rid] for rid in range(len(prompts))],
                       "toks_per_s": res.metrics.tokens_per_s,
                       "compilations": dict(eng.compilations())}
    assert out[policies[0]]["streams"] == out[policies[1]]["streams"], \
        "chunked streams diverged from the bucketed baseline"
    return out


def run(arch: str, layers: int | None, max_batch: int, max_len: int,
        chunk: int, budget: int, max_new: int,
        require_speedup: float | None, out_json: str) -> dict:
    cfg = reduced(REGISTRY[arch])
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    policies = ("bucketed", "chunked")
    results = {}
    for policy in policies:
        eng = build(cfg, params, policy, max_batch, max_len, chunk, budget)
        # cold: the arrivals trigger any not-yet-compiled programs (the
        # long prompt's fresh bucket on the bucketed engine; nothing on
        # the chunked engine — its one step compiled at background admit)
        cold = arrival_phase(eng, max_len, max_new, seed=1)
        warm = arrival_phase(eng, max_len, max_new, seed=2)
        results[policy] = {"cold": cold, "warm": warm}

    check = correctness_pass(cfg, params, policies, max_batch, max_len,
                             chunk, budget, max_new)

    b, c = results["bucketed"], results["chunked"]
    speedups = {
        "ttft_short_cold": b["cold"]["ttft_short"] / c["cold"]["ttft_short"],
        "ttft_short_warm": b["warm"]["ttft_short"] / c["warm"]["ttft_short"],
        "bg_itl_max_warm": b["warm"]["bg_itl_max"] / c["warm"]["bg_itl_max"],
    }

    print(f"arch={cfg.name}  max_batch={max_batch} max_len={max_len}  "
          f"chunk={chunk} budget={budget}  arrival: "
          f"{3 * max_len // 4}-token long + {max(max_len // 16, 4)}-token "
          f"short into a busy engine")
    for policy in policies:
        r, comp = results[policy], check[policy]["compilations"]
        print(f"  {policy:8s} cold: TTFT(short) "
              f"{r['cold']['ttft_short'] * 1e3:7.1f} ms  TTFT(long) "
              f"{r['cold']['ttft_long'] * 1e3:7.1f} ms   warm: TTFT(short) "
              f"{r['warm']['ttft_short'] * 1e3:7.1f} ms  bg stall(max) "
              f"{r['warm']['bg_itl_max'] * 1e3:7.1f} ms   drain "
              f"{check[policy]['toks_per_s']:6.1f} tok/s  "
              f"compilations prefill={comp['prefill']} "
              f"decode={comp['decode']}")
    print(f"  TTFT(short) speedup: {speedups['ttft_short_cold']:.2f}x cold "
          f"(compiles included), {speedups['ttft_short_warm']:.2f}x warm; "
          f"background decode stall shrinks "
          f"{speedups['bg_itl_max_warm']:.2f}x; streams bit-identical")

    payload = write_payload(
        out_json, "chunked_prefill", arch=cfg.name,
        config={"max_batch": max_batch, "max_len": max_len,
                "chunk_size": chunk, "token_budget": budget,
                "max_new": max_new},
        results={
            "phases": results,
            "speedups": speedups,
            "drain_toks_per_s": {p: check[p]["toks_per_s"]
                                 for p in policies},
            "compilations": {p: check[p]["compilations"] for p in policies},
            "streams_bit_identical": True,
        })
    print(f"  wrote {out_json} (key 'chunked_prefill')")
    if require_speedup is not None:
        got = speedups["ttft_short_warm"]
        assert got >= require_speedup, (
            f"warm TTFT(short) speedup {got:.2f}x below the required "
            f"{require_speedup:.2f}x (cold: "
            f"{speedups['ttft_short_cold']:.2f}x)")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--require-speedup", type=float, default=1.5,
                    help="fail unless short-prompt TTFT improves this much")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, small shapes, no speedup gate")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.max_len, args.chunk, args.budget = 1, 64, 16, 32
        args.max_new = 4
        args.require_speedup = None
    run(args.arch, args.layers, args.max_batch, args.max_len, args.chunk,
        args.budget, args.max_new, args.require_speedup, args.json)


if __name__ == "__main__":
    main()
