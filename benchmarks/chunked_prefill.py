"""Chunked prefill vs bucketed prefill: TTFT and decode stalls when a
long prompt arrives at a busy engine.

The bucketed engine prefills an admitted prompt in one indivisible B=1
dispatch (padded to its power-of-two bucket, one compilation per
bucket): when a long prompt arrives, every decoding slot stalls for the
whole dispatch, and a short prompt admitted behind it waits for it too.
The chunked scheduler feeds the same prompt through the ONE fused mixed
step in ``chunk_size`` chunks under a per-step ``token_budget`` with a
fair share per prefilling slot, so the short prompt's first token and
the background streams' next tokens are only ever one fused step away.

Scenario (per measured phase): two background requests decode steadily;
at t0 a long prompt (3/4 max_len) and a short prompt arrive together.
Measured: TTFT of both arrivals and the worst inter-token gap of the
background streams while the long prompt prefills.  The cold phase
includes compilations triggered by the arrivals — for the bucketed
engine that is the long prompt's fresh bucket, for the chunked engine
nothing (the paper's no-recompilation claim); the warm phase repeats the
arrivals with everything compiled.  A separate correctness pass replays
a mixed trace on both engines and asserts bit-identical greedy streams.
Results land in ``BENCH_serving.json`` so the perf trajectory stays
machine-readable.

    PYTHONPATH=src python benchmarks/chunked_prefill.py
    PYTHONPATH=src python benchmarks/chunked_prefill.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

try:                                   # package form (benchmarks.run)
    from benchmarks._util import append_json
except ModuleNotFoundError:            # direct script invocation
    from _util import append_json

from repro.configs import REGISTRY, reduced
from repro.core.spec import MemorySpec, RuntimeSpec, SchedulerSpec
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def _prompt(rng, n):
    return [1 + int(t) for t in rng.randint(0, 50, size=n)]


def build(cfg, params, policy, max_batch, max_len, chunk, budget):
    spec = RuntimeSpec(
        arch=cfg,
        memory=MemorySpec(max_batch=max_batch, max_len=max_len),
        scheduler=SchedulerSpec(policy=policy, chunk_size=chunk,
                                token_budget=budget))
    eng = ServingEngine(spec, sampling=SamplingParams())
    eng.load(params)
    return eng


def arrival_phase(eng: ServingEngine, max_len: int, max_new: int,
                  seed: int) -> dict:
    """Seed two background decoders, then land a long + short arrival and
    time their first tokens plus the background streams' worst stall."""
    rng = np.random.RandomState(seed)
    bg = {eng.submit(_prompt(rng, 6), max_new_tokens=4 * max_new)
          for _ in range(2)}
    for _ in range(3):                       # background reaches steady decode
        eng.step()
    counts = jax.device_get(eng.state.count)
    prev = {req.uid: int(counts[slot])
            for slot, req in enumerate(eng.slot_req) if req is not None}

    t0 = time.perf_counter()
    u_long = eng.submit(_prompt(rng, 3 * max_len // 4),
                        max_new_tokens=max_new)
    u_short = eng.submit(_prompt(rng, max(max_len // 16, 4)),
                         max_new_tokens=max_new)
    ttft: dict[int, float] = {}
    last_emit = {u: t0 for u in bg}
    gaps: list[float] = []
    steps = 0
    while len(ttft) < 2 and steps < 10_000:
        eng.step()
        steps += 1
        now = time.perf_counter()
        counts = jax.device_get(eng.state.count)
        for slot, req in enumerate(eng.slot_req):
            if req is None:
                continue
            c = int(counts[slot])
            if req.uid in (u_long, u_short) and c > 0 \
                    and req.uid not in ttft:
                ttft[req.uid] = now - t0
            if req.uid in bg and c != prev.get(req.uid):
                # the first post-arrival gap IS the admission stall the
                # background stream suffered
                gaps.append(now - last_emit[req.uid])
                prev[req.uid] = c
                last_emit[req.uid] = now
    eng.run_to_completion()                  # drain for the next phase
    return {"ttft_short": ttft[u_short], "ttft_long": ttft[u_long],
            "bg_itl_max": max(gaps), "steps_to_first_tokens": steps}


def correctness_pass(cfg, params, policies, max_batch, max_len, chunk,
                     budget, max_new, seed: int = 7) -> dict:
    """Replay one mixed trace on both engines: greedy streams must be
    bit-identical; also yields drain throughput at equal memory."""
    rng = np.random.RandomState(seed)
    trace = [_prompt(rng, 3 * max_len // 4), _prompt(rng, 5),
             _prompt(rng, max_len // 4), _prompt(rng, 9),
             _prompt(rng, max_len // 2), _prompt(rng, 12)]
    out = {}
    for policy in policies:
        eng = build(cfg, params, policy, max_batch, max_len, chunk, budget)
        uids = [eng.submit(p, max_new_tokens=max_new) for p in trace]
        t0 = time.perf_counter()
        done = {r.uid: r.generated for r in eng.run_to_completion()}
        wall = time.perf_counter() - t0
        toks = sum(len(v) for v in done.values())
        out[policy] = {"streams": [done[u] for u in uids],
                       "toks_per_s": toks / wall,
                       "compilations": dict(eng.compilations())}
    assert out[policies[0]]["streams"] == out[policies[1]]["streams"], \
        "chunked streams diverged from the bucketed baseline"
    return out


def run(arch: str, layers: int | None, max_batch: int, max_len: int,
        chunk: int, budget: int, max_new: int,
        require_speedup: float | None, out_json: str) -> dict:
    cfg = reduced(REGISTRY[arch])
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    policies = ("bucketed", "chunked")
    results = {}
    for policy in policies:
        eng = build(cfg, params, policy, max_batch, max_len, chunk, budget)
        # cold: the arrivals trigger any not-yet-compiled programs (the
        # long prompt's fresh bucket on the bucketed engine; nothing on
        # the chunked engine — its one step compiled at background admit)
        cold = arrival_phase(eng, max_len, max_new, seed=1)
        warm = arrival_phase(eng, max_len, max_new, seed=2)
        results[policy] = {"cold": cold, "warm": warm}

    check = correctness_pass(cfg, params, policies, max_batch, max_len,
                             chunk, budget, max_new)

    b, c = results["bucketed"], results["chunked"]
    speedups = {
        "ttft_short_cold": b["cold"]["ttft_short"] / c["cold"]["ttft_short"],
        "ttft_short_warm": b["warm"]["ttft_short"] / c["warm"]["ttft_short"],
        "bg_itl_max_warm": b["warm"]["bg_itl_max"] / c["warm"]["bg_itl_max"],
    }

    print(f"arch={cfg.name}  max_batch={max_batch} max_len={max_len}  "
          f"chunk={chunk} budget={budget}  arrival: "
          f"{3 * max_len // 4}-token long + {max(max_len // 16, 4)}-token "
          f"short into a busy engine")
    for policy in policies:
        r, comp = results[policy], check[policy]["compilations"]
        print(f"  {policy:8s} cold: TTFT(short) "
              f"{r['cold']['ttft_short'] * 1e3:7.1f} ms  TTFT(long) "
              f"{r['cold']['ttft_long'] * 1e3:7.1f} ms   warm: TTFT(short) "
              f"{r['warm']['ttft_short'] * 1e3:7.1f} ms  bg stall(max) "
              f"{r['warm']['bg_itl_max'] * 1e3:7.1f} ms   drain "
              f"{check[policy]['toks_per_s']:6.1f} tok/s  "
              f"compilations prefill={comp['prefill']} "
              f"decode={comp['decode']}")
    print(f"  TTFT(short) speedup: {speedups['ttft_short_cold']:.2f}x cold "
          f"(compiles included), {speedups['ttft_short_warm']:.2f}x warm; "
          f"background decode stall shrinks "
          f"{speedups['bg_itl_max_warm']:.2f}x; streams bit-identical")

    payload = {
        "benchmark": "chunked_prefill",
        "arch": cfg.name,
        "config": {"max_batch": max_batch, "max_len": max_len,
                   "chunk_size": chunk, "token_budget": budget,
                   "max_new": max_new},
        "results": results,
        "speedups": speedups,
        "drain_toks_per_s": {p: check[p]["toks_per_s"] for p in policies},
        "compilations": {p: check[p]["compilations"] for p in policies},
        "streams_bit_identical": True,
    }
    append_json(out_json, "chunked_prefill", payload)
    print(f"  wrote {out_json} (key 'chunked_prefill')")
    if require_speedup is not None:
        got = speedups["ttft_short_warm"]
        assert got >= require_speedup, (
            f"warm TTFT(short) speedup {got:.2f}x below the required "
            f"{require_speedup:.2f}x (cold: "
            f"{speedups['ttft_short_cold']:.2f}x)")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--require-speedup", type=float, default=1.5,
                    help="fail unless short-prompt TTFT improves this much")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, small shapes, no speedup gate")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.max_len, args.chunk, args.budget = 1, 64, 16, 32
        args.max_new = 4
        args.require_speedup = None
    run(args.arch, args.layers, args.max_batch, args.max_len, args.chunk,
        args.budget, args.max_new, args.require_speedup, args.json)


if __name__ == "__main__":
    main()
