"""Fig. 11 analogue: portability across platforms.

The paper re-picks (TS_MHA, TS_FFN) to fit the same custom encoder onto
U55C / ZCU102 / VC707.  TPU version: the tile planner re-picks BlockSpec
shapes for three on-chip-memory budgets (full v5e VMEM, a half-VMEM
'embedded' proxy, and a quarter-VMEM proxy) and reports the resulting
operating points — same model, no code change, different 'platform'.
"""
from __future__ import annotations


from repro.configs import get_config
from repro.core.analytical import V5E
from repro.core.tiling import plan_matmul

# Budgets chosen to mirror the paper's platform spread: a data-center part
# (U55C, full VMEM), a mid-size part (VC707 ~ 4 MiB usable BRAM) and an
# embedded part (ZCU102 ~ 2 MiB) — the planner must re-pick tiles, exactly
# as the paper re-picks (TS_MHA, TS_FFN) per board.
PLATFORMS = [("u55c-like-64MiB", V5E.vmem_bytes),
             ("vc707-like-4MiB", 4 * 2**20),
             ("zcu102-like-2MiB", 2 * 2**20)]


def run() -> list[str]:
    cfg = get_config("custom-encoder")  # d_model 200, 3 heads — Fig. 11 net
    seq = 64
    out = ["fig11,platform,workload,bm,bk,bn,vmem_mib,t_model_us"]
    for pname, budget in PLATFORMS:
        for wname, (M, K, N) in {
            "mha_proj": (seq, cfg.d_model,
                         cfg.num_heads * (cfg.d_model // cfg.num_heads)),
            "ffn1": (seq, cfg.d_model, cfg.d_ff),
            "ffn1_batched": (seq * 64, cfg.d_model, cfg.d_ff),
        }.items():
            p = plan_matmul(M, K, N, vmem_budget=budget)
            out.append(f"fig11,{pname},{wname},{p.bm},{p.bk},{p.bn},"
                       f"{p.vmem_bytes / 2**20:.1f},{p.t_total * 1e6:.1f}")
    return out


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
