"""Paged vs dense KV cache under the same memory budget.

The dense layout preallocates ``[max_batch, max_len]`` KV rows, so a
fixed memory budget of C cache tokens admits at most ``C // max_len``
concurrent requests — a request of length 40 pays for ``max_len``.  The
paged layout spends the same C tokens as ``C // block_size`` pool blocks
and admits a request when ``ceil(len / block_size)`` blocks are free, so
a mixed-length trace packs many more requests into the same bytes.

Both engines replay the same trace with the same seed; greedy streams
are asserted identical request-by-request (the paged layout is a memory
layout, not an approximation), then the report compares peak admitted
concurrency, steps-to-drain, and fragmentation.

    PYTHONPATH=src python benchmarks/paged_cache.py
    PYTHONPATH=src python benchmarks/paged_cache.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.core.spec import MemorySpec, RuntimeSpec
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def mixed_trace(n: int, max_len: int, seed: int = 0
                ) -> list[tuple[list[int], int]]:
    """Mostly-short prompts with a long tail — the serving regime where
    worst-case preallocation hurts (arXiv:2208.03646's traffic shape)."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        if i % 5 == 4:                       # long tail
            plen = int(rng.randint(max_len // 2, 3 * max_len // 4))
        else:
            plen = int(rng.randint(3, max_len // 8))
        budget = int(rng.randint(2, max_len // 8))
        prompt = [1 + int(t) for t in rng.randint(0, 50, size=plen)]
        reqs.append((prompt, budget))
    return reqs


def drive(eng: ServingEngine, reqs) -> dict:
    for prompt, budget in reqs:
        eng.submit(prompt, max_new_tokens=budget)
    peak, steps, done = 0, 0, []
    while eng.queue or eng._occupied():
        done += eng.step()
        peak = max(peak, len(eng._occupied()))
        steps += 1
    return {"peak": peak, "steps": steps,
            "done": {r.uid: r.generated for r in done}}


def run(arch: str, layers: int | None, max_len: int, budget_tokens: int,
        block_size: int, n_requests: int) -> dict:
    cfg = reduced(REGISTRY[arch])
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = mixed_trace(n_requests, max_len)
    kv_token_bytes = 2 * cfg.num_layers * cfg.num_kv_heads \
        * cfg.resolved_head_dim * 2          # k+v, bf16

    dense_slots = budget_tokens // max_len   # what the budget buys, dense
    eng_d = ServingEngine(model, max_batch=dense_slots, max_len=max_len,
                          sampling=SamplingParams())
    eng_d.load(params)
    dense = drive(eng_d, reqs)

    num_blocks = budget_tokens // block_size  # same bytes, paged
    spec = RuntimeSpec(arch=cfg, memory=MemorySpec(
        cache_layout="paged", max_batch=min(4 * dense_slots, n_requests),
        max_len=max_len, block_size=block_size, num_blocks=num_blocks))
    eng_p = ServingEngine(spec, sampling=SamplingParams())
    eng_p.load(params)
    paged = drive(eng_p, reqs)

    same = all(dense["done"][u] == paged["done"][u] for u in dense["done"])
    print(f"arch={cfg.name}  max_len={max_len}  "
          f"budget={budget_tokens} cache tokens "
          f"({budget_tokens * kv_token_bytes / 2**20:.1f} MiB KV)")
    print(f"  trace: {len(reqs)} requests, prompt lengths "
          f"{min(len(p) for p, _ in reqs)}..{max(len(p) for p, _ in reqs)}")
    print(f"  dense  [{dense_slots:3d} slots x {max_len}]      "
          f"peak concurrency {dense['peak']:3d}   "
          f"steps to drain {dense['steps']:4d}")
    print(f"  paged  [{num_blocks:3d} blocks x {block_size}]      "
          f"peak concurrency {paged['peak']:3d}   "
          f"steps to drain {paged['steps']:4d}   "
          f"preemptions {eng_p.stats['preemptions']}")
    print(f"  streams bit-identical: {same}   "
          f"concurrency gain {paged['peak'] / max(dense['peak'], 1):.2f}x   "
          f"drain speedup {dense['steps'] / max(paged['steps'], 1):.2f}x")
    assert same, "paged streams diverged from dense"
    assert paged["peak"] > dense["peak"], (
        f"paged peak concurrency {paged['peak']} not strictly above "
        f"dense {dense['peak']}")
    return {"dense": dense, "paged": paged}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--budget-tokens", type=int, default=None,
                    help="KV memory budget in cache tokens (both layouts)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, short trace, small max_len")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.max_len, args.requests = 1, 64, 10
        args.block_size = 8
    budget = args.budget_tokens or 4 * args.max_len
    run(args.arch, args.layers, args.max_len, budget, args.block_size,
        args.requests)


if __name__ == "__main__":
    main()
