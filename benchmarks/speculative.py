"""Speculative decoding vs target-only decode, at equal cache bytes.

The fused speculative step (``serving/engine.py``) makes the draft
propose ``k`` tokens and the target verify all ``k + 1`` positions in
one chunk-shaped attend, so a decoding slot can emit up to ``k + 1``
tokens per dispatch instead of 1.  On a greedy trace the emitted
streams are *provably token-identical* to target-only decode (an
accepted proposal IS the target argmax — see README "Speculative
decoding"), so the whole win shows up as fewer fused steps for the same
tokens.

This benchmark self-drafts (draft arch == target arch, same weights):
acceptance is then maximal and the measured gain is the machinery's
ceiling, uncontaminated by draft quality.  Memory is equalized the
honest way — the speculative engine pays for the draft's private dense
cache, so the target-only baseline's paged pool is grown by the same
number of bytes.

Gates (CI runs ``--smoke``):

* tokens/step gain >= ``--require-gain`` (default 1.5x; ISSUE-10's
  acceptance floor) on the greedy trace,
* 100% stream identity vs the target-only engine,
* two fresh-engine replays byte-identical (deterministic metrics JSON
  and token streams) — the per-slot PRNG lanes make speculation
  replayable, not just fast.

    PYTHONPATH=src python benchmarks/speculative.py
    PYTHONPATH=src python benchmarks/speculative.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

try:                                   # package form (benchmarks.run)
    from benchmarks._util import write_payload
except ModuleNotFoundError:            # direct script invocation
    from _util import write_payload

from repro.configs import REGISTRY, reduced
from repro.core.analytical import kv_bytes_per_token
from repro.core.spec import (MemorySpec, RuntimeSpec, SchedulerSpec,
                             SpeculationSpec)
from repro.harness import replay, scripted_trace
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def greedy_trace(n: int, max_len: int, max_new: int,
                 seed: int = 0) -> list[tuple[list[int], int]]:
    """Decode-heavy greedy workload: short mixed prompts, long budgets —
    the regime speculation exists for."""
    rng = np.random.RandomState(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.randint(2, max(max_len // 8, 3)))
        prompt = [1 + int(t) for t in rng.randint(0, 50, size=plen)]
        budget = int(rng.randint(max_new // 2, max_new + 1))
        reqs.append((prompt, min(budget, max_len - plen - 1)))
    return reqs


def build(cfg, params, *, spec_k: int, max_batch: int, max_len: int,
          block_size: int, num_blocks: int) -> ServingEngine:
    speculation = SpeculationSpec(draft_model=cfg, k=spec_k) \
        if spec_k else None
    spec = RuntimeSpec(
        arch=cfg,
        memory=MemorySpec(cache_layout="paged", max_batch=max_batch,
                          max_len=max_len, block_size=block_size,
                          num_blocks=num_blocks),
        scheduler=SchedulerSpec(policy="chunked",
                                chunk_size=max(block_size, spec_k + 1)),
        speculation=speculation)
    eng = ServingEngine(spec, sampling=SamplingParams())   # greedy
    eng.load(params, draft=params if speculation else None)
    return eng


def drive(eng: ServingEngine, reqs) -> dict:
    trace = scripted_trace([(0, p, b) for p, b in reqs], name="spec-greedy")
    res = replay(eng, trace)
    m = res.metrics
    return {"steps": m.steps, "tokens": m.total_new_tokens,
            "tokens_per_step": m.tokens_per_step,
            "mean_accepted_len": m.mean_accepted_len,
            "seconds": m.wall_s, "tok_s": m.tokens_per_s,
            "metrics_json": m.deterministic_json(),
            "done": {res.uid_to_rid[r.uid]: r.generated
                     for r in res.finished}}


def run(arch: str, layers: int | None, spec_k: int, max_len: int,
        block_size: int, num_blocks: int, n_requests: int, max_new: int,
        max_batch: int, require_gain: float | None, out_json: str | None,
        trace_seed: int = 7) -> dict:
    over = {} if layers is None else {"num_layers": layers}
    cfg = reduced(REGISTRY[arch], **over)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    reqs = greedy_trace(n_requests, max_len, max_new, trace_seed)

    # equal cache bytes: the speculative engine carries a private dense
    # draft cache; the target-only baseline gets the same bytes as extra
    # pool blocks
    per_tok = kv_bytes_per_token(cfg, "compute")
    draft_blocks = max_batch * max_len * kv_bytes_per_token(cfg, "compute") \
        // (block_size * per_tok)
    base = build(cfg, params, spec_k=0, max_batch=max_batch,
                 max_len=max_len, block_size=block_size,
                 num_blocks=num_blocks + int(draft_blocks))
    r_base = drive(base, reqs)

    spec = build(cfg, params, spec_k=spec_k, max_batch=max_batch,
                 max_len=max_len, block_size=block_size,
                 num_blocks=num_blocks)
    r_spec = drive(spec, reqs)

    # determinism: a second fresh engine must replay byte-identically
    spec2 = build(cfg, params, spec_k=spec_k, max_batch=max_batch,
                  max_len=max_len, block_size=block_size,
                  num_blocks=num_blocks)
    r_spec2 = drive(spec2, reqs)

    n_same = sum(r_base["done"][u] == r_spec["done"][u]
                 for u in r_base["done"])
    gain = r_spec["tokens_per_step"] / max(r_base["tokens_per_step"], 1e-9)
    acc = r_spec["mean_accepted_len"]

    print(f"arch={cfg.name}  k={spec_k}  max_len={max_len}  "
          f"pool={num_blocks} x {block_size}-token blocks "
          f"(+{int(draft_blocks)} blocks to the baseline = draft cache)")
    print(f"  trace: {len(reqs)} greedy requests, <= {max_new} new tokens")
    for name, r in (("target-only", r_base), ("speculative", r_spec)):
        extra = "" if r["mean_accepted_len"] is None else \
            f"   mean accepted {r['mean_accepted_len']:.2f}/{spec_k}"
        print(f"  {name:12s}  {r['steps']:4d} steps for {r['tokens']} "
              f"tokens   {r['tokens_per_step']:.2f} tok/step   "
              f"{r['tok_s']:,.0f} tok/s{extra}")
    print(f"  tokens/step gain {gain:.2f}x; identical streams "
          f"{n_same}/{len(r_base['done'])}; decode compilations "
          f"{spec.compilations['decode']}")

    assert n_same == len(r_base["done"]), (
        f"only {n_same}/{len(r_base['done'])} speculative streams matched "
        "target-only decode — greedy speculation must be token-identical")
    assert spec.compilations["decode"] == 1, (
        f"speculative decode compiled {spec.compilations['decode']}x")
    assert r_spec["metrics_json"] == r_spec2["metrics_json"] \
        and r_spec["done"] == r_spec2["done"], (
        "two fresh-engine speculative replays disagree — the per-slot "
        "PRNG lanes are not replaying deterministically")
    if require_gain is not None:
        assert gain >= require_gain, (
            f"tokens/step gain {gain:.2f}x below the required "
            f"{require_gain:.2f}x")

    results = {
        "tokens_per_step": {"target_only": r_base["tokens_per_step"],
                            "speculative": r_spec["tokens_per_step"]},
        "steps": {"target_only": r_base["steps"],
                  "speculative": r_spec["steps"]},
        "gain": gain,
        "mean_accepted_len": acc,
        "identical_streams": f"{n_same}/{len(r_base['done'])}",
        "deterministic_replay": True,
        "tok_s": {"target_only": r_base["tok_s"],
                  "speculative": r_spec["tok_s"]},
    }
    payload = {"benchmark": "speculative", "results": results}
    if out_json:
        payload = write_payload(
            out_json, "speculative", arch=cfg.name,
            config={"spec_k": spec_k, "max_len": max_len,
                    "block_size": block_size, "num_blocks": num_blocks,
                    "requests": n_requests, "max_new": max_new,
                    "max_batch": max_batch, "self_draft": True},
            results=results)
        print(f"  appended to {out_json}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--spec-k", type=int, default=3)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="speculative engine's pool (baseline gets the "
                         "draft cache's bytes on top)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--trace-seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--require-gain", type=float, default=1.5,
                    help="fail unless tokens/step improves this much")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, short trace, small max_len")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.max_len, args.requests = 1, 128, 8
        args.block_size, args.max_batch, args.max_new = 8, 6, 24
    num_blocks = args.num_blocks or \
        args.max_batch * args.max_len // args.block_size
    run(args.arch, args.layers, args.spec_k, args.max_len, args.block_size,
        num_blocks, args.requests, args.max_new, args.max_batch,
        args.require_gain, args.json, trace_seed=args.trace_seed)


if __name__ == "__main__":
    main()
