"""Prefix-cached paged pool vs no sharing, at equal pool memory.

Production traffic shares structure: system prompts and few-shot
preambles put the same long prefix in front of most requests.  Without
sharing, every arrival re-prefills that prefix (latency) and re-stores
its KV blocks (capacity).  The prefix cache
(``MemorySpec(prefix_cache=True)``, ``core.paging.PrefixCache``) attacks
both: a cache-hit request maps the resident prefix blocks into its block
table (refcount++, zero compute) and chunked prefill charges token
budget only for the uncached suffix.

The trace is 80% shared-prefix traffic across two prefix families with
mixed prompt lengths, 20% unique prompts.  Both engines replay it with
the same seed and pool geometry; the report measures

* **warm TTFT** — steps and wall time to the first token of a
  shared-prefix arrival once its family's prefix is resident,
* **peak concurrency** + **steps to drain** — shared blocks are charged
  once, so the same pool admits more requests at once,
* **drain tok/s** and **bit-identical greedy streams** vs the
  sharing-off engine (sharing reuses identical KV, so it must not move
  a single token).

    PYTHONPATH=src python benchmarks/prefix_cache.py
    PYTHONPATH=src python benchmarks/prefix_cache.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

try:                                   # package form (benchmarks.run)
    from benchmarks._util import write_payload
except ModuleNotFoundError:            # direct script invocation
    from _util import write_payload

from repro.configs import REGISTRY, reduced
from repro.core.spec import MemorySpec, RuntimeSpec, SchedulerSpec
from repro.harness import replay, scripted_trace
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def shared_trace(n: int, prefixes: list[list[int]], max_len: int,
                 seed: int = 0) -> list[tuple[list[int], int]]:
    """80% of requests extend one of the shared prefixes with a unique
    suffix (mixed lengths); 20% are fully unique prompts."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        budget = int(rng.randint(2, max(max_len // 16, 3)))
        if i % 5 != 4:                                   # 80%: shared
            base = prefixes[i % len(prefixes)]
            sfx_len = int(rng.randint(1, max(max_len // 8, 2)))
            sfx_len = min(sfx_len, max_len - len(base) - budget)
            suffix = [1 + int(t) for t in rng.randint(0, 50, size=sfx_len)]
            reqs.append((base + suffix, budget))
        else:                                            # 20%: unique
            plen = int(rng.randint(3, max(max_len // 4, 4)))
            reqs.append(([1 + int(t) for t in rng.randint(0, 50, size=plen)],
                         budget))
    return reqs


def build(cfg, params, *, prefix: bool, max_batch: int, max_len: int,
          block_size: int, num_blocks: int) -> ServingEngine:
    spec = RuntimeSpec(
        arch=cfg,
        memory=MemorySpec(cache_layout="paged", max_batch=max_batch,
                          max_len=max_len, block_size=block_size,
                          num_blocks=num_blocks, prefix_cache=prefix),
        scheduler=SchedulerSpec(policy="chunked",
                                chunk_size=max(block_size, 16)))
    eng = ServingEngine(spec, sampling=SamplingParams())
    eng.load(params)
    return eng


def warm(eng: ServingEngine, prefixes: list[list[int]]) -> None:
    """Prefill one request per prefix family and drain — the prefix
    engine registers the family chains; the baseline just does the
    same work for fairness."""
    for p in prefixes:
        eng.submit(p + [7], max_new_tokens=2)
    eng.run_to_completion()


def measure_ttft(eng: ServingEngine, prompt: list[int],
                 repeats: int = 3) -> dict:
    """Steps + wall seconds until a fresh arrival's first token exists on
    device — a one-request harness replay; both numbers come from the
    engine's lifecycle events.  Steps are deterministic; the wall number
    takes the best of ``repeats`` replays (scheduler noise dominates a
    single-step measurement)."""
    best = None
    for _ in range(repeats):
        res = replay(eng, scripted_trace([(0, prompt, 4)], name="ttft"))
        m = res.metrics
        assert m.n_finished == 1, "TTFT request never produced a token"
        if best is None or m.ttft_s_p50 < best["seconds"]:
            best = {"steps": m.ttft_steps_p50, "seconds": m.ttft_s_p50}
    return best


def drive(eng: ServingEngine, reqs) -> dict:
    """Replay the full trace through the harness driver; peak
    concurrency / drain steps / throughput are harness metrics."""
    trace = scripted_trace([(0, prompt, budget) for prompt, budget in reqs],
                           name="shared-prefix")
    res = replay(eng, trace)
    m = res.metrics
    return {"peak": m.peak_concurrency, "steps": m.steps,
            "seconds": m.wall_s, "tok_s": m.tokens_per_s,
            "done": {res.uid_to_rid[r.uid]: r.generated
                     for r in res.finished}}


def run(arch: str, layers: int | None, max_len: int, block_size: int,
        num_blocks: int, n_requests: int, max_batch: int,
        require_ttft: float | None, require_peak: float | None,
        out_json: str | None, trace_seed: int = 5) -> dict:
    over = {} if layers is None else {"num_layers": layers}
    cfg = reduced(REGISTRY[arch], **over)
    params = Model(cfg).init(jax.random.PRNGKey(0))

    # two prefix families, each ~5/8 of max_len — long enough that
    # re-prefilling them dominates both latency and pool pressure
    plen = 5 * max_len // 8 // block_size * block_size
    prefixes = [[10 + f] * plen for f in range(2)]
    reqs = shared_trace(n_requests, prefixes, max_len, trace_seed)

    results, engines = {}, {}
    for mode, prefix in (("sharing-off", False), ("sharing-on", True)):
        eng = build(cfg, params, prefix=prefix, max_batch=max_batch,
                    max_len=max_len, block_size=block_size,
                    num_blocks=num_blocks)
        warm(eng, prefixes)
        ttft = measure_ttft(eng, prefixes[0] + [40, 41])
        results[mode] = {"ttft": ttft, **drive(eng, reqs)}
        engines[mode] = eng

    off, on = results["sharing-off"], results["sharing-on"]
    n_same = sum(off["done"][u] == on["done"][u] for u in off["done"])
    ttft_gain = off["ttft"]["seconds"] / max(on["ttft"]["seconds"], 1e-9)
    ttft_step_gain = off["ttft"]["steps"] / max(on["ttft"]["steps"], 1)
    peak_gain = on["peak"] / max(off["peak"], 1)
    drain_gain = off["steps"] / max(on["steps"], 1)
    st = engines["sharing-on"].stats

    print(f"arch={cfg.name}  max_len={max_len}  pool={num_blocks} x "
          f"{block_size}-token blocks (equal both engines)")
    print(f"  trace: {len(reqs)} requests, 80% sharing 2 prefixes of "
          f"{plen} tokens")
    for mode in ("sharing-off", "sharing-on"):
        r = results[mode]
        print(f"  {mode:12s}  warm TTFT {r['ttft']['seconds'] * 1e3:7.1f} ms "
              f"({r['ttft']['steps']} steps)   peak concurrency "
              f"{r['peak']:3d}   steps to drain {r['steps']:4d}   "
              f"{r['tok_s']:,.0f} tok/s")
    print(f"  prefix cache: {st['prefix_hits']} hits / "
          f"{st['prefix_hit_tokens']} tokens skipped, {st['cow_forks']} CoW "
          f"forks, {st['prefix_evictions']} evictions, "
          f"{engines['sharing-on'].stats['preemptions']} preemptions")
    print(f"  warm TTFT {ttft_gain:.2f}x ({ttft_step_gain:.2f}x steps); "
          f"peak concurrency {peak_gain:.2f}x; drain {drain_gain:.2f}x "
          f"steps; identical streams {n_same}/{len(off['done'])}")

    assert n_same == len(off["done"]), (
        f"only {n_same}/{len(off['done'])} shared-prefix streams matched "
        "the sharing-off engine — shared KV must be bit-identical")
    if require_ttft is not None:
        assert ttft_gain >= require_ttft, (
            f"warm TTFT gain {ttft_gain:.2f}x below the required "
            f"{require_ttft:.2f}x")
    if require_peak is not None:
        assert peak_gain >= require_peak, (
            f"peak concurrency gain {peak_gain:.2f}x below the required "
            f"{require_peak:.2f}x at equal pool memory")

    results_out = {
        "warm_ttft": {m: results[m]["ttft"] for m in results},
        "peak_concurrency": {m: results[m]["peak"] for m in results},
        "steps_to_drain": {m: results[m]["steps"] for m in results},
        "drain_tok_s": {m: results[m]["tok_s"] for m in results},
        "ttft_gain": ttft_gain,
        "peak_gain": peak_gain,
        "drain_gain": drain_gain,
        "identical_streams": f"{n_same}/{len(off['done'])}",
        "prefix_stats": {k: st[k] for k in
                         ("prefix_hits", "prefix_hit_tokens", "cow_forks",
                          "prefix_evictions")},
    }
    payload = {"benchmark": "prefix_cache", "results": results_out}
    if out_json:
        payload = write_payload(
            out_json, "prefix_cache", arch=cfg.name,
            config={"max_len": max_len, "block_size": block_size,
                    "num_blocks": num_blocks, "requests": n_requests,
                    "prefix_tokens": plen, "max_batch": max_batch},
            results=results_out)
        print(f"  appended to {out_json}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="pool size, same for both engines (default "
                         "2.5 * max_len / block_size)")
    ap.add_argument("--requests", type=int, default=25)
    ap.add_argument("--trace-seed", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=24)
    ap.add_argument("--require-ttft", type=float, default=2.0,
                    help="fail unless warm TTFT improves this much")
    ap.add_argument("--require-peak", type=float, default=1.5,
                    help="fail unless peak concurrency gains this much")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 1 layer, short trace, small max_len")
    args = ap.parse_args()
    if args.smoke:
        args.layers, args.max_len, args.requests = 1, 128, 15
        args.block_size, args.max_batch = 8, 24
    num_blocks = args.num_blocks or 5 * args.max_len // args.block_size // 2
    run(args.arch, args.layers, args.max_len, args.block_size, num_blocks,
        args.requests, args.max_batch, args.require_ttft, args.require_peak,
        args.json, trace_seed=args.trace_seed)


if __name__ == "__main__":
    main()
