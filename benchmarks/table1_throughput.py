"""Table 1 analogue: achieved throughput / efficiency per network.

The paper reports GOPS, GOPS/DSP and GOPS/W for three networks on fixed
fabric.  TPU mapping (all modeled from the roofline, labeled as such):
  GOPS        -> achieved FLOP/s = model FLOPs / roofline-bound time
  GOPS/DSP    -> MXU utilization = achieved / peak
  GOPS/W      -> achieved FLOP/s / modeled chip power (v5e TDP ~ 200 W)
One chip, forward pass, SL 64 — the paper's measurement point.
"""
from __future__ import annotations


from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.analytical import V5E, analytical_step_seconds, step_flops

CHIP_WATTS = 200.0
NETWORKS = ["shallow-transformer", "custom-encoder", "adaptor-bert"]


def run() -> list[str]:
    out = ["table1,network,gflops_step,achieved_tflops_s,mxu_frac,"
           "gflops_per_watt,dominant"]
    for name in NETWORKS:
        cfg = get_config(name)
        shape = ShapeSpec("bench", 64, 1, "prefill")
        f = step_flops(cfg, shape)["total"]
        r = analytical_step_seconds(cfg, shape, n_chips=1)
        achieved = f / r.t_total
        out.append(
            f"table1,{name},{f / 1e9:.2f},{achieved / 1e12:.3f},"
            f"{achieved / V5E.peak_flops:.4f},"
            f"{achieved / 1e9 / CHIP_WATTS:.2f},{r.dominant}")
    # the paper's batch=1 SL=64 point is hopelessly memory-bound on any
    # accelerator; show the batched serving point too (beyond-paper)
    for name in NETWORKS:
        cfg = get_config(name)
        shape = ShapeSpec("bench", 64, 128, "prefill")
        f = step_flops(cfg, shape)["total"]
        r = analytical_step_seconds(cfg, shape, n_chips=1)
        achieved = f / r.t_total
        out.append(
            f"table1_b128,{name},{f / 1e9:.2f},{achieved / 1e12:.3f},"
            f"{achieved / V5E.peak_flops:.4f},"
            f"{achieved / 1e9 / CHIP_WATTS:.2f},{r.dominant}")
    return out


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
