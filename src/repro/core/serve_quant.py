"""Serving-time weight quantization (paper C6 applied to deployment).

Walks a parameter tree and replaces eligible leaves — 2-D+ matmul kernels
(path key 'kernel') and embedding/unembedding tables ('table') — with
int8 ``QTensor``s: per-output-channel scales for kernels, per-row scales
for tables.  Three parallel entry points mirror ``ParamBuilder``'s modes:

* ``quantize_params``   — real arrays (runnable serving),
* ``quantize_abstract`` — ShapeDtypeStructs (dry-run lowering),
* ``quantize_axes``     — PartitionSpecs (sharding trees).

All three produce structurally identical trees, so the existing
``tree_param_shardings`` machinery works unchanged.  The eligibility
floor defaults to ``core.quant.DEFAULT_QUANT_MIN_SIZE`` and is
configured per engine through ``ExecutionSpec.quant_min_size``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.quant import DEFAULT_QUANT_MIN_SIZE, QTensor


def _last_key(path) -> str:
    for pp in reversed(path):
        if hasattr(pp, "key"):
            return str(pp.key)
    return ""


def _eligible(path, leaf, min_size: int = DEFAULT_QUANT_MIN_SIZE) -> str | None:
    """Returns 'kernel' / 'table' when the leaf should be quantized."""
    name = _last_key(path)
    if name not in ("kernel", "table"):
        return None
    shape = getattr(leaf, "shape", None)
    if shape is None or len(shape) < 2:
        return None
    n = 1
    for d in shape:
        n *= d
    if n < min_size:
        return None
    return name


def _map_with_path(tree, fn):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [fn(path, leaf) for path, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def quantize_leaf(leaf, kind: str) -> QTensor:
    """Kernels [..., K, N]: per-(stack, column) scales reducing over the
    contraction dim only; tables [V, ...]: per-row scales."""
    w = leaf.astype(jnp.float32)
    axis = -2 if kind == "kernel" else tuple(range(1, w.ndim))
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def quantize_params(params, min_size: int = DEFAULT_QUANT_MIN_SIZE):
    """Real arrays -> int8 QTensors (kernels per-column, tables per-row)."""

    def one(path, leaf):
        kind = _eligible(path, leaf, min_size)
        if kind is None:
            return leaf
        return quantize_leaf(leaf, kind)

    return _map_with_path(params, one)


def quantize_abstract(abstract, min_size: int = DEFAULT_QUANT_MIN_SIZE):
    """ShapeDtypeStruct tree -> QTensor(SDS int8, SDS f32 scale)."""

    def one(path, leaf):
        kind = _eligible(path, leaf, min_size)
        if kind is None:
            return leaf
        shape = leaf.shape
        if kind == "kernel":  # keep stack dims, collapse the contraction dim
            sshape = shape[:-2] + (1, shape[-1])
        else:
            sshape = (shape[0],) + tuple(1 for _ in shape[1:])
        return QTensor(jax.ShapeDtypeStruct(shape, jnp.int8),
                       jax.ShapeDtypeStruct(sshape, jnp.float32))

    return _map_with_path(abstract, one)


def quantize_axes(axes, abstract, min_size: int = DEFAULT_QUANT_MIN_SIZE):
    """Logical-axes tree -> QTensor(P values, P scale) matching
    ``quantize_abstract``'s structure.  The scale inherits the spec of its
    non-degenerate dim so it co-shards with the values."""
    flat_ax = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, P))
    flat_ab = jax.tree_util.tree_flatten_with_path(abstract)
    leaves = []
    for (path, spec), (_, leaf) in zip(flat_ax[0], flat_ab[0]):
        kind = _eligible(path, leaf, min_size)
        if kind is None:
            leaves.append(spec)
            continue
        names = tuple(spec) + (None,) * (len(leaf.shape) - len(spec))
        if kind == "kernel":  # [stack..., 1, N] scale co-shards with values
            sspec = P(*(names[:-2] + (None, names[-1])))
        else:
            sspec = P(*((names[0],) + (None,) * (len(leaf.shape) - 1)))
        leaves.append(QTensor(spec, sspec))
    return jax.tree_util.tree_unflatten(flat_ax[1], leaves)
