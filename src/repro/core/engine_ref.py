"""Pure-jnp oracle for the adaptive engine (unpadded, per-topology).

Computes the paper's post-LN encoder/decoder (Eq. 1-7) directly at the
*live* sizes, with no masking or padding.  The engine equivalence test
asserts that the padded+masked engine output restricted to live lanes
matches this oracle — i.e. idle fabric never contaminates live compute.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def random_network(rng: jax.Array, *, seq: int, d_model: int, heads: int,
                   d_ff: int, layers_enc: int, layers_dec: int = 0,
                   vocab: int = 1000, out: int | None = None,
                   kv_heads: int | None = None) -> dict:
    """An unpadded post-LN network in engine-native weight naming."""
    kv_heads = kv_heads or heads
    head_dim = d_model // heads
    keys = iter(jax.random.split(rng, 4096))
    nrm = lambda *s: (jax.random.normal(next(keys), s)
                      / math.sqrt(max(s[0], 1))).astype(jnp.float32)

    def attn() -> dict:
        return {
            "wq": nrm(d_model, heads * head_dim),
            "wk": nrm(d_model, kv_heads * head_dim),
            "wv": nrm(d_model, kv_heads * head_dim),
            "bq": nrm(heads * head_dim) * 0.1,
            "bk": nrm(kv_heads * head_dim) * 0.1,
            "bv": nrm(kv_heads * head_dim) * 0.1,
            "wo": nrm(heads * head_dim, d_model).reshape(heads, head_dim,
                                                         d_model)
            .reshape(heads * head_dim, d_model),
            "bo": nrm(d_model) * 0.1,
        }

    def layer(cross: bool = False) -> dict:
        p = {"attn": attn(),
             "ln1_g": jnp.ones(d_model), "ln1_b": jnp.zeros(d_model),
             "w1": nrm(d_model, d_ff), "b1": nrm(d_ff) * 0.1,
             "w2": nrm(d_ff, d_model), "b2": nrm(d_model) * 0.1,
             "ln2_g": jnp.ones(d_model), "ln2_b": jnp.zeros(d_model)}
        if cross:
            p["cross"] = attn()
            p["ln3_g"] = jnp.ones(d_model)
            p["ln3_b"] = jnp.zeros(d_model)
        return p

    return {
        "seq": seq, "d_model": d_model, "heads": heads,
        "kv_heads": kv_heads, "head_dim": head_dim, "d_ff": d_ff,
        "vocab": vocab, "out": out or d_model,
        "embed": 0.02 * jax.random.normal(next(keys), (vocab, d_model)),
        "pos": 0.02 * jax.random.normal(next(keys), (seq, d_model)),
        "w_out": nrm(d_model, out or d_model),
        "b_out": jnp.zeros(out or d_model),
        "enc_layers": [layer() for _ in range(layers_enc)],
        "dec_layers": [layer(cross=True) for _ in range(layers_dec)],
    }


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _act(x, kind: str):
    return jax.nn.gelu(x, approximate=False) if kind == "gelu" \
        else jax.nn.relu(x)


def _mha(x, kv_src, a, heads, kv_heads, head_dim, *, causal=False):
    b_, s, d = x.shape
    sk = kv_src.shape[1]
    rep = heads // kv_heads
    q = (x @ a["wq"] + a["bq"]).reshape(b_, s, heads, head_dim)
    k = (kv_src @ a["wk"] + a["bk"]).reshape(b_, sk, kv_heads, head_dim)
    v = (kv_src @ a["wv"] + a["bv"]).reshape(b_, sk, kv_heads, head_dim)
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s_ = jnp.einsum("bqhe,bkhe->bhqk", q, k) / math.sqrt(head_dim)
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), bool))
        s_ = jnp.where(mask[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqk,bkhe->bqhe", p, v).reshape(b_, s, heads * head_dim)
    return o @ a["wo"] + a["bo"]


def _layer(x, lp, heads, kv_heads, head_dim, act, *, causal=False,
           enc_out=None):
    a = _mha(x, x, lp["attn"], heads, kv_heads, head_dim, causal=causal)
    x = _ln(x + a, lp["ln1_g"], lp["ln1_b"])
    if enc_out is not None:
        c = _mha(x, enc_out, lp["cross"], heads, kv_heads, head_dim)
        x = _ln(x + c, lp["ln3_g"], lp["ln3_b"])
    f = _act(x @ lp["w1"] + lp["b1"], act) @ lp["w2"] + lp["b2"]
    return _ln(x + f, lp["ln2_g"], lp["ln2_b"])


def forward(net: dict, tokens: jax.Array, *, activation: str = "relu",
            tgt_tokens: jax.Array | None = None) -> jax.Array:
    """tokens: [B, seq] (already at the live length).  -> [B, seq, out]."""
    h, kv, hd = net["heads"], net["kv_heads"], net["head_dim"]
    x = net["embed"][tokens] + net["pos"][: tokens.shape[1]][None]
    for lp in net["enc_layers"]:
        x = _layer(x, lp, h, kv, hd, activation)
    if net["dec_layers"]:
        y = net["embed"][tgt_tokens] + net["pos"][: tgt_tokens.shape[1]][None]
        for lp in net["dec_layers"]:
            y = _layer(y, lp, h, kv, hd, activation, causal=True, enc_out=x)
        x = y
    return x @ net["w_out"] + net["b_out"]
