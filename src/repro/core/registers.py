"""Topology registers — the paper's §3.12 configuration register file.

On the FPGA these are AXI4-Lite registers written by the MicroBlaze before
asserting the start signal.  Here they are a small pytree of *traced* int32
scalars passed to an already-compiled step function: changing their values
never triggers a retrace/recompile, exactly as reprogramming the register
file never triggers re-synthesis.

Registers (paper names kept):
  sequence    — live sequence length        (<= seq_max)
  heads       — live attention head count   (<= heads_max)
  layers_enc  — live encoder layer count    (<= layers_enc_max)
  layers_dec  — live decoder layer count    (<= layers_dec_max; 0 = enc-only)
  embeddings  — live d_model                (<= d_model_max)
  hidden      — live FFN hidden dim         (<= d_ff_max)
  out         — live output class count     (<= out_max)
plus one extension register for modern variants:
  kv_heads    — live KV head count (GQA); == heads for MHA models
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


class TopologyRegisters(NamedTuple):
    sequence: jax.Array
    heads: jax.Array
    layers_enc: jax.Array
    layers_dec: jax.Array
    embeddings: jax.Array
    hidden: jax.Array
    out: jax.Array
    kv_heads: jax.Array

    @property
    def head_dim(self) -> jax.Array:
        """d_k = embeddings / heads (paper §2.1), computed at runtime."""
        return self.embeddings // jnp.maximum(self.heads, 1)


def make_registers(*, sequence: int, heads: int, layers_enc: int,
                   layers_dec: int = 0, embeddings: int, hidden: int,
                   out: int = 0, kv_heads: int | None = None
                   ) -> TopologyRegisters:
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    return TopologyRegisters(
        sequence=i32(sequence), heads=i32(heads), layers_enc=i32(layers_enc),
        layers_dec=i32(layers_dec), embeddings=i32(embeddings),
        hidden=i32(hidden), out=i32(out if out else embeddings),
        kv_heads=i32(kv_heads if kv_heads is not None else heads))


def registers_for(cfg: ArchConfig, sequence: int,
                  layers_dec: int | None = None) -> TopologyRegisters:
    """Program the register file for one architecture config (Alg. 18 step 3)."""
    return make_registers(
        sequence=sequence,
        heads=cfg.num_heads,
        layers_enc=(cfg.encdec.num_encoder_layers if cfg.encdec
                    else cfg.num_layers),
        layers_dec=(layers_dec if layers_dec is not None
                    else (cfg.num_layers if cfg.encdec else 0)),
        embeddings=cfg.d_model,
        hidden=cfg.d_ff,
        out=cfg.vocab_size,
        kv_heads=cfg.num_kv_heads,
    )


class Maxima(NamedTuple):
    """Synthesis-time maxima — the provisioned 'fabric' (frozen at compile)."""

    seq_max: int
    heads_max: int
    layers_enc_max: int
    layers_dec_max: int
    d_model_max: int
    d_ff_max: int
    out_max: int
    head_dim_max: int
    vocab: int

    def validate(self, regs_static: dict) -> None:
        lim = {"sequence": self.seq_max, "heads": self.heads_max,
               "layers_enc": self.layers_enc_max,
               "layers_dec": self.layers_dec_max,
               "embeddings": self.d_model_max, "hidden": self.d_ff_max,
               "out": self.out_max}
        for k, mx in lim.items():
            v = regs_static.get(k)
            if v is not None and v > mx:
                raise ValueError(
                    f"register {k}={v} exceeds synthesized maximum {mx}; "
                    f"re-synthesis (recompile) required")
