"""Quantization path (paper C6: 'fully quantized for computational
efficiency and portability').

The FPGA uses fixed-point throughout (float->fixed conversion is even in
the latency model, 3 cc).  The TPU-native equivalent is symmetric int8:

* weights  — per-output-channel symmetric int8 (scale = amax / 127)
* activations — per-tensor dynamic symmetric int8
* accumulation — int32 on the MXU (f32 when emulated), rescaled to the
  activation dtype on the way out.

``int8_matmul`` in ``repro.kernels`` is the Pallas kernel consuming this
format; this module provides the quantizers and the jnp reference path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Leaves below this many elements stay float when serving-time weight
# quantization walks a parameter tree (biases, norms, tiny projections:
# the memory win is negligible and the relative error is largest).  The
# deployment default; override per-engine via
# ``core.spec.ExecutionSpec(quant_min_size=...)``.
DEFAULT_QUANT_MIN_SIZE = 65_536


class QTensor(NamedTuple):
    values: jax.Array  # int8
    scale: jax.Array   # f32, broadcastable to values along the quant axis


def quantize(w: jax.Array, axis: int | None = -1) -> QTensor:
    """Symmetric int8 quantization.  ``axis=None`` -> per-tensor scale;
    otherwise per-slice along ``axis`` (per-output-channel for weights)."""
    w32 = w.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(w32))
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
        return QTensor(q, scale)
    reduce_axes = tuple(i for i in range(w32.ndim) if i != axis % w32.ndim)
    amax = jnp.max(jnp.abs(w32), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def dequantize(q: QTensor) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scale


def quantize_dynamic(x: jax.Array) -> QTensor:
    """Per-tensor dynamic activation quantization (serving path)."""
    return quantize(x, axis=None)


def int8_matmul_ref(x: jax.Array, qw: QTensor) -> jax.Array:
    """Reference quantized matmul: dynamic-quant x, int accumulate,
    rescale.  x: [..., K], qw.values: [K, N] -> [..., N] (x.dtype)."""
    qx = quantize_dynamic(x)
    acc = jnp.matmul(qx.values.astype(jnp.int32), qw.values.astype(jnp.int32))
    out = acc.astype(jnp.float32) * qx.scale * qw.scale.reshape(1, -1)
    return out.astype(x.dtype)


def quantize_tree(params, axis: int | None = -1,
                  min_size: int = 4096) -> tuple[dict, dict]:
    """Quantize every large float leaf of a param tree; small leaves
    (biases, norms) stay in float.  Returns (quantized_tree, meta) where
    meta marks which leaves were quantized."""
    flat, treedef = jax.tree.flatten(params)
    out, meta = [], []
    for leaf in flat:
        if (hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.size >= min_size and leaf.ndim >= 2):
            out.append(quantize(leaf, axis=axis))
            meta.append(True)
        else:
            out.append(leaf)
            meta.append(False)
    return jax.tree.unflatten(treedef, out), \
        jax.tree.unflatten(treedef, meta)


def quantization_error(w: jax.Array, axis: int | None = -1) -> float:
    """Relative RMS error of the int8 round-trip (test/report helper)."""
    q = quantize(w, axis)
    back = dequantize(q)
    num = jnp.sqrt(jnp.mean(jnp.square(back - w.astype(jnp.float32))))
    den = jnp.sqrt(jnp.mean(jnp.square(w.astype(jnp.float32)))) + 1e-12
    return float(num / den)
