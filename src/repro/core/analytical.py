"""Analytical resource/latency model — the paper's §5, re-derived for TPU.

The paper models DSP/BRAM counts (Eq. 8, 25) and per-module pipelined-loop
latency (Eq. 9-39) as closed-form functions of the topology registers
(sequence length, heads, d_model, d_ff, layers) and the tile sizes.  On a
TPU the same role is played by

* per-module FLOP counts            (DSP MACs      -> MXU FLOPs)
* per-module HBM byte traffic       (BRAM loads    -> HBM->VMEM streams)
* collective byte traffic           (no FPGA analogue; pod-scale addition)
* a three-term roofline             (pipelined-loop latency -> max of terms)

Like the paper's model, everything here is *pre-synthesis* arithmetic: it
never touches a device, so it can size tiles, predict memory, and be
validated against the compiled artifact (``benchmarks/table2_analytical.py``
is the Table 2 analogue, with ``compiled.cost_analysis()`` standing in for
the AXI-timer measurements).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # only for type hints; avoid import cycle at runtime
    from repro.configs.base import ArchConfig, ShapeSpec


# ---------------------------------------------------------------------------
# Hardware constants (assignment-fixed TPU v5e-class chip)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip (MXU)
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link direction
    ici_links: int = 4                # 2D torus: 4 links per chip
    hbm_bytes: int = 16 * 1024**3     # 16 GiB HBM per chip
    vmem_bytes: int = 64 * 1024**2    # planning budget for kernel tiles
    mxu_tile: int = 128               # systolic array edge (alignment unit)


V5E = TPUSpec()


# ---------------------------------------------------------------------------
# Parameter counts (paper Eq. 8/25 analogue: how much "fabric" a topology uses)
# ---------------------------------------------------------------------------
def _attention_params(cfg: "ArchConfig") -> int:
    """Per-layer attention parameter count, by family."""
    d = cfg.d_model
    if cfg.family == "ssm":
        return 0
    if cfg.mla is not None:
        m = cfg.mla
        n = 0
        n += d * m.q_lora_rank + m.q_lora_rank  # q down + norm
        n += m.q_lora_rank * cfg.num_heads * m.qk_head_dim  # q up
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank  # kv down + norm
        n += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)  # kv up
        n += cfg.num_heads * m.v_head_dim * d  # out proj
        return n
    hd = cfg.resolved_head_dim
    n = d * cfg.num_heads * hd          # W_q
    n += 2 * d * cfg.num_kv_heads * hd  # W_k, W_v
    n += cfg.num_heads * hd * d         # W_o
    if cfg.qkv_bias:
        n += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
    return n


def _ffn_params(cfg: "ArchConfig", d_ff: int) -> int:
    d = cfg.d_model
    from repro.models.layers import is_gated

    mats = 3 if is_gated(cfg.activation) else 2
    n = mats * d * d_ff
    if cfg.family in ("encoder", "audio") or cfg.activation in ("gelu", "relu"):
        # paper-style FFN carries biases
        n += d_ff + d
    return n


def _ssm_params(cfg: "ArchConfig") -> int:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = s.dt_rank or math.ceil(d / 16)
    n = d * 2 * d_in                      # in_proj (x and gate branches)
    n += d_in * s.conv_kernel + d_in      # depthwise conv + bias
    n += d_in * (dt_rank + 2 * s.state_dim)  # x_proj -> (dt, B, C)
    n += dt_rank * d_in + d_in            # dt_proj
    n += d_in * s.state_dim + d_in        # A_log, D
    n += d_in * d                         # out_proj
    return n


def _rglru_params(cfg: "ArchConfig") -> int:
    h = cfg.hybrid
    d = cfg.d_model
    w = h.lru_width or d
    heads = max(cfg.num_heads, 1)
    blk = w // heads
    n = 2 * d * w                         # two input branches (x, gate)
    n += w * 4 + w                        # temporal conv (k=4) + bias
    n += 2 * heads * blk * blk + 2 * w    # block-diag input & recurrence gates
    n += w                                # a (recurrence) parameter
    n += w * d                            # out proj
    return n


def _moe_layer_params(cfg: "ArchConfig") -> tuple[int, int]:
    """(total, active) FFN params for one MoE layer."""
    m = cfg.moe
    from repro.models.layers import is_gated

    mats = 3 if is_gated(cfg.activation) else 2
    per_expert = mats * cfg.d_model * m.expert_d_ff
    router = cfg.d_model * m.num_experts
    shared = m.num_shared_experts * mats * cfg.d_model * m.shared_expert_d_ff
    total = m.num_experts * per_expert + router + shared
    active = m.experts_per_token * per_expert + router + shared
    return total, active


def arch_param_count(cfg: "ArchConfig", active_only: bool = False) -> int:
    """Total (or activated) parameter count for an architecture."""
    d = cfg.d_model
    n = cfg.vocab_size * d                       # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * d                  # unembedding
    if cfg.positional == "learned":
        n += cfg.max_position_embeddings * d

    def layer_params(kind: str) -> int:
        ln = 2 * d if cfg.norm == "layernorm" else d
        p = 2 * ln                               # pre-attn + pre-ffn norms
        if kind == "ssm":
            return p // 2 + _ssm_params(cfg)
        if kind == "rglru":
            return p + _rglru_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        if kind == "attn+moe":
            total, active = _moe_layer_params(cfg)
            return p + _attention_params(cfg) + (active if active_only else total)
        if kind == "attn+dense_ffn":
            dff = cfg.moe.dense_d_ff if (cfg.moe and cfg.moe.first_k_dense) else cfg.d_ff
            return p + _attention_params(cfg) + _ffn_params(cfg, dff)
        if kind == "cross":                      # enc-dec decoder layer
            ln3 = 3 * ln
            return ln3 + 2 * _attention_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        raise ValueError(kind)

    if cfg.family == "ssm":
        n += cfg.num_layers * layer_params("ssm")
    elif cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        for i in range(cfg.num_layers):
            kind = "rglru" if pat[i % len(pat)] == "r" else "attn+dense_ffn"
            n += layer_params(kind)
    elif cfg.family == "moe":
        k = cfg.moe.first_k_dense
        n += k * layer_params("attn+dense_ffn")
        n += (cfg.num_layers - k) * layer_params("attn+moe")
    elif cfg.encdec is not None:
        n += cfg.encdec.num_encoder_layers * layer_params("attn+dense_ffn")
        n += cfg.num_layers * layer_params("cross")
    else:
        n += cfg.num_layers * layer_params("attn+dense_ffn")

    if cfg.num_mtp_modules:
        # MTP: projection + one extra transformer layer per module (DeepSeek-V3)
        n += cfg.num_mtp_modules * (2 * d * d + layer_params("attn+moe" if cfg.moe else "attn+dense_ffn"))
    return n


# ---------------------------------------------------------------------------
# Per-module FLOPs (paper Eq. 11-39 analogue, module names kept)
# ---------------------------------------------------------------------------
def _mm(b_tokens: int, d_in: int, d_out: int) -> float:
    """FLOPs of a [tokens, d_in] @ [d_in, d_out] matmul."""
    return 2.0 * b_tokens * d_in * d_out


def attention_module_flops(cfg: "ArchConfig", batch: int, q_len: int,
                           kv_len: int) -> dict[str, float]:
    """FLOPs per attention layer, split by the paper's processing modules.

    QKV_PM -> 'qkv', QK_PM -> 'qk', softmax -> counted in 'qk' (VPU-light),
    SV_PM -> 'sv', output projection -> 'out'.
    """
    d = cfg.d_model
    t = batch * q_len
    if cfg.mla is not None:
        m = cfg.mla
        qkv = _mm(t, d, m.q_lora_rank) + _mm(t, m.q_lora_rank, cfg.num_heads * m.qk_head_dim)
        qkv += _mm(t, d, m.kv_lora_rank + m.qk_rope_head_dim)
        qkv += _mm(t, m.kv_lora_rank, cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim))
        qk = 2.0 * batch * q_len * kv_len * cfg.num_heads * m.qk_head_dim
        sv = 2.0 * batch * q_len * kv_len * cfg.num_heads * m.v_head_dim
        out = _mm(t, cfg.num_heads * m.v_head_dim, d)
        return {"qkv": qkv, "qk": qk, "sv": sv, "out": out}
    hd = cfg.resolved_head_dim
    win = None
    if cfg.hybrid is not None:
        win = cfg.hybrid.attention_window
        kv_len = min(kv_len, win)
    qkv = _mm(t, d, (cfg.num_heads + 2 * cfg.num_kv_heads) * hd)
    qk = 2.0 * batch * q_len * kv_len * cfg.num_heads * hd
    sv = 2.0 * batch * q_len * kv_len * cfg.num_heads * hd
    out = _mm(t, cfg.num_heads * hd, d)
    return {"qkv": qkv, "qk": qk, "sv": sv, "out": out}


def ffn_module_flops(cfg: "ArchConfig", tokens: int, d_ff: int) -> float:
    from repro.models.layers import is_gated

    mats = 3 if is_gated(cfg.activation) else 2
    return mats * _mm(tokens, cfg.d_model, d_ff)


def ssm_module_flops(cfg: "ArchConfig", tokens: int) -> dict[str, float]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = s.dt_rank or math.ceil(d / 16)
    proj = _mm(tokens, d, 2 * d_in) + _mm(tokens, d_in, dt_rank + 2 * s.state_dim)
    proj += _mm(tokens, dt_rank, d_in) + _mm(tokens, d_in, d)
    conv = 2.0 * tokens * d_in * s.conv_kernel
    # selective scan: state update (2 mul + add) + output contraction per (ch, state)
    scan = 6.0 * tokens * d_in * s.state_dim
    return {"qkv": proj, "qk": conv, "sv": scan, "out": 0.0}


def rglru_module_flops(cfg: "ArchConfig", tokens: int) -> dict[str, float]:
    h = cfg.hybrid
    d = cfg.d_model
    w = h.lru_width or d
    heads = max(cfg.num_heads, 1)
    blk = w // heads
    proj = _mm(tokens, d, 2 * w) + _mm(tokens, w, d)
    gates = 2.0 * 2.0 * tokens * heads * blk * blk  # two block-diag gates
    conv = 2.0 * tokens * w * 4
    rec = 6.0 * tokens * w  # per-channel gated recurrence
    return {"qkv": proj, "qk": gates + conv, "sv": rec, "out": 0.0}


def step_flops(cfg: "ArchConfig", shape: "ShapeSpec") -> dict[str, float]:
    """Forward-pass FLOPs of one step, per module group, plus 'total'.

    For training shapes the caller multiplies by 3 (fwd + 2x bwd) — see
    ``train_multiplier``.  Decode shapes are one new token per sequence
    against a kv_len-deep cache.
    """
    B = shape.global_batch
    if shape.kind == "decode":
        q_len, kv_len = 1, shape.seq_len
    else:
        q_len = kv_len = shape.seq_len
    t = B * q_len
    d = cfg.d_model
    out: dict[str, float] = {"qkv": 0.0, "qk": 0.0, "sv": 0.0, "out": 0.0,
                             "ffn": 0.0, "router": 0.0, "norm": 0.0,
                             "embed": 0.0}

    def add_attn(n_layers: int, q: int, kv: int, cross: bool = False) -> None:
        f = attention_module_flops(cfg, B, q, kv)
        for k, v in f.items():
            out[k] += n_layers * v
        if cross:
            # cross-attention K/V comes from encoder output (kv fixed)
            pass

    def add_ffn(n_layers: int, tokens: int, d_ff: int) -> None:
        out["ffn"] += n_layers * ffn_module_flops(cfg, tokens, d_ff)

    if cfg.family == "ssm":
        f = ssm_module_flops(cfg, t)
        for k, v in f.items():
            out[k] += cfg.num_layers * v
    elif cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        n_r = sum(1 for i in range(cfg.num_layers) if pat[i % len(pat)] == "r")
        n_a = cfg.num_layers - n_r
        f = rglru_module_flops(cfg, t)
        for k, v in f.items():
            out[k] += n_r * v
        add_attn(n_a, q_len, kv_len)
        add_ffn(cfg.num_layers, t, cfg.d_ff)
    elif cfg.family == "moe":
        m = cfg.moe
        k_dense = m.first_k_dense
        add_attn(cfg.num_layers, q_len, kv_len)
        if k_dense:
            add_ffn(k_dense, t, m.dense_d_ff)
        n_moe = cfg.num_layers - k_dense
        out["router"] += n_moe * _mm(t, d, m.num_experts)
        out["ffn"] += n_moe * m.experts_per_token * ffn_module_flops(cfg, t, m.expert_d_ff)
        if m.num_shared_experts:
            out["ffn"] += n_moe * m.num_shared_experts * ffn_module_flops(cfg, t, m.shared_expert_d_ff)
    elif cfg.encdec is not None:
        enc_t = B * cfg.encdec.encoder_seq_len
        add_attn(cfg.encdec.num_encoder_layers, cfg.encdec.encoder_seq_len,
                 cfg.encdec.encoder_seq_len)
        add_ffn(cfg.encdec.num_encoder_layers, enc_t, cfg.d_ff)
        add_attn(cfg.num_layers, q_len, kv_len)              # decoder self-attn
        add_attn(cfg.num_layers, q_len, cfg.encdec.encoder_seq_len, cross=True)
        add_ffn(cfg.num_layers, t, cfg.d_ff)
    else:
        add_attn(cfg.num_layers, q_len, kv_len)
        add_ffn(cfg.num_layers, t, cfg.d_ff)

    out["norm"] += 8.0 * cfg.num_layers * t * d  # LN/RMSNorm + residuals (VPU)
    out["embed"] += _mm(t, d, cfg.vocab_size) if shape.kind != "decode" else _mm(B, d, cfg.vocab_size)
    if cfg.num_mtp_modules and shape.kind == "train":
        f = attention_module_flops(cfg, B, q_len, kv_len)
        out["qkv"] += cfg.num_mtp_modules * (sum(f.values()) + _mm(t, 2 * d, d))
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def train_multiplier() -> float:
    """fwd + bwd FLOP multiplier (bwd ~ 2x fwd for matmul-dominated nets)."""
    return 3.0


def scan_undercount_correction(cfg: "ArchConfig", shape: "ShapeSpec") -> float:
    """FLOPs hidden from cost_analysis inside non-layer lax.scans.

    The dry-run unrolls *layer* stacks, but two inner scans remain (their
    bodies are counted once instead of x trip-count):
      * the SSM / RG-LRU time recurrence (train & prefill),
      * blockwise attention's query-block scan (S >= 8192 full attention).
    Returns the missing FLOPs to add to HLO_FLOPs (fwd; x3 applied for
    train by the caller via ``train_multiplier``).
    """
    from repro.models.attention import BLOCKWISE_THRESHOLD, QUERY_BLOCK

    if shape.kind == "decode":
        return 0.0  # single-step updates, no inner scans
    B, S = shape.global_batch, shape.seq_len
    t = B * S
    missing = 0.0
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        # scan body counted once (one timestep): missing (S-1)/S of it
        missing += 6.0 * t * d_in * s.state_dim * (S - 1) / S
    if cfg.family == "hybrid":
        w = cfg.hybrid.lru_width or cfg.d_model
        pat = cfg.hybrid.pattern
        n_r = sum(1 for i in range(cfg.num_layers) if pat[i % len(pat)] == "r")
        missing += n_r * 6.0 * t * w * (S - 1) / S
    if S >= BLOCKWISE_THRESHOLD and cfg.family not in ("ssm",):
        # blockwise attention: one query block counted, nb-1 missing
        if cfg.mla is not None:
            m = cfg.mla
            per_tok = 2.0 * S * cfg.num_heads * (m.qk_head_dim + m.v_head_dim)
        elif cfg.hybrid is not None:
            per_tok = 0.0  # hybrid uses windowed attention, not blockwise
        else:
            per_tok = 4.0 * S * cfg.num_heads * cfg.resolved_head_dim
        n_attn = cfg.num_layers
        if cfg.hybrid is not None:
            pat = cfg.hybrid.pattern
            n_attn = sum(1 for i in range(cfg.num_layers)
                         if pat[i % len(pat)] == "a")
        nb = -(-S // QUERY_BLOCK)
        missing += n_attn * B * S * per_tok * (nb - 1) / nb
    return missing


def model_flops(cfg: "ArchConfig", shape: "ShapeSpec") -> float:
    """The 6·N·D (dense) / 6·N_active·D (MoE) useful-FLOPs yardstick."""
    n = arch_param_count(cfg, active_only=True)
    n -= cfg.vocab_size * cfg.d_model  # embedding lookups are not matmul FLOPs
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Memory footprints (paper Eq. 25 analogue)
# ---------------------------------------------------------------------------
def kv_cache_bytes(cfg: "ArchConfig", seq_len: int, batch: int,
                   dtype_bytes: int = 2) -> int:
    """Decode-time per-sequence state, by family (GQA/MLA/SSM/hybrid)."""
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        per_seq = d_in * s.state_dim + d_in * s.conv_kernel
        return cfg.num_layers * per_seq * batch * 4  # states kept in f32
    if cfg.mla is not None:
        m = cfg.mla
        per_tok = m.kv_lora_rank + m.qk_rope_head_dim
        return cfg.num_layers * seq_len * per_tok * batch * dtype_bytes
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        w = cfg.hybrid.lru_width or cfg.d_model
        win = min(cfg.hybrid.attention_window, seq_len)
        total = 0
        for i in range(cfg.num_layers):
            if pat[i % len(pat)] == "r":
                total += (w + w * 4) * 4  # LRU state + conv state, f32
            else:
                total += 2 * win * cfg.num_kv_heads * cfg.resolved_head_dim * dtype_bytes
        return total * batch
    kv = 2 * seq_len * cfg.num_kv_heads * cfg.resolved_head_dim
    n_self = cfg.num_layers
    total = n_self * kv * dtype_bytes
    if cfg.encdec is not None:  # cross-attention cache (encoder K/V)
        total += cfg.num_layers * 2 * cfg.encdec.encoder_seq_len * \
            cfg.num_kv_heads * cfg.resolved_head_dim * dtype_bytes
    return total * batch


def kv_bytes_per_token(cfg: "ArchConfig", kv_dtype: str = "compute") -> int:
    """Decode-cache bytes one cached token costs, across all layers.

    The per-token unit the autotuner sizes cache pools with: a dense
    cache holds ``max_batch * max_len`` of them, a paged pool
    ``num_blocks * block_size``.  ``kv_dtype="int8"`` accounts the
    ``core.kv_quant`` codec rows (int8 values + one f32 scale per
    (position, kv-head) row) instead of bf16 values.

    Only attention KV/latent caches have a per-token cost; recurrent
    families (SSM / RG-LRU hybrid) carry per-*sequence* state and the
    enc-dec cross cache is per-encoder-token — use
    :func:`kv_cache_bytes` for those.
    """
    if cfg.family in ("ssm", "hybrid") or cfg.encdec is not None:
        raise ValueError(
            f"family {cfg.family!r} has no per-token KV cache (its decode "
            "state is per-sequence or encoder-sided); use kv_cache_bytes")
    if cfg.mla is not None:
        width = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        per_layer = (width + 4) if kv_dtype == "int8" else 2 * width
        return cfg.num_layers * per_layer
    hd = cfg.resolved_head_dim
    per_row = (hd + 4) if kv_dtype == "int8" else 2 * hd
    return cfg.num_layers * 2 * cfg.num_kv_heads * per_row


def weight_bytes(cfg: "ArchConfig", dtype_bytes: int = 2) -> int:
    return arch_param_count(cfg) * dtype_bytes


def train_state_bytes(cfg: "ArchConfig") -> int:
    """bf16 params + f32 master + f32 m/v + bf16 grads (mixed-precision Adam)."""
    n = arch_param_count(cfg)
    return n * (2 + 4 + 4 + 4 + 2)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds, for one (arch, shape, mesh)."""

    t_compute: float
    t_memory: float
    t_collective: float
    flops: float              # HLO or analytical FLOPs (global)
    bytes_hbm: float          # HBM traffic (global)
    bytes_collective: float   # inter-chip traffic (global)
    n_chips: int
    spec: TPUSpec = V5E

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_total(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: useful compute time / bound time."""
        return self.t_compute / max(self.t_total, 1e-30)

    def scaled(self, **kw) -> "RooflineTerms":
        return dataclasses.replace(self, **kw)


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             n_chips: int, spec: TPUSpec = V5E) -> RooflineTerms:
    return RooflineTerms(
        t_compute=flops / (n_chips * spec.peak_flops),
        t_memory=bytes_hbm / (n_chips * spec.hbm_bw),
        t_collective=bytes_collective / (n_chips * spec.ici_bw),
        flops=flops, bytes_hbm=bytes_hbm, bytes_collective=bytes_collective,
        n_chips=n_chips, spec=spec,
    )


def analytical_step_seconds(cfg: "ArchConfig", shape: "ShapeSpec",
                            n_chips: int, spec: TPUSpec = V5E,
                            dtype_bytes: int = 2, *,
                            tp: int = 1) -> RooflineTerms:
    """Closed-form roofline estimate (no compiler), paper-Table-2 style.

    ``tp`` sizes the tensor-parallel collective term explicitly: with
    ``tp > 1`` every layer pays two all-reduces of the activation slab
    (Megatron attention-out + FFN-out), each moving ``2(tp-1)/tp`` of
    the payload per chip over the interconnect.  ``tp=1`` keeps the
    historical order-of-magnitude placeholder, so single-device rankings
    (pinned by the calibration test) are unchanged.
    """
    f = step_flops(cfg, shape)["total"]
    if shape.kind == "train":
        f *= train_multiplier()
    wb = weight_bytes(cfg, dtype_bytes)
    act = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len) \
        * cfg.d_model * dtype_bytes
    layers = cfg.num_layers + (cfg.encdec.num_encoder_layers if cfg.encdec else 0)
    bytes_hbm = wb + act * layers * 8  # weights once + activations per layer
    if shape.kind == "decode":
        bytes_hbm += kv_cache_bytes(cfg, shape.seq_len, shape.global_batch, dtype_bytes)
    if shape.kind == "train":
        bytes_hbm = 3 * wb + act * layers * 12
        coll = 2.0 * arch_param_count(cfg) * dtype_bytes  # grad all-reduce
    elif tp > 1:
        # serving TP: 2 ring all-reduces per layer over the activations
        coll = layers * 2.0 * act * 2.0 * (tp - 1) / tp
    else:
        coll = 2.0 * act  # TP activation collectives (order-of-magnitude)
    return roofline(f, bytes_hbm, coll, n_chips, spec)
