"""The ADAPTOR engine: compile once, run any topology within maxima (C1).

FPGA flow (paper)                      | This module
---------------------------------------+----------------------------------
synthesize fabric at TS_MHA/TS_FFN     | ``AdaptiveEngine(maxima)`` +
maxima, ~36 h                          | ``engine.compile(...)`` (once)
write AXI-Lite topology registers      | pass ``TopologyRegisters`` values
start signal                           | call the compiled step
different model, no re-synthesis       | different registers, **no retrace**

The engine is a *padded maximal* post-LN transformer encoder/decoder — the
paper's exact domain (Eq. 1-7, BERT-style): every buffer is allocated at
the synthesis maxima; topologies smaller than the maxima leave lanes idle,
and `core.masking` keeps idle lanes from contaminating live ones (the XLA
equivalent of unused DSPs holding garbage that never reaches an output).

Weight layout note: GQA models are packed by *replicating* KV weights
across the head group at load time (``pack``), so the runtime compute is
uniform MHA over ``heads`` lanes — the same trick the paper uses when it
maps any head count onto the fixed PE array.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.core.jitutil import strict_jit
from repro.core.registers import Maxima, TopologyRegisters
from repro.models.params import ParamBuilder


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    batch: int = 1
    dtype: Any = jnp.float32
    decoder: bool = False            # provision a decoder stack (layers_dec)
    pooled_output: bool = False      # [B, out] pooled vs [B, S, out] logits


class AdaptiveEngine:
    """One synthesized 'fabric' serving every topology within its maxima."""

    def __init__(self, maxima: Maxima, options: EngineOptions | None = None):
        self.mx = maxima
        self.opt = options or EngineOptions()
        self._compiled: Callable | None = None
        self._jitted = None

    @classmethod
    def from_spec(cls, spec, *, batch: int = 1,
                  pooled_output: bool = False) -> "AdaptiveEngine":
        """Synthesize the fabric a ``core.spec.RuntimeSpec`` describes:
        maxima from ``spec.maxima`` (required — that IS the fabric),
        dtype from ``spec.execution``, decoder stack provisioned when the
        arch has one.  Topologies are then selected per call with
        ``spec.registers(...)`` — the one configuration surface."""
        if spec.maxima is None:
            raise ValueError(
                "AdaptiveEngine.from_spec needs spec.maxima — the fabric "
                "is synthesized at the maxima, not at one topology "
                "(build them with core.spec.maxima_for)")
        # a constructed RuntimeSpec already fits its own maxima (validated
        # in __post_init__), so no re-check here
        opts = EngineOptions(
            batch=batch, dtype=spec.execution.param_dtype,
            decoder=spec.maxima.layers_dec_max > 0,
            pooled_output=pooled_output)
        return cls(spec.maxima, opts)

    # ------------------------------------------------------------------
    # Parameter structure (synthesis-time buffers)
    # ------------------------------------------------------------------
    def build(self, b: ParamBuilder) -> dict:
        mx = self.mx

        def attn_block() -> dict:
            return {
                "wq": b.param((mx.d_model_max, mx.heads_max, mx.head_dim_max),
                              ("embed", "heads", None)),
                "wk": b.param((mx.d_model_max, mx.heads_max, mx.head_dim_max),
                              ("embed", "heads", None)),
                "wv": b.param((mx.d_model_max, mx.heads_max, mx.head_dim_max),
                              ("embed", "heads", None)),
                "bq": b.param((mx.heads_max, mx.head_dim_max), ("heads", None),
                              init="zeros"),
                "bk": b.param((mx.heads_max, mx.head_dim_max), ("heads", None),
                              init="zeros"),
                "bv": b.param((mx.heads_max, mx.head_dim_max), ("heads", None),
                              init="zeros"),
                "wo": b.param((mx.heads_max, mx.head_dim_max, mx.d_model_max),
                              ("heads", None, "embed")),
                "bo": b.param((mx.d_model_max,), ("embed",), init="zeros"),
            }

        def layer(cross: bool = False) -> dict:
            p = {
                "attn": attn_block(),
                "ln1_g": b.param((mx.d_model_max,), ("embed",), init="ones"),
                "ln1_b": b.param((mx.d_model_max,), ("embed",), init="zeros"),
                "w1": b.param((mx.d_model_max, mx.d_ff_max), ("embed", "ffn")),
                "b1": b.param((mx.d_ff_max,), ("ffn",), init="zeros"),
                "w2": b.param((mx.d_ff_max, mx.d_model_max), ("ffn", "embed")),
                "b2": b.param((mx.d_model_max,), ("embed",), init="zeros"),
                "ln2_g": b.param((mx.d_model_max,), ("embed",), init="ones"),
                "ln2_b": b.param((mx.d_model_max,), ("embed",), init="zeros"),
            }
            if cross:
                p["cross"] = attn_block()
                p["ln3_g"] = b.param((mx.d_model_max,), ("embed",), init="ones")
                p["ln3_b"] = b.param((mx.d_model_max,), ("embed",), init="zeros")
            return p

        p: dict[str, Any] = {
            "embed": b.param((mx.vocab, mx.d_model_max), ("vocab", "embed"),
                             scale=0.02),
            "pos": b.param((mx.seq_max, mx.d_model_max), ("pos", "embed"),
                           scale=0.02),
            "w_out": b.param((mx.d_model_max, mx.out_max), ("embed", "vocab")),
            "b_out": b.param((mx.out_max,), ("vocab",), init="zeros"),
        }
        with b.stacked(mx.layers_enc_max):
            p["enc"] = layer()
        if self.opt.decoder and mx.layers_dec_max:
            with b.stacked(mx.layers_dec_max):
                p["dec"] = layer(cross=True)
        return p

    def init(self, rng: jax.Array) -> dict:
        return self.build(ParamBuilder("init", rng, self.opt.dtype))

    def abstract(self) -> dict:
        return self.build(ParamBuilder("abstract", dtype=self.opt.dtype))

    def axes(self) -> dict:
        return self.build(ParamBuilder("axes", dtype=self.opt.dtype))

    # ------------------------------------------------------------------
    # Masked compute (Eq. 1-7 with live-lane masking)
    # ------------------------------------------------------------------
    def _activate(self, x: jax.Array, act_sel: jax.Array) -> jax.Array:
        """Runtime-selected activation unit (§3.4): 0 = ReLU, 1 = GELU."""
        return jnp.where(act_sel == 1,
                         jax.nn.gelu(x.astype(jnp.float32), approximate=False),
                         jax.nn.relu(x.astype(jnp.float32))).astype(x.dtype)

    def _mha(self, x: jax.Array, kv_src: jax.Array, w: dict,
             regs: TopologyRegisters, *, causal: bool) -> jax.Array:
        """Masked multi-head attention: QKV_PM -> QK_PM -> softmax -> SV_PM."""
        mx = self.mx
        hd_live = regs.head_dim
        h_mask = masking.dim_mask(mx.heads_max, regs.heads)[:, None]
        e_mask = masking.dim_mask(mx.head_dim_max, hd_live)[None, :]
        he_mask = (h_mask * e_mask).astype(x.dtype)

        def proj(src, kernel, bias):
            y = jnp.einsum("bsd,dhe->bshe", src, kernel.astype(src.dtype))
            return (y + bias.astype(src.dtype)) * he_mask

        q = proj(x, w["wq"], w["bq"])
        k = proj(kv_src, w["wk"], w["bk"])
        v = proj(kv_src, w["wv"], w["bv"])
        scale = jax.lax.rsqrt(jnp.maximum(hd_live, 1).astype(jnp.float32))
        s = jnp.einsum("bqhe,bkhe->bhqk", q, k).astype(jnp.float32) * scale
        if causal:
            qpos = jnp.arange(s.shape[-2])[:, None]
            kpos = jnp.arange(s.shape[-1])[None, :]
            s = jnp.where((kpos <= qpos)[None, None], s, masking.NEG_INF)
        kv_live = regs.sequence  # kv length == live sequence for both stacks
        p = masking.masked_softmax(s, kv_live, axis=-1)
        o = jnp.einsum("bhqk,bkhe->bqhe", p.astype(v.dtype), v) * he_mask
        a = jnp.einsum("bqhe,hed->bqd", o, w["wo"].astype(x.dtype))
        return a + w["bo"].astype(x.dtype)

    def _ffn(self, x: jax.Array, w: dict, regs: TopologyRegisters,
             act_sel: jax.Array) -> jax.Array:
        f_mask = masking.dim_mask(self.mx.d_ff_max, regs.hidden, x.dtype)
        f1 = jnp.einsum("bsd,df->bsf", x, w["w1"].astype(x.dtype))
        f1 = self._activate((f1 + w["b1"].astype(x.dtype)) * f_mask, act_sel)
        f1 = f1 * f_mask
        f2 = jnp.einsum("bsf,fd->bsd", f1, w["w2"].astype(x.dtype))
        return f2 + w["b2"].astype(x.dtype)

    def _layer(self, x: jax.Array, w: dict, regs: TopologyRegisters,
               act_sel: jax.Array, *, causal: bool,
               enc_out: jax.Array | None = None) -> jax.Array:
        d = regs.embeddings
        a = self._mha(x, x, w["attn"], regs, causal=causal)
        x = masking.masked_layernorm(x + a, w["ln1_g"], w["ln1_b"], d)
        if enc_out is not None:
            c = self._mha(x, enc_out, w["cross"], regs, causal=False)
            x = masking.masked_layernorm(x + c, w["ln3_g"], w["ln3_b"], d)
        f = self._ffn(x, w, regs, act_sel)
        return masking.masked_layernorm(x + f, w["ln2_g"], w["ln2_b"], d)

    def _embed(self, params: dict, tokens: jax.Array,
               regs: TopologyRegisters) -> jax.Array:
        x = params["embed"].astype(self.opt.dtype)[tokens]
        x = x + params["pos"].astype(self.opt.dtype)[: tokens.shape[1]][None]
        x = masking.mask_lanes(x, regs.embeddings, axis=-1)
        return masking.mask_lanes(x, regs.sequence, axis=1)

    def _stack(self, x: jax.Array, stacked: dict, n_live: jax.Array,
               regs: TopologyRegisters, act_sel: jax.Array, *,
               causal: bool, enc_out: jax.Array | None = None) -> jax.Array:
        n_max = jax.tree.leaves(stacked)[0].shape[0]

        def body(i, h):
            w = jax.tree.map(lambda l: jax.lax.dynamic_index_in_dim(
                l, i, keepdims=False), stacked)
            h2 = self._layer(h, w, regs, act_sel, causal=causal,
                             enc_out=enc_out)
            return jnp.where(i < n_live, h2, h)  # idle layers pass through

        return jax.lax.fori_loop(0, n_max, body, x)

    # ------------------------------------------------------------------
    # The compiled step (Alg. 18 body)
    # ------------------------------------------------------------------
    def serve_fn(self) -> Callable:
        """Returns f(params, regs, act_sel, tokens[, tgt_tokens]) -> logits."""
        mx, opt = self.mx, self.opt

        def step(params: dict, regs: TopologyRegisters, act_sel: jax.Array,
                 tokens: jax.Array, tgt_tokens: jax.Array | None = None):
            x = self._embed(params, tokens, regs)
            x = self._stack(x, params["enc"], regs.layers_enc, regs, act_sel,
                            causal=False)
            if opt.decoder and "dec" in params:
                y = self._embed(params, tgt_tokens, regs)
                y = self._stack(y, params["dec"], regs.layers_dec, regs,
                                act_sel, causal=True, enc_out=x)
                x = jnp.where(regs.layers_dec > 0, y, x)
            if opt.pooled_output:
                x = masking.masked_mean_pool(x, regs.sequence)[:, None]
            logits = jnp.einsum("bsd,do->bso",
                                x, params["w_out"].astype(x.dtype))
            logits = logits + params["b_out"].astype(x.dtype)
            return masking.mask_lanes(logits, regs.out, axis=-1)

        return step

    def compile(self, donate: bool = False):
        """'Synthesis': jit once; every later topology is a register write.

        ``strict_jit`` makes a requested-but-unusable donation raise
        under ``REPRO_STRICT=1`` instead of silently copying the padded
        maximal weight buffers every call."""
        if self._jitted is None:
            self._jitted = strict_jit(self.serve_fn(),
                                      donate_argnums=() if not donate
                                      else (0,))
        return self._jitted

    def trace_count(self) -> int:
        """Number of traces the compiled step has accumulated (must stay 1)."""
        if self._jitted is None:
            return 0
        return self._jitted._cache_size()


# ---------------------------------------------------------------------------
# Weight packing: unpadded topology weights -> padded engine buffers
# ---------------------------------------------------------------------------
def _pad_to(a: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(a.shape, shape)]
    return jnp.pad(a, pads)


def pack(engine: AdaptiveEngine, net: dict) -> dict:
    """Pack an unpadded post-LN network (see ``engine_ref.random_network``)
    into the engine's padded buffers — the paper's weight-loading units
    (§3.1-3.3), including KV replication for GQA topologies."""
    mx = engine.mx
    base = jax.tree.map(jnp.zeros_like,
                        engine.init(jax.random.PRNGKey(0)))
    d, h, hd = net["d_model"], net["heads"], net["head_dim"]
    kv = net.get("kv_heads", h)
    rep = h // kv

    def pack_attn(dst: dict, a: dict) -> dict:
        def split(w_, n):  # [d, n*hd] -> [d, n, hd]
            return w_.reshape(w_.shape[0], n, hd)
        wq = split(a["wq"], h)
        wk = jnp.repeat(split(a["wk"], kv), rep, axis=1)
        wv = jnp.repeat(split(a["wv"], kv), rep, axis=1)
        out = dict(dst)
        out["wq"] = _pad_to(wq, dst["wq"].shape)
        out["wk"] = _pad_to(wk, dst["wk"].shape)
        out["wv"] = _pad_to(wv, dst["wv"].shape)
        out["bq"] = _pad_to(a["bq"].reshape(h, hd), dst["bq"].shape)
        out["bk"] = _pad_to(jnp.repeat(a["bk"].reshape(kv, hd), rep, 0),
                            dst["bk"].shape)
        out["bv"] = _pad_to(jnp.repeat(a["bv"].reshape(kv, hd), rep, 0),
                            dst["bv"].shape)
        out["wo"] = _pad_to(a["wo"].reshape(h, hd, d), dst["wo"].shape)
        out["bo"] = _pad_to(a["bo"], dst["bo"].shape)
        return out

    def pack_layer(dst: dict, src: dict) -> dict:
        out = {"attn": pack_attn(dst["attn"], src["attn"])}
        for k_ in ("ln1_g", "ln1_b", "ln2_g", "ln2_b",
                   "w1", "b1", "w2", "b2"):
            out[k_] = _pad_to(src[k_], dst[k_].shape)
        if "cross" in src:
            out["cross"] = pack_attn(dst["cross"], src["cross"])
            out["ln3_g"] = _pad_to(src["ln3_g"], dst["ln3_g"].shape)
            out["ln3_b"] = _pad_to(src["ln3_b"], dst["ln3_b"].shape)
        return out

    packed = dict(base)
    packed["embed"] = _pad_to(net["embed"], base["embed"].shape)
    packed["pos"] = _pad_to(net["pos"], base["pos"].shape)
    packed["w_out"] = _pad_to(net["w_out"], base["w_out"].shape)
    packed["b_out"] = _pad_to(net["b_out"], base["b_out"].shape)

    def stack_layers(dst_stacked, layers_list, n_max):
        one = jax.tree.map(lambda l: l[0], dst_stacked)
        packed_layers = [pack_layer(one, lp) for lp in layers_list]
        while len(packed_layers) < n_max:
            packed_layers.append(jax.tree.map(jnp.zeros_like, one))
        return jax.tree.map(lambda *ls: jnp.stack(ls), *packed_layers)

    packed["enc"] = stack_layers(base["enc"], net["enc_layers"],
                                 mx.layers_enc_max)
    if "dec" in base and net.get("dec_layers"):
        packed["dec"] = stack_layers(base["dec"], net["dec_layers"],
                                     mx.layers_dec_max)
    return packed
