"""Paged KV-cache block allocator — the paper's tiling discipline applied
to decode-time memory.

ADAPTOR bounds on-chip buffers by tiling weight matrices to fixed
TS x TS blocks; the serving analogue is to tile the *KV cache* along the
sequence axis into fixed-size token blocks and allocate them on demand.
A dense ``[max_batch, max_len]`` cache charges every request for the
worst case; a paged pool of shape ``[num_blocks, block_size, ...]``
charges each request ``ceil(len / block_size)`` blocks, so admitted
concurrency is bounded by *actual* demand (arXiv:2208.03646's
length-adaptive win) and one pool serves any mix of request lengths the
way NPE's fixed overlay serves varied topologies (arXiv:2104.06535).

Host/device split:

* ``BlockAllocator`` — host-side free-list bookkeeping (which physical
  block belongs to which slot).  Pure Python, O(1) alloc/free, no jax.
* block tables — ``[max_batch, blocks_per_slot]`` int32 device array
  owned by the serving engine; logical block ``i`` of a slot lives in
  physical pool block ``table[slot, i]``.

Block 0 is the **null block**: never handed out, it absorbs the writes
of idle slots inside the fused decode step and backs unallocated table
entries, so the device step needs no host intervention to stay safe.
"""
from __future__ import annotations

import dataclasses


NULL_BLOCK = 0


def blocks_for_tokens(num_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``num_tokens`` cache positions."""
    return max(-(-num_tokens // block_size), 0)


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Pool geometry (the 'synthesis parameters' of the KV memory).

    ``num_blocks`` counts *usable* blocks; the null block is allocated
    on top of it, so the pool arrays have ``num_blocks + 1`` rows.
    """

    block_size: int = 16
    num_blocks: int = 0

    def __post_init__(self):
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive, got {self.block_size}")
        if self.num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {self.num_blocks}")

    @property
    def pool_blocks(self) -> int:
        """Physical rows in the pool arrays (usable blocks + null block)."""
        return self.num_blocks + 1


@dataclasses.dataclass(frozen=True)
class FragmentationStats:
    """Pool occupancy + internal fragmentation snapshot."""

    total_blocks: int
    free_blocks: int
    used_blocks: int
    # tokens actually resident vs token capacity of the allocated blocks:
    # the gap is internal fragmentation (tail of each slot's last block)
    used_tokens: int
    capacity_tokens: int

    @property
    def utilization(self) -> float:
        """Fraction of the pool's usable blocks currently allocated."""
        return self.used_blocks / max(self.total_blocks, 1)

    @property
    def internal_fragmentation(self) -> float:
        """Wasted fraction *inside* allocated blocks (0 when empty)."""
        if self.capacity_tokens == 0:
            return 0.0
        return 1.0 - self.used_tokens / self.capacity_tokens


class BlockAllocator:
    """Free-list allocator over the paged KV pool (host side).

    LIFO free list: a just-freed block is the next handed out, which
    keeps the hot region of the pool small (HBM page locality).
    """

    def __init__(self, config: PagingConfig):
        self.config = config
        # block 0 is the null block and never enters the free list
        self._free: list[int] = list(range(config.pool_blocks - 1, 0, -1))
        self._used_tokens = 0  # engine-reported resident tokens

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.config.num_blocks - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` blocks, or None (and no change) if unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        taken = self._free[len(self._free) - n:]
        del self._free[len(self._free) - n:]
        return taken[::-1]

    def free(self, blocks: list[int]) -> None:
        seen = set(self._free)
        for b in blocks:
            if not 0 < b < self.config.pool_blocks:
                raise ValueError(f"block id {b} outside pool")
            if b in seen:
                raise ValueError(f"double free of block {b}")
            seen.add(b)
        self._free.extend(reversed(blocks))

    def set_used_tokens(self, n: int) -> None:
        """Engine hook: tokens currently resident across all slots."""
        self._used_tokens = n

    def stats(self) -> FragmentationStats:
        cfg = self.config
        used = self.num_used
        return FragmentationStats(
            total_blocks=cfg.num_blocks,
            free_blocks=self.num_free,
            used_blocks=used,
            used_tokens=self._used_tokens,
            capacity_tokens=used * cfg.block_size)
