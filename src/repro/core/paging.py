"""Paged KV-cache block allocator — the paper's tiling discipline applied
to decode-time memory.

ADAPTOR bounds on-chip buffers by tiling weight matrices to fixed
TS x TS blocks; the serving analogue is to tile the *KV cache* along the
sequence axis into fixed-size token blocks and allocate them on demand.
A dense ``[max_batch, max_len]`` cache charges every request for the
worst case; a paged pool of shape ``[num_blocks, block_size, ...]``
charges each request ``ceil(len / block_size)`` blocks, so admitted
concurrency is bounded by *actual* demand (arXiv:2208.03646's
length-adaptive win) and one pool serves any mix of request lengths the
way NPE's fixed overlay serves varied topologies (arXiv:2104.06535).

Host/device split:

* ``BlockAllocator`` — host-side free-list bookkeeping (which physical
  block belongs to which slot).  Pure Python, O(1) alloc/free, no jax.
* block tables — ``[max_batch, blocks_per_slot]`` int32 device array
  owned by the serving engine; logical block ``i`` of a slot lives in
  physical pool block ``table[slot, i]``.

Block 0 is the **null block**: never handed out, it absorbs the writes
of idle slots inside the fused decode step and backs unallocated table
entries, so the device step needs no host intervention to stay safe.

Prefix sharing (PR 7) adds two layers on top of the free list, both
pure host-side bookkeeping — the device pool and the fused step are
untouched:

* **refcounts** — every allocated physical block carries a reference
  count.  ``alloc`` hands out blocks at refcount 1; a cache-hit request
  maps an already-resident block with ``incref`` instead of allocating
  a duplicate; release paths ``decref`` and a block returns to the free
  list only at refcount zero.
* **``PrefixCache``** — a radix trie over *token-block* granules: each
  node covers exactly ``block_size`` prompt tokens and owns the
  physical block holding their KV.  Children are keyed on a rolling
  hash ``hash((parent_chain, tokens))`` with the token tuple verified
  on every walk, so a hash collision can only cost a missed share,
  never serve wrong KV.  Nodes whose block's refcount is zero stay
  *parked* in the trie (resident but unreferenced) as an LRU eviction
  tier: when the pool runs dry they are freed oldest-first before the
  engine resorts to preempting live requests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable


NULL_BLOCK = 0


def blocks_for_tokens(num_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``num_tokens`` cache positions."""
    return max(-(-num_tokens // block_size), 0)


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Pool geometry (the 'synthesis parameters' of the KV memory).

    ``num_blocks`` counts *usable* blocks; the null block is allocated
    on top of it, so the pool arrays have ``num_blocks + 1`` rows.
    """

    block_size: int = 16
    num_blocks: int = 0

    def __post_init__(self):
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive, got {self.block_size}")
        if self.num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {self.num_blocks}")

    @property
    def pool_blocks(self) -> int:
        """Physical rows in the pool arrays (usable blocks + null block)."""
        return self.num_blocks + 1


@dataclasses.dataclass(frozen=True)
class FragmentationStats:
    """Pool occupancy + internal fragmentation snapshot.

    With prefix caching on, ``used_blocks`` counts *physical* residency:
    a block mapped by three requests counts once (it is ``shared``), and
    a block kept only by the prefix trie at refcount zero still occupies
    the pool (``cached``) until LRU eviction reclaims it.
    """

    total_blocks: int
    free_blocks: int
    used_blocks: int
    # tokens actually resident vs token capacity of the allocated blocks:
    # the gap is internal fragmentation (tail of each slot's last block)
    used_tokens: int
    capacity_tokens: int
    # blocks mapped by >1 request (refcount >= 2)
    shared_blocks: int = 0
    # unreferenced blocks parked in the prefix trie (refcount == 0,
    # not on the free list) — reclaimable by LRU eviction
    cached_blocks: int = 0

    @property
    def utilization(self) -> float:
        """Fraction of the pool's usable blocks currently allocated."""
        return self.used_blocks / max(self.total_blocks, 1)

    @property
    def internal_fragmentation(self) -> float:
        """Wasted fraction *inside* allocated blocks (0 when empty)."""
        if self.capacity_tokens == 0:
            return 0.0
        return 1.0 - self.used_tokens / self.capacity_tokens


class BlockAllocator:
    """Free-list allocator over the paged KV pool (host side).

    LIFO free list: a just-freed block is the next handed out, which
    keeps the hot region of the pool small (HBM page locality).
    """

    def __init__(self, config: PagingConfig):
        self.config = config
        # block 0 is the null block and never enters the free list
        self._free: list[int] = list(range(config.pool_blocks - 1, 0, -1))
        # persistent mirror of _free so the double-free check in free()
        # is O(len(blocks)), not O(pool) per call
        self._free_set: set[int] = set(self._free)
        # per-block reference counts; free blocks and the null block sit
        # at 0, alloc hands blocks out at 1, prefix sharing increfs
        self._refs: list[int] = [0] * config.pool_blocks
        self._used_tokens = 0  # engine-reported resident tokens

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.config.num_blocks - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` blocks at refcount 1, or None (and no change) if
        unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        taken = self._free[len(self._free) - n:]
        del self._free[len(self._free) - n:]
        for b in taken:
            self._free_set.discard(b)
            self._refs[b] = 1
        return taken[::-1]

    def ref(self, block: int) -> int:
        """Current reference count of ``block``."""
        return self._refs[block]

    def incref(self, blocks: list[int]) -> None:
        """Map already-resident blocks into one more request."""
        for b in blocks:
            if not 0 < b < self.config.pool_blocks:
                raise ValueError(f"block id {b} outside pool")
            if b in self._free_set:
                raise ValueError(f"incref of free block {b}")
            self._refs[b] += 1

    def decref(self, blocks: list[int]) -> list[int]:
        """Drop one reference per block; returns the blocks that hit
        refcount zero (in input order).  Does NOT free them — the caller
        routes zeros through the prefix cache's ``park`` (trie-resident
        blocks stay for reuse) and ``free``s the remainder."""
        zeros: list[int] = []
        for b in blocks:
            if not 0 < b < self.config.pool_blocks:
                raise ValueError(f"block id {b} outside pool")
            if self._refs[b] <= 0:
                raise ValueError(f"decref of unreferenced block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                zeros.append(b)
        return zeros

    def truncate(self, blocks: list[int], keep: int) -> \
            tuple[list[int], list[int]]:
        """Block-tail truncate — the speculative-rollback release.

        Drops this request's reference on ``blocks[keep:]`` and returns
        ``(kept, zeros)``: the retained head and the tail blocks whose
        refcount hit zero, in tail order.  Like :meth:`decref`, nothing
        is freed here — the caller routes ``zeros`` through
        ``PrefixCache.park`` (a trie-owned tail block parks, never
        frees) and ``free``s the remainder.  A tail block another
        request still maps just loses one reference and stays resident.
        """
        if keep < 0:
            raise ValueError(f"cannot keep {keep} blocks")
        if keep >= len(blocks):
            return list(blocks), []
        return list(blocks[:keep]), self.decref(blocks[keep:])

    def free(self, blocks: list[int]) -> None:
        """Return blocks to the free list.  Accepts refcount <= 1 (the
        sole owner may free directly, skipping decref); freeing a block
        other requests still map is an error."""
        for b in blocks:
            if not 0 < b < self.config.pool_blocks:
                raise ValueError(f"block id {b} outside pool")
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            if self._refs[b] > 1:
                raise ValueError(
                    f"freeing block {b} with refcount {self._refs[b]} "
                    "(still mapped by another request — decref instead)")
            self._refs[b] = 0
            self._free_set.add(b)
        self._free.extend(reversed(blocks))

    def set_used_tokens(self, n: int) -> None:
        """Engine hook: tokens currently resident across all slots."""
        self._used_tokens = n

    def stats(self) -> FragmentationStats:
        cfg = self.config
        used = self.num_used
        shared = sum(1 for r in self._refs if r >= 2)
        cached = sum(1 for b in range(1, cfg.pool_blocks)
                     if self._refs[b] == 0 and b not in self._free_set)
        return FragmentationStats(
            total_blocks=cfg.num_blocks,
            free_blocks=self.num_free,
            used_blocks=used,
            used_tokens=self._used_tokens,
            capacity_tokens=used * cfg.block_size,
            shared_blocks=shared,
            cached_blocks=cached)


class _TrieNode:
    """One block-granule of cached prompt: ``block_size`` tokens and the
    physical block holding their KV."""

    __slots__ = ("chain", "tokens", "block", "parent", "children", "tick")

    def __init__(self, chain: int, tokens: tuple[int, ...], block: int,
                 parent: "Any"):
        self.chain = chain          # rolling hash up to and incl. this node
        self.tokens = tokens        # verified on every walk
        self.block = block
        self.parent = parent        # _TrieNode | namespace-root sentinel
        self.children: dict[int, _TrieNode] = {}
        self.tick = 0               # LRU stamp while parked


class _Root:
    """Per-namespace virtual root (no block of its own)."""

    __slots__ = ("chain", "children")

    def __init__(self, namespace: Hashable):
        self.chain = hash(("prefix-cache-ns", namespace))
        self.children: dict[int, _TrieNode] = {}


@dataclasses.dataclass
class PrefixHit:
    """Result of a trie lookup: the cached span a request may map.

    ``blocks`` are whole cached blocks (``len(blocks) * block_size``
    tokens reusable as-is); ``fork_block``/``fork_tokens`` describe a
    trailing partial match whose first ``fork_tokens`` rows must be
    copy-on-write forked into a private block before the request may
    write the remainder.
    """

    blocks: list[int]
    tokens: int
    fork_block: int | None = None
    fork_tokens: int = 0
    nodes: list = dataclasses.field(default_factory=list)
    fork_node: Any = None

    @property
    def cached_tokens(self) -> int:
        return self.tokens + self.fork_tokens


class PrefixCache:
    """Radix trie over token-block hashes + LRU tier of parked blocks.

    Pure host-side bookkeeping, same contract as the allocator: no jax,
    no device access.  The engine owns when to ``lookup``/``acquire``
    (admission), ``insert`` (prefill completion), ``park`` (release
    decref hit zero) and ``evict`` (pool ran dry).
    """

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self.block_size = allocator.config.block_size
        self._roots: dict[Hashable, _Root] = {}
        self._node_of_block: dict[int, _TrieNode] = {}
        self._parked: dict[int, _TrieNode] = {}   # block -> node, ref==0
        self._tick = 0
        self.evictions = 0

    # -- introspection -------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return len(self._node_of_block)

    @property
    def num_parked(self) -> int:
        return len(self._parked)

    def owns(self, block: int) -> bool:
        return block in self._node_of_block

    def _next_tick(self) -> int:
        self._tick += 1
        return self._tick

    def _root(self, namespace: Hashable) -> _Root:
        root = self._roots.get(namespace)
        if root is None:
            root = self._roots[namespace] = _Root(namespace)
        return root

    @staticmethod
    def _key(chain: int, tokens: tuple[int, ...]) -> int:
        return hash((chain, tokens))

    # -- admission side ------------------------------------------------
    def lookup(self, namespace: Hashable, tokens: list[int],
               limit: int | None = None) -> PrefixHit:
        """Longest cached prefix of ``tokens`` (capped at ``limit``).

        Walks whole-block children first, then scans the final node's
        children for the longest partial token match (the CoW fork
        source).  Never mutates refcounts — pair with :meth:`acquire`.
        """
        bs = self.block_size
        limit = len(tokens) if limit is None else min(limit, len(tokens))
        node: Any = self._root(namespace)
        hit = PrefixHit(blocks=[], tokens=0)
        i = 0
        while i + bs <= limit:
            chunk = tuple(tokens[i:i + bs])
            child = node.children.get(self._key(node.chain, chunk))
            if child is None or child.tokens != chunk:
                break
            hit.blocks.append(child.block)
            hit.nodes.append(child)
            node = child
            i += bs
        hit.tokens = i
        # partial tail: longest common prefix with any child, >= 1 token
        rem = limit - i
        if rem > 0:
            best, best_len = None, 0
            for child in node.children.values():
                k = 0
                for a, b in zip(child.tokens, tokens[i:i + rem]):
                    if a != b:
                        break
                    k += 1
                if k > best_len:
                    best, best_len = child, k
            if best is not None and best_len >= 1:
                hit.fork_block = best.block
                hit.fork_tokens = best_len
                hit.fork_node = best
        return hit

    def acquire(self, hit: PrefixHit) -> None:
        """Pin a hit before any allocation that could evict: incref all
        matched blocks (the fork source too — it must survive until the
        CoW copy lands) and unpark their nodes from the LRU tier."""
        blocks = list(hit.blocks)
        if hit.fork_block is not None:
            blocks.append(hit.fork_block)
        self.allocator.incref(blocks)
        tick = self._next_tick()
        for node in [*hit.nodes, *([hit.fork_node] if hit.fork_node else [])]:
            node.tick = tick
            self._parked.pop(node.block, None)

    def release(self, hit: PrefixHit) -> None:
        """Roll back an :meth:`acquire` (admission failed mid-way)."""
        blocks = list(hit.blocks)
        if hit.fork_block is not None:
            blocks.append(hit.fork_block)
        self.park(self.allocator.decref(blocks))

    def drop_fork_source(self, hit: PrefixHit) -> None:
        """Release just the fork source once its rows are copied."""
        if hit.fork_block is not None:
            self.park(self.allocator.decref([hit.fork_block]))

    # -- registration / release side -----------------------------------
    def insert(self, namespace: Hashable, tokens: list[int],
               blocks: list[int]) -> int:
        """Register a prefilled prompt's whole blocks: ``blocks[j]``
        holds KV for ``tokens[j*bs:(j+1)*bs]``.  An existing node always
        wins (its KV is identical by construction) and the caller's
        duplicate block simply stays slot-private; new nodes take
        ownership of the caller's block (which keeps its current
        refcount — the registering slot still maps it).  Returns the
        number of newly registered blocks."""
        bs = self.block_size
        node: Any = self._root(namespace)
        added = 0
        for j, block in enumerate(blocks):
            chunk = tuple(tokens[j * bs:(j + 1) * bs])
            if len(chunk) != bs:
                break
            key = self._key(node.chain, chunk)
            child = node.children.get(key)
            if child is not None:
                if child.tokens != chunk:
                    break  # hash collision: skip registration, never alias
                node = child
                continue
            if block in self._node_of_block:
                break  # block already registered under another path
            child = _TrieNode(self._key(node.chain, chunk), chunk, block, node)
            node.children[key] = child
            self._node_of_block[block] = child
            node = child
            added += 1
        return added

    def park(self, blocks: list[int]) -> list[int]:
        """Route decref-to-zero blocks: trie-owned ones stay resident as
        parked LRU entries; returns the rest for ``allocator.free``."""
        remainder: list[int] = []
        tick = self._next_tick()
        for b in blocks:
            node = self._node_of_block.get(b)
            if node is None:
                remainder.append(b)
            else:
                node.tick = tick
                self._parked[b] = node
        return remainder

    # -- eviction ------------------------------------------------------
    def evict(self, n: int) -> int:
        """Free up to ``n`` parked blocks, least recently used first,
        leaves before parents (a node with children anchors its
        subtree's chain and is skipped until they go).  May free fewer
        than ``n``; the caller falls back to preemption."""
        freed = 0
        while freed < n:
            victims = sorted(
                (node for node in self._parked.values()
                 if not node.children),
                key=lambda nd: nd.tick)
            if not victims:
                break
            for node in victims:
                if freed >= n:
                    break
                self._unlink(node)
                self.allocator.free([node.block])
                freed += 1
                self.evictions += 1
        return freed

    def _unlink(self, node: _TrieNode) -> None:
        parent = node.parent
        key = self._key(parent.chain, node.tokens)
        if parent.children.get(key) is node:
            del parent.children[key]
        self._parked.pop(node.block, None)
        self._node_of_block.pop(node.block, None)
