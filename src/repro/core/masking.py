"""Masked primitives — runtime-adaptive equivalents of clock-gated modules.

The FPGA activates only the PEs a topology needs; idle DSP lanes hold
garbage that never reaches the output.  In a compiled XLA program every
lane *is* computed, so correctness comes from masking instead: statistics
(LayerNorm mean/variance, softmax normalizer) are taken over the *live*
dims only, and dead lanes are zeroed before they can contaminate live ones.

Every function takes static maxima shapes and traced live-extent scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def dim_mask(max_dim: int, live, dtype=jnp.float32) -> jax.Array:
    """[max_dim] mask: 1.0 for lanes < live, else 0.0 (live may be traced)."""
    return (jnp.arange(max_dim) < live).astype(dtype)


def masked_layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                     d_live, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the first ``d_live`` lanes of the last dim (Eq. 4)."""
    m = dim_mask(x.shape[-1], d_live)
    n = jnp.maximum(d_live, 1).astype(jnp.float32)
    x32 = x.astype(jnp.float32) * m
    mu = jnp.sum(x32, axis=-1, keepdims=True) / n
    cent = (x32 - mu) * m
    var = jnp.sum(jnp.square(cent), axis=-1, keepdims=True) / n
    y = cent * jax.lax.rsqrt(var + eps)
    return ((y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)) * m) \
        .astype(x.dtype)


def masked_rmsnorm(x: jax.Array, gamma: jax.Array, d_live,
                   eps: float = 1e-6) -> jax.Array:
    m = dim_mask(x.shape[-1], d_live)
    n = jnp.maximum(d_live, 1).astype(jnp.float32)
    x32 = x.astype(jnp.float32) * m
    var = jnp.sum(jnp.square(x32), axis=-1, keepdims=True) / n
    return (x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32) * m) \
        .astype(x.dtype)


def masked_softmax(scores: jax.Array, live_len, axis: int = -1) -> jax.Array:
    """Softmax over the first ``live_len`` entries of ``axis`` (Eq. 5 with
    the Mask() of Eq. 1); dead entries get exactly 0 weight."""
    size = scores.shape[axis]
    live = jnp.arange(size) < live_len
    shape = [1] * scores.ndim
    shape[axis] = size
    live = live.reshape(shape)
    s = jnp.where(live, scores.astype(jnp.float32), NEG_INF)
    out = jax.nn.softmax(s, axis=axis)
    return jnp.where(live, out, 0.0)


def masked_mean_pool(x: jax.Array, seq_live) -> jax.Array:
    """[B, S_max, D] -> [B, D], averaging live positions only."""
    m = dim_mask(x.shape[1], seq_live)[None, :, None]
    n = jnp.maximum(seq_live, 1).astype(jnp.float32)
    return (jnp.sum(x.astype(jnp.float32) * m, axis=1) / n).astype(x.dtype)


def mask_lanes(x: jax.Array, live, axis: int = -1) -> jax.Array:
    """Zero lanes >= live along ``axis``."""
    size = x.shape[axis]
    m = jnp.arange(size) < live
    shape = [1] * x.ndim
    shape[axis] = size
    return x * m.reshape(shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Per-slot (batched) variants — multi-topology serving.  Every slot of a
# batch may run a *different* topology, so the live extent is a [B] vector
# rather than one scalar register.
# ---------------------------------------------------------------------------
def slot_mask(max_dim: int, live: jax.Array, dtype=jnp.float32) -> jax.Array:
    """[B, max_dim] mask: row b is 1.0 for lanes < live[b]."""
    return (jnp.arange(max_dim)[None, :] < live[:, None]).astype(dtype)


def masked_rmsnorm_slots(x: jax.Array, gamma: jax.Array,
                         d_live: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm of ``x [B, S, D]`` over each slot's first ``d_live[b]``
    lanes; ``gamma`` is per-slot ``[B, D]`` (gathered from a model table)."""
    m = slot_mask(x.shape[-1], d_live)[:, None, :]
    n = jnp.maximum(d_live, 1).astype(jnp.float32)[:, None, None]
    x32 = x.astype(jnp.float32) * m
    var = jnp.sum(jnp.square(x32), axis=-1, keepdims=True) / n
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)[:, None, :] * m).astype(x.dtype)


def lane_mask(num_lanes: int, n_live: jax.Array) -> jax.Array:
    """[B, W] bool: lane l of slot b is live iff l < n_live[b].

    The chunked mixed step advances every slot by up to ``num_lanes``
    query lanes per dispatch; a decoding slot uses one lane, a prefilling
    slot up to a chunk, an idle slot none — dead lanes compute garbage
    that is dropped at the KV write and the sampling gather.
    """
    return jnp.arange(num_lanes)[None, :] < n_live[:, None]


def chunk_causal_mask(max_kv: int, start: jax.Array,
                      num_lanes: int) -> jax.Array:
    """[B, W, max_kv] bool: query lane l (cache position start[b] + l)
    sees cache positions <= start[b] + l.

    With chunk K/V written *before* the attend, this one mask covers both
    halves of chunked prefill attention: causal intra-chunk masking and
    the full view of the prior cache.
    """
    q_pos = start[:, None] + jnp.arange(num_lanes)[None, :]
    return jnp.arange(max_kv)[None, None, :] <= q_pos[:, :, None]


def masked_layernorm_slots(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                           d_live: jax.Array,
                           eps: float = 1e-5) -> jax.Array:
    """LayerNorm of ``x [B, S, D]`` with per-slot live width and per-slot
    ``[B, D]`` scale/bias."""
    m = slot_mask(x.shape[-1], d_live)[:, None, :]
    n = jnp.maximum(d_live, 1).astype(jnp.float32)[:, None, None]
    x32 = x.astype(jnp.float32) * m
    mu = jnp.sum(x32, axis=-1, keepdims=True) / n
    cent = (x32 - mu) * m
    var = jnp.sum(jnp.square(cent), axis=-1, keepdims=True) / n
    y = cent * jax.lax.rsqrt(var + eps)
    out = y * gamma.astype(jnp.float32)[:, None, :] \
        + beta.astype(jnp.float32)[:, None, :]
    return (out * m).astype(x.dtype)
