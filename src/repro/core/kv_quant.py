"""The KV-cache codec: quantize-on-write / dequantize-on-read decode state.

ADAPTOR is "fully quantized for computational efficiency and portability"
(paper C6) — the FPGA keeps *all* resident state in fixed point, not just
the weight matrices.  The serving analogue: the KV cache is the binding
resource at high concurrency (cache bytes bound admitted requests long
before FLOPs do), so storing it at int8 instead of bf16 nearly doubles
concurrent capacity at equal HBM.

One ``CacheCodec`` policy object rules every cache layout:

* **compute** — values are stored in the compute dtype (bf16); the codec
  is the identity and no scale arrays exist.  Bit-identical to the
  historical behaviour.
* **int8**    — values are stored as symmetric int8 with one f32 scale
  per *cache row* (per (position, kv-head) for GQA K/V, per position for
  MLA latents), reduced over the trailing feature dim.  Write-local:
  quantizing a new token touches only its own row, so the fused decode
  step stays a pure scatter.  Scales live in arrays shaped like the
  values minus the feature dim and ride beside the dense rows or the
  paged pool (``[NB, bs, kv]`` for the pool — one scale per block entry
  per kv head), through the same block tables, inserts and donation.

``encode``/``decode`` are the only quantization math; ``store``/``load``
are the call-site helpers that collapse to a no-op in compute mode, so
every attention variant carries exactly one codec line per cache access.

Storage cost per cached feature row of width ``d``: ``d`` bytes of int8
values + 4 bytes of f32 scale, vs ``2 d`` bytes of bf16 — a
``2 d / (d + 4)`` compression (1.88x at head_dim 64, 1.94x at 128).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

KV_DTYPES = ("compute", "int8")

# Keeps a zero row's scale finite; any value quantizes to 0 against it.
_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class CacheCodec:
    """Frozen per-engine policy: how cache rows are stored and recovered.

    ``kv_dtype="compute"`` is the identity codec (no scales, no casts
    beyond the storage dtype); ``"int8"`` is symmetric per-row int8 with
    f32 scales reduced over the trailing feature dim.
    """

    kv_dtype: str = "compute"

    def __post_init__(self) -> None:
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"CacheCodec.kv_dtype={self.kv_dtype!r} is not one of "
                f"{KV_DTYPES}")

    @property
    def quantized(self) -> bool:
        return self.kv_dtype == "int8"

    def storage_dtype(self, compute_dtype: Any = jnp.bfloat16):
        """dtype of the cache *values* arrays."""
        return jnp.int8 if self.quantized else compute_dtype

    # ------------------------------------------------------------------
    # The quantization math (int8 mode)
    # ------------------------------------------------------------------
    def encode(self, x: jax.Array) -> tuple[jax.Array, jax.Array]:
        """float ``[..., d]`` -> (int8 values ``[..., d]``, f32 scales
        ``[...]``), symmetric per-row: scale = amax(|row|) / 127."""
        x32 = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x32), axis=-1)
        scale = jnp.maximum(amax, _EPS) / 127.0
        q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127)
        return q.astype(jnp.int8), scale

    def decode(self, values: jax.Array, scale: jax.Array,
               dtype: Any = jnp.bfloat16) -> jax.Array:
        """int8 values + per-row scales -> float ``[..., d]``."""
        out = values.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
        return out.astype(dtype)

    # ------------------------------------------------------------------
    # Call-site helpers (identity in compute mode)
    # ------------------------------------------------------------------
    def store(self, x: jax.Array, store_dtype: Any
              ) -> tuple[jax.Array, jax.Array | None]:
        """Values (+ scales, or None) ready for the cache scatter."""
        if not self.quantized:
            return x.astype(store_dtype), None
        return self.encode(x)

    def load(self, values: jax.Array, scale: jax.Array | None,
             dtype: Any = jnp.bfloat16) -> jax.Array:
        """A float view of stored values (pass-through in compute mode)."""
        if not self.quantized:
            return values
        return self.decode(values, scale, dtype)

    # ------------------------------------------------------------------
    # Cache construction
    # ------------------------------------------------------------------
    def cache_arrays(self, shape: tuple[int, ...], *,
                     compute_dtype: Any = jnp.bfloat16,
                     abstract: bool = False):
        """(values, scales-or-None) leaves for one cache tensor whose
        trailing dim is the quantized feature dim."""
        vd = self.storage_dtype(compute_dtype)
        if abstract:
            vals = jax.ShapeDtypeStruct(shape, vd)
            sc = jax.ShapeDtypeStruct(shape[:-1], jnp.float32) \
                if self.quantized else None
        else:
            vals = jnp.zeros(shape, vd)
            sc = jnp.zeros(shape[:-1], jnp.float32) if self.quantized else None
        return vals, sc

    def bytes_per_feature_row(self, d: int, compute_dtype: Any = jnp.bfloat16
                              ) -> int:
        """HBM bytes one cached row of width ``d`` costs (the
        memory-per-slot arithmetic used by capacity planning)."""
        if self.quantized:
            return d + 4                       # int8 values + f32 scale
        return d * jnp.dtype(compute_dtype).itemsize


FLOAT_CODEC = CacheCodec("compute")


def cache_put(values: jax.Array, scales: jax.Array | None, idx: tuple,
              new_vals: jax.Array, new_scales: jax.Array | None
              ) -> tuple[jax.Array, jax.Array | None]:
    """Scatter codec-stored (values, scales) at ``idx`` — the one write
    primitive shared by every cache layout (dense rows, paged blocks,
    chunk lanes) and every attention variant; scales are None end-to-end
    in compute mode."""
    out_v = values.at[idx].set(new_vals)
    out_s = scales if new_scales is None else scales.at[idx].set(new_scales)
    return out_v, out_s


def fork_block(cache, src: jax.Array, dst: jax.Array):
    """Copy-on-write fork: copy pool block ``src`` into block ``dst``
    across every leaf of a paged cache pytree.

    Every paged cache leaf — GQA K/V values, MLA latents, and their int8
    scale arrays alike — is pool-block-major on axis 1
    (``[layers, pool_blocks, block_size, ...]``), so one tree.map forks
    values *and* scales together: a shared block's ``(position, kv-head)``
    scale rows are duplicated with its int8 rows and the fork stays
    exactly the codec's stored representation (bit-identical readback).
    ``src``/``dst`` may be traced scalars; the caller jits this with the
    cache donated so XLA rewrites the two rows in place.
    """
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), cache)


def gather_view(codec: CacheCodec, values: jax.Array,
                scales: jax.Array | None, block_tables: jax.Array,
                shape: tuple[int, ...], dtype) -> jax.Array:
    """Block-table gather of a pooled cache into sequence-major ``shape``,
    dequantized on the way out (the fused-on-TPU read half of the
    codec)."""
    g = values[block_tables].reshape(shape)
    if not codec.quantized:
        return g
    sg = scales[block_tables].reshape(shape[:-1])
    return codec.decode(g, sg, dtype)
