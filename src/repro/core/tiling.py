"""Tile-size planner — the paper's §3.9/§3.10 re-derived for VMEM (C2/C5).

The paper picks TS_MHA/TS_FFN at synthesis time by sweeping tile sizes
against (a) BRAM/DSP fit and (b) the post-route frequency cliff.  On TPU
the hard constraint is the VMEM working set of a ``pallas_call`` grid
step, and the "frequency cliff" becomes (i) HBM re-streaming cost when
blocks are small and (ii) MXU misalignment when blocks are not multiples
of 128.  ``plan_matmul`` scores candidate BlockSpec shapes under those
terms and returns the operating point; ``benchmarks/fig5_tilesize.py``
sweeps it the way the paper sweeps Fig. 5/9/13.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.analytical import TPUSpec, V5E


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(x: int, m: int) -> int:
    return _ceil_div(x, m) * m


@dataclass(frozen=True)
class TilePlan:
    """One matmul tiling decision: C[M,N] += A[M,K] @ B[K,N] in
    (bm, bk, bn) blocks with K-major accumulation (paper Fig. 4)."""

    bm: int
    bk: int
    bn: int
    M: int
    K: int
    N: int
    dtype_bytes: int = 2

    @property
    def grid(self) -> tuple[int, int, int]:
        return (_ceil_div(self.M, self.bm), _ceil_div(self.N, self.bn),
                _ceil_div(self.K, self.bk))

    @property
    def vmem_bytes(self) -> int:
        """Working set per grid step: A + B blocks double-buffered,
        f32 accumulator resident."""
        a = self.bm * self.bk * self.dtype_bytes
        b = self.bk * self.bn * self.dtype_bytes
        acc = self.bm * self.bn * 4
        return 2 * (a + b) + acc

    @property
    def hbm_traffic(self) -> int:
        """Bytes streamed HBM->VMEM for the whole matmul: A is re-read once
        per N-tile, B once per M-tile (the paper's tile 'replenish' count),
        C written once."""
        gm, gn, _ = self.grid
        a = self.M * self.K * self.dtype_bytes * gn
        b = self.K * self.N * self.dtype_bytes * gm
        c = self.M * self.N * self.dtype_bytes
        return a + b + c

    @property
    def mxu_occupancy(self) -> float:
        """Fraction of MXU lanes doing useful work (alignment penalty)."""
        eff = 1.0
        for blk, dim in ((self.bm, self.M), (self.bn, self.N),
                         (self.bk, self.K)):
            pad = _round_up(dim, blk) * 1.0
            eff *= dim / pad
        align = 1.0
        for blk in (self.bm, self.bn):
            align *= min(blk, 128) / 128.0
        return eff * align

    def latency(self, spec: TPUSpec = V5E) -> tuple[float, float]:
        """(t_compute, t_memory) seconds for one chip, roofline style."""
        flops = 2.0 * self.M * self.K * self.N
        t_c = flops / (spec.peak_flops * max(self.mxu_occupancy, 1e-9))
        t_m = self.hbm_traffic / spec.hbm_bw
        return t_c, t_m

    @property
    def t_total(self) -> float:
        return max(self.latency())


_CANDIDATE_BLOCKS = (128, 256, 512, 1024, 2048)


def plan_matmul(M: int, K: int, N: int, dtype_bytes: int = 2,
                spec: TPUSpec = V5E,
                vmem_budget: int | None = None) -> TilePlan:
    """Pick (bm, bk, bn) minimizing modeled latency under the VMEM budget.

    This is the §3.10 procedure: enumerate tile sizes, reject the ones
    that blow the on-chip budget (BRAM there, VMEM here), take the best
    modeled operating point.
    """
    budget = vmem_budget or spec.vmem_bytes
    best: TilePlan | None = None
    for bm in _CANDIDATE_BLOCKS:
        if bm // 2 >= _round_up(M, 128) and bm > 128:
            continue
        for bn in _CANDIDATE_BLOCKS:
            if bn // 2 >= _round_up(N, 128) and bn > 128:
                continue
            for bk in _CANDIDATE_BLOCKS:
                if bk // 2 >= _round_up(K, 128) and bk > 128:
                    continue
                plan = TilePlan(bm=min(bm, _round_up(M, 128)),
                                bk=min(bk, _round_up(K, 128)),
                                bn=min(bn, _round_up(N, 128)),
                                M=M, K=K, N=N, dtype_bytes=dtype_bytes)
                if plan.vmem_bytes > budget:
                    continue
                if best is None or plan.t_total < best.t_total:
                    best = plan
    if best is None:  # degenerate: even 128^3 blocks overflow -> smallest legal
        best = TilePlan(bm=128, bk=128, bn=128, M=M, K=K, N=N,
                        dtype_bytes=dtype_bytes)
    return best


def plan_for_shape(M: int, K: int, N: int, **kw) -> tuple[int, int, int]:
    p = plan_matmul(M, K, N, **kw)
    return p.bm, p.bk, p.bn


def sweep(M: int, K: int, N: int, dtype_bytes: int = 2,
          spec: TPUSpec = V5E) -> list[TilePlan]:
    """All candidate plans (fit or not) — the Fig. 5/9/13 sweep data."""
    out = []
    for bm in _CANDIDATE_BLOCKS:
        for bn in _CANDIDATE_BLOCKS:
            for bk in _CANDIDATE_BLOCKS:
                out.append(TilePlan(bm=bm, bk=bk, bn=bn, M=M, K=K, N=N,
                                    dtype_bytes=dtype_bytes))
    return out


@dataclasses.dataclass(frozen=True)
class ModuleTiling:
    """ADAPTOR-style per-module tile configuration (TS_MHA / TS_FFN)."""

    ts_mha: int = 512    # block width for attention-side matmuls
    ts_ffn: int = 1024   # block width for FFN-side matmuls

    def mha_plan(self, seq: int, d_model: int, heads: int) -> TilePlan:
        hd = d_model // max(heads, 1)
        return plan_matmul(seq, d_model, heads * hd)

    def ffn_plan(self, seq: int, d_model: int, d_ff: int) -> TilePlan:
        return plan_matmul(seq, d_model, d_ff)
