"""``strict_jit``: ``jax.jit`` whose donation failures are loud.

Every fused serving/training step donates its big buffers
(``donate_argnums``) so XLA aliases them in place instead of copying a
KV pool per token.  When a refactor silently breaks the aliasing — an
output stops matching a donated input's shape/dtype, or a donated value
gets captured as a constant — XLA demotes the failure to a *warning*
("Some donated buffers were not usable") and the step quietly doubles
its memory traffic.  Three PRs later a benchmark notices.

``strict_jit`` is a drop-in ``jax.jit`` wrapper that escalates that
warning to a ``RuntimeError`` when ``REPRO_STRICT=1`` is set in the
environment (the test suite sets it, see ``tests/conftest.py``), and on
platforms that actually implement buffer donation (CPU/TPU/GPU all do
in current JAX; the probe keeps exotic backends from false-failing).
Outside strict mode the wrapper is a transparent passthrough.

The wrapper forwards every attribute of the underlying jitted callable
(``lower``, ``_cache_size``, ...), so compile-count accounting and the
jaxpr audit (``repro.analysis``) see it as a plain jit.
"""
from __future__ import annotations

import functools
import os
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

# Substrings of the XLA/JAX donation-diagnostic warnings we escalate.
_DONATION_WARNING_MARKERS = (
    "donated buffers were not usable",
    "buffer donation",
    "donation is not implemented",
)


def strict_enabled() -> bool:
    """True when REPRO_STRICT=1 asks for donation failures to raise.

    Read per call (not cached) so a test can flip the env var.
    """
    return os.environ.get("REPRO_STRICT", "0") == "1"


@functools.lru_cache(maxsize=None)
def platform_donates() -> bool:
    """True when this backend aliases donated buffers at all."""
    f = jax.jit(lambda a: a + 1.0, donate_argnums=(0,))
    x = jnp.ones((8,), jnp.float32)
    p = x.unsafe_buffer_pointer()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        # x is deliberately dead after this call — the probe exists to
        # observe the donation itself
        return f(x).unsafe_buffer_pointer() == p  # ra: ignore[RA003]


def _is_donation_warning(message: Warning | str) -> bool:
    text = str(message).lower()
    return any(m in text for m in _DONATION_WARNING_MARKERS)


class DonationError(RuntimeError):
    """A buffer listed in ``donate_argnums`` was not actually donated."""


class _StrictJit:
    """Callable wrapper escalating donation warnings under REPRO_STRICT.

    The check only has teeth on the calls that *compile* (the warning
    fires at compile time); cached-executable calls re-enter the
    recording context but produce no warnings, so steady-state overhead
    is one ``warnings.catch_warnings`` block per dispatch in strict mode
    and zero outside it.
    """

    def __init__(self, jitted: Any, label: str):
        self._jitted = jitted
        self._label = label

    def __call__(self, *args, **kwargs):
        if not (strict_enabled() and platform_donates()):
            return self._jitted(*args, **kwargs)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            out = self._jitted(*args, **kwargs)
        bad = [w for w in caught if _is_donation_warning(w.message)]
        for w in caught:
            if w not in bad:
                warnings.warn_explicit(w.message, w.category,
                                       w.filename, w.lineno)
        if bad:
            raise DonationError(
                f"{self._label}: buffer donation was requested but not "
                "applied — "
                + "; ".join(str(w.message) for w in bad)
                + " (REPRO_STRICT=1 escalates this XLA warning: a fused "
                "step that stops aliasing its donated buffers silently "
                "copies them every dispatch; make the output shapes/"
                "dtypes match the donated inputs or drop the argnum "
                "from donate_argnums)")
        return out

    def __getattr__(self, name: str):
        return getattr(self._jitted, name)


def strict_jit(fun: Callable, *, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` with donation failures escalated under REPRO_STRICT=1.

    Drop-in at every ``donate_argnums`` site; the returned object
    forwards ``lower``/``_cache_size``/... to the underlying jit.
    """
    jitted = jax.jit(fun, donate_argnums=donate_argnums, **jit_kwargs)
    label = getattr(fun, "__qualname__", getattr(fun, "__name__", repr(fun)))
    return _StrictJit(jitted, label)
