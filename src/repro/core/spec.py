"""The one adaptive configuration surface: ``RuntimeSpec``.

ADAPTOR's runtime contract has exactly three kinds of knobs, and the
paper keeps them strictly separated (§3.12):

* **synthesis-time maxima** — frozen into the fabric; changing them costs
  a ~36 h re-synthesis (here: a recompile).  ``Maxima``.
* **topology registers**    — rewritten per network over AXI-Lite with
  zero re-synthesis.  ``TopologyRegisters``.
* **execution discipline**  — which compute units / dtypes the fabric
  was built with.

Before this module the repo scattered those knobs over four surfaces
(``ModelOptions``, ``ServingEngine`` kwargs, ``EngineOptions``,
``PagingConfig``) with duplicated fields.  ``RuntimeSpec`` is the single
frozen source of truth:

    spec = RuntimeSpec(arch=cfg, maxima=mx,
                       execution=ExecutionSpec(matmul_backend="pallas"),
                       memory=MemorySpec(cache_layout="paged"))
    spec.registers(sequence=64)     # lowering to the register file
    spec.fits_within(mx)            # the re-synthesis boundary check

Validation happens at *construction* time with actionable messages —
the divisibility and pool-geometry mistakes that used to surface as
cryptic shape errors deep inside jit are rejected here instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.kv_quant import KV_DTYPES, CacheCodec
from repro.core.paging import PagingConfig, blocks_for_tokens
from repro.core.quant import DEFAULT_QUANT_MIN_SIZE
from repro.core.registers import Maxima, TopologyRegisters, registers_for

_MATMUL_BACKENDS = ("xla", "pallas")
_PAGED_ATTN_IMPLS = ("gather", "pallas")
_CACHE_LAYOUTS = ("dense", "paged")
_QUANT_MODES = ("none", "int8")
_SCHEDULER_POLICIES = ("auto", "chunked", "bucketed")

# String spellings accepted for ExecutionSpec.param_dtype/compute_dtype —
# the CLI surface (launch/serve.py --param-dtype bf16) and config files
# speak strings; the spec normalizes them to jnp dtypes at construction.
_DTYPE_ALIASES = {
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
    "fp32": jnp.float32, "f32": jnp.float32, "float32": jnp.float32,
    "fp16": jnp.float16, "f16": jnp.float16, "float16": jnp.float16,
}

# Families whose decode state is a genuine KV/latent cache; recurrent
# (SSM / RG-LRU) and enc-dec state keeps the compute dtype.
KV_QUANTIZABLE_FAMILIES = ("dense", "vlm", "moe")


def _normalize_dtype(field_name: str, value):
    """Accept jnp dtypes or their string names; reject non-float dtypes
    with the valid spellings in the message."""
    if isinstance(value, str):
        key = value.lower()
        if key not in _DTYPE_ALIASES:
            raise ValueError(
                f"ExecutionSpec.{field_name}={value!r} is not a recognized "
                f"dtype name; use one of {sorted(set(_DTYPE_ALIASES))}")
        return _DTYPE_ALIASES[key]
    try:
        dt = jnp.dtype(value)
    except TypeError as e:
        raise ValueError(
            f"ExecutionSpec.{field_name}={value!r} is not a dtype") from e
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(
            f"ExecutionSpec.{field_name}={value!r} must be a floating "
            "dtype (params/activations; int8 quantization is configured "
            "through quant= and MemorySpec.kv_dtype, not the dtypes)")
    return value

# Families whose prefill can be replayed through the fused chunked step
# (attention caches are write-then-attend; recurrent / rolling-window /
# enc-dec state needs sequential prefill and stays on the bucketed path).
CHUNKABLE_FAMILIES = ("dense", "vlm", "moe")


@dataclass(frozen=True)
class ExecutionSpec:
    """How the fabric computes: kernel routing, dtypes, quantization.

    These are trace-time choices — changing any of them recompiles, so
    they live beside the maxima, not beside the registers.

    * ``param_dtype`` / ``compute_dtype`` accept jnp dtypes or their
      string names (``"bf16"``, ``"fp32"``, ...) and are normalized at
      construction, so CLI flags and config files can pass strings.
    * ``quant="int8"`` quantizes serving *weights* (paper C6): eligible
      kernels/tables become per-column/per-row int8 ``QTensor``s.  Works
      in single-topology AND multi-topology (fleet) mode — the fabric's
      model table packs int8 values + f32 scales per member.
    * ``quant_min_size`` — parameter leaves below this many elements
      stay float (biases, norms, tiny projections); threaded through
      ``quantize_params``/``quantize_abstract``/``quantize_axes`` and the
      fleet weight table.
    * The KV *cache* dtype is a memory-provisioning choice and lives on
      ``MemorySpec.kv_dtype``, not here.
    """

    matmul_backend: str = "xla"      # "xla" | "pallas" (ADAPTOR tiled kernels)
    paged_attn_impl: str = "gather"  # "gather" | "pallas" (fused flash-decode)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    quant: str = "none"              # "none" | "int8" (C6 serving weights)
    quant_min_size: int = DEFAULT_QUANT_MIN_SIZE  # leaf-size quant floor
    grouped_gqa: bool = False        # GQA-grouped decode contraction

    def __post_init__(self) -> None:
        if self.matmul_backend not in _MATMUL_BACKENDS:
            raise ValueError(
                f"ExecutionSpec.matmul_backend={self.matmul_backend!r} is not "
                f"one of {_MATMUL_BACKENDS}")
        if self.paged_attn_impl not in _PAGED_ATTN_IMPLS:
            raise ValueError(
                f"ExecutionSpec.paged_attn_impl={self.paged_attn_impl!r} is "
                f"not one of {_PAGED_ATTN_IMPLS}")
        if self.quant not in _QUANT_MODES:
            raise ValueError(
                f"ExecutionSpec.quant={self.quant!r} is not one of "
                f"{_QUANT_MODES}")
        if self.quant_min_size < 0:
            raise ValueError(
                f"ExecutionSpec.quant_min_size={self.quant_min_size} must "
                "be >= 0 (elements below which a param leaf stays float)")
        object.__setattr__(self, "param_dtype",
                           _normalize_dtype("param_dtype", self.param_dtype))
        object.__setattr__(self, "compute_dtype",
                           _normalize_dtype("compute_dtype",
                                            self.compute_dtype))


@dataclass(frozen=True)
class MemorySpec:
    """How decode-time memory is provisioned: cache layout, pool
    geometry, and the KV storage dtype.

    ``num_blocks=None`` sizes the paged pool at the dense worst case
    (``max_batch * max_len / block_size``), which makes ``paged`` a pure
    fragmentation win with identical capacity.

    ``kv_dtype`` selects the cache codec (``core.kv_quant``):

    * ``"compute"`` — bf16 cache values, the historical behaviour.
    * ``"int8"``    — quantize-on-write symmetric int8 with one f32
      scale per (position, kv-head) row, stored beside the dense rows or
      the paged pool and read back through a fused dequant in every
      attention variant.  ~``2 hd / (hd + 4)``x fewer cache bytes per
      token, so nearly 2x concurrent requests at equal HBM.  Supported
      for the KV/latent-cache families (``dense``/``vlm``/``moe``,
      GQA and MLA) in every mode: dense, paged, chunked, fleet.

    ``prefix_cache=True`` (paged + chunked only) keeps prefilled prompt
    blocks in a refcounted radix trie (``core.paging.PrefixCache``) so
    requests sharing a prompt prefix map the same physical blocks and
    prefill only their uncached suffix; the int8 codec composes (shared
    blocks share their scale rows).
    """

    cache_layout: str = "dense"      # "dense" | "paged"
    max_batch: int = 8
    max_len: int = 512
    block_size: int = 16
    num_blocks: int | None = None    # None -> dense worst case
    kv_dtype: str = "compute"        # "compute" | "int8" (cache codec)
    prefix_cache: bool = False       # share prompt KV blocks cross-request

    def __post_init__(self) -> None:
        if self.cache_layout not in _CACHE_LAYOUTS:
            raise ValueError(
                f"MemorySpec.cache_layout={self.cache_layout!r} is not one "
                f"of {_CACHE_LAYOUTS}")
        if self.prefix_cache and self.cache_layout != "paged":
            raise ValueError(
                "MemorySpec.prefix_cache=True requires cache_layout='paged' "
                "(prefix sharing maps physical pool blocks into multiple "
                "block tables; the dense layout has no blocks to share)")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"MemorySpec.kv_dtype={self.kv_dtype!r} is not one of "
                f"{KV_DTYPES}")
        if self.max_batch <= 0 or self.max_len <= 0:
            raise ValueError(
                f"MemorySpec needs positive max_batch/max_len, got "
                f"max_batch={self.max_batch} max_len={self.max_len}")
        if self.cache_layout == "paged":
            if self.block_size <= 0:
                raise ValueError(
                    f"MemorySpec.block_size must be positive, got "
                    f"{self.block_size}")
            if self.max_len % self.block_size:
                raise ValueError(
                    f"MemorySpec.block_size={self.block_size} must divide "
                    f"max_len={self.max_len} (the block tables address whole "
                    "blocks)")
            need = blocks_for_tokens(self.max_len, self.block_size)
            if self.num_blocks is not None and self.num_blocks < need:
                raise ValueError(
                    f"paged pool of {self.num_blocks} x {self.block_size}-"
                    f"token blocks holds {self.num_blocks * self.block_size} "
                    f"tokens < max_len={self.max_len}: one full-length "
                    f"request could never be admitted; use num_blocks >= "
                    f"{need} (or shrink max_len)")

    @property
    def resolved_num_blocks(self) -> int:
        if self.num_blocks is not None:
            return self.num_blocks
        return self.max_batch * (self.max_len // self.block_size)

    def paging(self) -> PagingConfig | None:
        """Lower to the pool geometry (None for the dense layout)."""
        if self.cache_layout != "paged":
            return None
        return PagingConfig(block_size=self.block_size,
                            num_blocks=self.resolved_num_blocks)

    def codec(self) -> CacheCodec:
        """Lower to the cache codec (quantize-on-write policy)."""
        return CacheCodec(self.kv_dtype)


@dataclass(frozen=True)
class SchedulerSpec:
    """How the serving engine feeds work to the fused device step.

    * ``policy="chunked"`` — prompts are split into fixed ``chunk_size``
      chunks and fed through the *same* jitted step that decodes active
      slots (a Sarathi-style mixed batch): prefill compilations drop to
      O(1) and long prompts never stall decoding slots.  Requires an
      attention-cache family (``CHUNKABLE_FAMILIES``) or fleet mode.
    * ``policy="bucketed"`` — the legacy path: a separate B=1 prefill
      dispatch per power-of-two prompt bucket.
    * ``policy="auto"`` (default) — chunked wherever it is supported,
      bucketed otherwise (and wherever ``chunk_size`` cannot satisfy the
      block-geometry constraint below).

    ``token_budget`` bounds the prompt tokens processed per fused step
    across all slots (decode lanes ride along for free); ``None``
    resolves to ``4 * chunk_size``.  In the paged layout ``chunk_size``
    must be a whole number of blocks so chunk KV writes stay
    block-aligned (the chunked-prefill kernel DMAs whole pool blocks).
    """

    policy: str = "auto"
    chunk_size: int = 16
    token_budget: int | None = None   # None -> 4 * chunk_size

    def __post_init__(self) -> None:
        if self.policy not in _SCHEDULER_POLICIES:
            raise ValueError(
                f"SchedulerSpec.policy={self.policy!r} is not one of "
                f"{_SCHEDULER_POLICIES}")
        if self.chunk_size <= 0:
            raise ValueError(
                f"SchedulerSpec.chunk_size must be positive, got "
                f"{self.chunk_size}")
        if self.token_budget is not None and \
                self.token_budget < self.chunk_size:
            raise ValueError(
                f"SchedulerSpec.token_budget={self.token_budget} < "
                f"chunk_size={self.chunk_size}: the scheduler could never "
                "grant a full chunk; raise token_budget or shrink "
                "chunk_size")

    @property
    def resolved_token_budget(self) -> int:
        if self.token_budget is not None:
            return self.token_budget
        return 4 * self.chunk_size

    def chunk_violations(self, memory: "MemorySpec") -> list[str]:
        """Every way this scheduler cannot chunk against ``memory``'s
        geometry (empty = the chunked policy is well-formed)."""
        out = []
        if self.chunk_size > memory.max_len:
            out.append(
                f"chunk_size={self.chunk_size} > max_len={memory.max_len} "
                "(a chunk never exceeds the cache)")
        if memory.cache_layout == "paged" and \
                self.chunk_size % memory.block_size:
            out.append(
                f"chunk_size={self.chunk_size} is not a multiple of "
                f"block_size={memory.block_size} (chunk KV writes must "
                "stay block-aligned for the paged pool)")
        return out


@dataclass(frozen=True)
class SpeculationSpec:
    """Speculative decoding: a small draft model proposes ``k`` tokens
    per fused step and the target verifies all ``k + 1`` positions in a
    single chunk-shaped attend (the PR 4 mixed-step machinery — lane
    ``j`` of the verify pass scores position ``index + j`` against the
    cache exactly like a prefill chunk lane).

    * ``draft_model`` — the proposer's architecture.  It decodes from
      its own private dense KV cache inside the same jitted step, so it
      must share the target's tokenizer space: ``vocab_size`` must match
      the serving arch (checked by ``RuntimeSpec.validate``).
    * ``k`` — draft tokens proposed per step.  The verify pass rides the
      chunk lanes, so ``k + 1 <= SchedulerSpec.chunk_size``.
    * ``greedy_accept=True`` — accept proposal ``j + 1`` iff it equals
      the target argmax at lane ``j`` (cumulative), which makes greedy
      streams provably token-identical to target-only decode.  ``False``
      uses standard rejection sampling on the softened distributions
      (rows with temperature <= 0 still take the greedy path).
    """

    draft_model: ArchConfig
    k: int = 3
    greedy_accept: bool = True

    def __post_init__(self) -> None:
        if not isinstance(self.draft_model, ArchConfig):
            raise ValueError(
                "SpeculationSpec.draft_model must be an ArchConfig, got "
                f"{type(self.draft_model).__name__}")
        self.draft_model.validate()
        if self.k < 1:
            raise ValueError(
                f"SpeculationSpec.k={self.k} must be >= 1 (propose at "
                "least one draft token per step)")
        if self.draft_model.family not in CHUNKABLE_FAMILIES:
            raise ValueError(
                f"SpeculationSpec.draft_model family "
                f"{self.draft_model.family!r} cannot draft: proposals ride "
                "the fused mixed step, which needs an attention KV cache "
                f"(families {CHUNKABLE_FAMILIES})")

    @property
    def horizon(self) -> int:
        """Positions a decoding slot may consume per fused step (the
        ``k`` proposals plus the bonus/correction token)."""
        return self.k + 1


@dataclass(frozen=True)
class MeshSpec:
    """How one runnable configuration maps onto devices.

    The ADAPTOR resource-allocation axis at datacenter scale: ``tp``
    devices cooperate on ONE fused step (QKV/FFN/vocab weights and the
    KV pool's kv-head axis sharded over the ``"model"`` mesh axis,
    block tables and ``SlotState`` replicated), and ``dp`` independent
    engine replicas sit behind one admission queue
    (``serving.cluster.EngineCluster``).  ``MeshSpec()`` is the exact
    historical single-device engine — no mesh is built at all.

    Per-leaf divisibility fallback applies throughout
    (``distributed.sharding``): an arch whose kv-head count does not
    divide ``tp`` still lowers, its cache simply replicates
    (``kv_shards`` reports what actually happened).
    """

    tp: int = 1   # tensor-parallel degree of each replica's fused step
    dp: int = 1   # data-parallel engine replicas behind one queue

    def __post_init__(self) -> None:
        if self.tp < 1 or self.dp < 1:
            raise ValueError(
                f"MeshSpec needs tp >= 1 and dp >= 1, got tp={self.tp} "
                f"dp={self.dp}")

    @property
    def n_devices(self) -> int:
        return self.tp * self.dp

    def kv_shards(self, arch: ArchConfig) -> int:
        """How many ways the cache's kv-head axis actually splits under
        ``tp`` — the divisor behind the ~1/N per-device KV bytes claim.
        MLA latents carry no kv-head axis and always replicate."""
        if arch.mla is not None:
            return 1
        kv = arch.num_kv_heads or arch.num_heads
        return self.tp if kv % self.tp == 0 else 1


@dataclass(frozen=True)
class MeshCapacity:
    """The mesh-aware capacity plan (``RuntimeSpec.capacity()``): what
    admission can actually hold, per device and across the replica set.
    Asserted against real admission behaviour by the mesh tests."""

    n_devices: int           # tp * dp
    max_concurrent: int      # dp * max_batch admission ceiling
    pool_tokens: int         # total KV tokens across all replicas
    kv_shards: int           # ways the kv-head axis splits (1 = replicated)
    cache_bytes_per_replica: int   # one replica's pool, summed over its tp
    per_device_cache_bytes: int    # ~cache_bytes_per_replica / kv_shards


@dataclass(frozen=True)
class RuntimeSpec:
    """One frozen description of a runnable configuration.

    ``arch`` is *what* runs, ``maxima`` is the fabric it must fit (None =
    a dedicated fabric exactly ``arch``-sized), ``execution`` is how it
    computes, ``memory`` is how its decode state is laid out,
    ``scheduler`` is how the serving engine feeds it, ``mesh`` is how
    many devices cooperate on (tp) and replicate (dp) the result, and
    ``speculation`` (optional) is the draft model that proposes tokens
    the target verifies in bulk.
    """

    arch: ArchConfig
    maxima: Maxima | None = None
    execution: ExecutionSpec = field(default_factory=ExecutionSpec)
    memory: MemorySpec = field(default_factory=MemorySpec)
    scheduler: SchedulerSpec = field(default_factory=SchedulerSpec)
    mesh: MeshSpec = field(default_factory=MeshSpec)
    speculation: SpeculationSpec | None = None

    def __post_init__(self) -> None:
        self.validate()

    # ------------------------------------------------------------------
    # Validation (construction-time, actionable messages)
    # ------------------------------------------------------------------
    def validate(self) -> "RuntimeSpec":
        cfg = self.arch
        cfg.validate()
        if self.memory.cache_layout == "paged" and \
                cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError(
                f"cache_layout='paged' is unsupported for family "
                f"{cfg.family!r} (SSM / rolling-window / enc-dec decode "
                "state is not paged); use cache_layout='dense'")
        if self.memory.kv_dtype == "int8" and \
                cfg.family not in KV_QUANTIZABLE_FAMILIES:
            raise ValueError(
                f"kv_dtype='int8' is unsupported for family {cfg.family!r}: "
                "only KV/latent attention caches are quantized "
                f"(families {KV_QUANTIZABLE_FAMILIES}); recurrent / "
                "rolling-window / enc-dec decode state keeps the compute "
                "dtype — use kv_dtype='compute'")
        if self.memory.prefix_cache and self.scheduler.policy == "bucketed":
            raise ValueError(
                "prefix_cache=True requires the chunked scheduler: a "
                "cache-hit request resumes prefill mid-prompt, which only "
                "the fused chunked step supports (the bucketed path always "
                "replays the whole prompt); use policy='auto' or 'chunked'")
        if self.scheduler.policy == "chunked":
            # "auto" silently falls back to bucketed on these; an explicit
            # chunked request fails loudly at construction instead
            bad = self.scheduler.chunk_violations(self.memory)
            if self.maxima is None and cfg.family not in CHUNKABLE_FAMILIES:
                bad.append(
                    f"family {cfg.family!r} has sequential prefill state "
                    "(chunked prefill needs an attention KV cache)")
            if bad:
                raise ValueError(
                    "scheduler policy 'chunked' is not satisfiable: "
                    + "; ".join(bad))
        if self.speculation is not None:
            sp = self.speculation
            if self.scheduler.policy == "bucketed":
                raise ValueError(
                    "speculation requires the chunked scheduler: the "
                    "draft-propose / target-verify pass is fused into the "
                    "chunk-shaped mixed step (the bucketed path has no "
                    "multi-position attend); use policy='auto' or 'chunked'")
            bad = self.scheduler.chunk_violations(self.memory)
            if self.maxima is None and cfg.family not in CHUNKABLE_FAMILIES:
                bad.append(
                    f"family {cfg.family!r} has sequential prefill state "
                    "(the verify pass needs the fused chunked step)")
            if bad:
                raise ValueError(
                    "speculation requires a satisfiable chunked scheduler: "
                    + "; ".join(bad))
            chunk = min(self.scheduler.chunk_size, self.memory.max_len)
            if sp.horizon > chunk:
                raise ValueError(
                    f"SpeculationSpec.k={sp.k} needs {sp.horizon} verify "
                    f"lanes but the fused step has only chunk_size={chunk} "
                    "(raise SchedulerSpec.chunk_size or lower k)")
            target_vocab = (self.maxima.vocab if self.maxima is not None
                            else cfg.vocab_size)
            if sp.draft_model.vocab_size != target_vocab:
                raise ValueError(
                    f"speculation draft vocab_size="
                    f"{sp.draft_model.vocab_size} != target vocab "
                    f"{target_vocab}: draft proposals are verified as "
                    "target token ids, so the models must share a "
                    "tokenizer space")
        if self.mesh.tp > 1:
            if self.maxima is not None:
                raise ValueError(
                    "mesh.tp > 1 is not supported in multi-topology (fleet) "
                    "mode: the fabric's per-slot weight-table gathers are "
                    "not sharded over the model axis; run fleet members as "
                    "data-parallel replicas instead (MeshSpec(dp=...))")
            if self.execution.matmul_backend != "xla" or \
                    self.execution.paged_attn_impl != "gather":
                raise ValueError(
                    "mesh.tp > 1 requires the XLA compute path "
                    "(matmul_backend='xla', paged_attn_impl='gather'): the "
                    "Pallas kernels are single-device programs GSPMD cannot "
                    "partition")
            if self.scheduler.policy == "bucketed":
                raise ValueError(
                    "mesh.tp > 1 requires the chunked scheduler: the "
                    "bucketed path stages B=1 prefill caches on the default "
                    "device, which cannot mix with a mesh-sharded pool; use "
                    "policy='auto' or 'chunked'")
            if cfg.family not in CHUNKABLE_FAMILIES:
                raise ValueError(
                    f"mesh.tp > 1 is unsupported for family {cfg.family!r}: "
                    "tensor parallelism requires the fused chunked step "
                    f"(families {CHUNKABLE_FAMILIES})")
        if self.maxima is not None:
            bad = self.violations(self.maxima)
            if bad:
                hint = ""
                if any(v.startswith("sequence=") for v in bad):
                    hint = (" (the spec's sequence bound is memory.max_len "
                            "— set memory=MemorySpec(max_len=...) to the "
                            "intended sequence length)")
                raise ValueError(
                    "spec does not fit its own maxima (re-synthesis "
                    "required): " + "; ".join(bad) + hint)
        return self

    # ------------------------------------------------------------------
    # Lowerings
    # ------------------------------------------------------------------
    def registers(self, sequence: int,
                  layers_dec: int | None = None) -> TopologyRegisters:
        """Lower to the §3.12 register file (identical to
        ``registers_for(self.arch, ...)`` — one lowering, two spellings)."""
        return registers_for(self.arch, sequence, layers_dec)

    def static_registers(self, sequence: int | None = None) -> dict[str, int]:
        """The register values as plain ints (for ceiling checks)."""
        cfg = self.arch
        return {
            "sequence": self.memory.max_len if sequence is None else sequence,
            "heads": cfg.num_heads,
            "layers_enc": (cfg.encdec.num_encoder_layers if cfg.encdec
                           else cfg.num_layers),
            "layers_dec": cfg.num_layers if cfg.encdec else 0,
            "embeddings": cfg.d_model,
            "hidden": cfg.d_ff,
            "out": cfg.vocab_size,
        }

    # ------------------------------------------------------------------
    # The re-synthesis boundary
    # ------------------------------------------------------------------
    def violations(self, maxima: Maxima,
                   mesh: MeshSpec | None = None) -> list[str]:
        """Every way this spec exceeds ``maxima`` (empty = fits).

        Mesh-aware: under tensor parallelism each device only has to
        hold its *shard*, so the TP-shardable dimensions (heads, hidden,
        out/vocab) are checked post-division — exactly the dims
        ``param_rules`` puts on the ``model`` axis, with the same
        divisibility fallback (an indivisible dim replicates and is
        checked whole).  ``mesh=None`` uses the spec's own mesh, so the
        historical single-device call sites are unchanged."""
        mesh = self.mesh if mesh is None else mesh
        regs = self.static_registers()
        for k in ("heads", "hidden", "out"):
            if mesh.tp > 1 and regs[k] % mesh.tp == 0:
                regs[k] //= mesh.tp
        lim = {"sequence": maxima.seq_max, "heads": maxima.heads_max,
               "layers_enc": maxima.layers_enc_max,
               "layers_dec": maxima.layers_dec_max,
               "embeddings": maxima.d_model_max, "hidden": maxima.d_ff_max,
               "out": maxima.out_max}
        out = [f"{k}={regs[k]} > {lim[k]}" for k in lim if regs[k] > lim[k]]
        if self.arch.resolved_head_dim > maxima.head_dim_max:
            out.append(f"head_dim={self.arch.resolved_head_dim} > "
                       f"{maxima.head_dim_max}")
        vocab = self.arch.vocab_size
        if mesh.tp > 1 and vocab % mesh.tp == 0:
            vocab //= mesh.tp
        if vocab > maxima.vocab:
            out.append(f"vocab={vocab} > {maxima.vocab}")
        return out

    def fits_within(self, maxima: Maxima,
                    mesh: MeshSpec | None = None) -> bool:
        """True iff every live dimension fits the synthesized fabric —
        exact equality is a fit (the maxima topology itself runs).
        Under a TP mesh the per-device *shard* is what must fit."""
        return not self.violations(maxima, mesh)

    # ------------------------------------------------------------------
    # Mesh-aware capacity planning
    # ------------------------------------------------------------------
    def capacity(self, mesh: MeshSpec | None = None) -> MeshCapacity:
        """What admission can hold on this spec's mesh: the budget
        scales ~N under DP (dp independent pools and slot sets) and the
        per-device KV bytes scale ~1/N under TP (the pool's kv-head
        axis splits ``kv_shards`` ways).  Asserted against real
        admission/sharding behaviour by the mesh tests."""
        from repro.core.analytical import kv_bytes_per_token
        mesh = self.mesh if mesh is None else mesh
        mem = self.memory
        per_replica_tokens = (
            mem.resolved_num_blocks * mem.block_size
            if mem.cache_layout == "paged" else mem.max_batch * mem.max_len)
        per_tok = kv_bytes_per_token(self.arch, kv_dtype=mem.kv_dtype)
        replica_bytes = int(per_replica_tokens * per_tok)
        shards = mesh.kv_shards(self.arch)
        return MeshCapacity(
            n_devices=mesh.n_devices,
            max_concurrent=mesh.dp * mem.max_batch,
            pool_tokens=mesh.dp * per_replica_tokens,
            kv_shards=shards,
            cache_bytes_per_replica=replica_bytes,
            per_device_cache_bytes=replica_bytes // shards)

    # ------------------------------------------------------------------
    # Analytical autotuning (the paper's resource allocator)
    # ------------------------------------------------------------------
    @staticmethod
    def tuned(arch: ArchConfig, device_profile=None, workload=None,
              **kw) -> "RuntimeSpec":
        """The predicted-best spec for ``arch`` on a device and workload,
        ranked by the ``core.analytical`` roofline model under a
        cache-memory budget.  Thin front door over
        ``repro.harness.tune.tune`` (which also exposes the full
        ranking); see that module for the knobs ``**kw`` accepts
        (``max_len``, ``execution``, ``allow_int8_kv``, ``maxima``)."""
        from repro.harness.tune import tune   # core must not import harness
        return tune(arch, device=device_profile, workload=workload, **kw).spec


# ---------------------------------------------------------------------------
# Fleet maxima
# ---------------------------------------------------------------------------
def maxima_for(*archs: ArchConfig, seq_max: int,
               layers_dec_max: int | None = None,
               mesh: MeshSpec | None = None) -> Maxima:
    """The smallest fabric covering every arch — elementwise maxima, the
    'synthesis planning' step of multi-topology serving.

    Mesh-aware: with ``mesh.tp > 1`` the planned fabric is the
    *per-device* one — each arch contributes its TP shard of the
    shardable dims (heads, d_ff, vocab/out; same divisibility fallback
    as ``distributed.sharding.param_rules``), so the returned maxima are
    ~1/tp smaller on those axes.  ``RuntimeSpec.fits_within(maxima,
    mesh)`` is the matching check."""
    if not archs:
        raise ValueError("maxima_for needs at least one ArchConfig")
    tp = mesh.tp if mesh is not None else 1

    def shard(dim: int) -> int:
        return dim // tp if tp > 1 and dim % tp == 0 else dim

    enc = [a.encdec.num_encoder_layers if a.encdec else a.num_layers
           for a in archs]
    dec = [a.num_layers if a.encdec else 0 for a in archs]
    return Maxima(
        seq_max=seq_max,
        heads_max=max(shard(a.num_heads) for a in archs),
        layers_enc_max=max(enc),
        layers_dec_max=(layers_dec_max if layers_dec_max is not None
                        else max(dec)),
        d_model_max=max(a.d_model for a in archs),
        d_ff_max=max(shard(a.d_ff) for a in archs),
        out_max=max(shard(a.vocab_size) for a in archs),
        head_dim_max=max(a.resolved_head_dim for a in archs),
        vocab=max(shard(a.vocab_size) for a in archs),
    )
