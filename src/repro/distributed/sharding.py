"""Logical-axis sharding rules -> physical mesh shardings (DP/TP/EP/SP/FSDP).

The model zoo annotates every parameter with *logical* axis names
("embed", "heads", "ffn", "experts", ...) via ``ParamBuilder('axes')``.
This module translates those names to physical mesh axes under a
``ShardingStrategy`` and resolves per-leaf divisibility: a logical axis
whose dimension does not divide its mesh extent falls back to replication
for that leaf (e.g. 3 attention heads on a 16-way model axis), so *every*
architecture lowers on *every* mesh — the portability requirement the
paper demonstrates across U55C/VC707/ZCU102 (Fig. 11).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingStrategy:
    """Which parallelism features are active and on which mesh axes."""

    dp_axes: tuple[str, ...] = ("data",)   # batch / gradient all-reduce
    tp_axis: str | None = "model"          # tensor parallel (heads/ffn/vocab)
    fsdp: bool = False                     # shard 'embed' of params over dp
    sp: bool = False                       # sequence-parallel activations
    ep_axis: str | None = None             # experts; defaults to tp_axis

    @property
    def expert_axis(self) -> str | None:
        return self.ep_axis or self.tp_axis


def strategy_for_mesh(mesh: Mesh, **kw) -> ShardingStrategy:
    """Default strategy: every non-'model' mesh axis is data-parallel."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    tp = "model" if "model" in mesh.axis_names else None
    return ShardingStrategy(dp_axes=dp, tp_axis=tp, **kw)


# Logical axis name -> rule key.  Anything unlisted is replicated.
def param_rules(s: ShardingStrategy) -> dict[str, Any]:
    tp = s.tp_axis
    r: dict[str, Any] = {
        "vocab": tp, "heads": tp, "kv_heads": tp, "ffn": tp,
        "experts": s.expert_axis, "dinner": tp, "lru": tp,
        "embed": s.dp_axes if s.fsdp else None,
        "q_lora": None, "kv_lora": None,
        "layers": None, "pos": None, "state": None,
    }
    return r


def activation_rules(s: ShardingStrategy) -> dict[str, Any]:
    return {
        "batch": s.dp_axes,
        # Megatron-SP: between blocks the residual stream is token-sharded
        # over the TP axis, so the TP all-reduce decomposes into
        # reduce-scatter (+ bf16 all-gather at the next matmul)
        "seq": s.tp_axis if s.sp else None,
        "heads": s.tp_axis, "kv_heads": s.tp_axis, "ffn": s.tp_axis,
        "experts": s.expert_axis, "embed": None, "vocab": s.tp_axis,
        "dinner": s.tp_axis, "lru": s.tp_axis,
    }


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def resolve_spec(logical: P, shape: tuple[int, ...], rules: dict,
                 mesh: Mesh) -> P:
    """Translate a logical PartitionSpec to mesh axes with divisibility
    fallback; drops mesh axes already used by an earlier dim."""
    out = []
    used: set[str] = set()
    for dim, name in enumerate(tuple(logical) + (None,) * (len(shape) - len(logical))):
        axes = rules.get(name) if name is not None else None
        if axes is None:
            out.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ax_tuple = tuple(a for a in ax_tuple
                         if a in mesh.shape and a not in used)
        if not ax_tuple or shape[dim] % _axis_size(mesh, ax_tuple) != 0:
            out.append(None)
            continue
        used.update(ax_tuple)
        out.append(ax_tuple[0] if len(ax_tuple) == 1 else ax_tuple)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_param_shardings(mesh: Mesh, axes_tree, abstract_tree,
                         strategy: ShardingStrategy):
    """Per-leaf NamedSharding for a parameter tree."""
    rules = param_rules(strategy)

    def one(spec, leaf):
        return NamedSharding(mesh, resolve_spec(spec, leaf.shape, rules, mesh))

    return jax.tree.map(one, axes_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, P))


def tp_mesh(devices) -> Mesh:
    """A ``(data=1, model=len(devices))`` mesh over an explicit device
    list — one serving replica's tensor-parallel group.  The replica
    set's data parallelism lives on the host (``serving.cluster``), so
    the data axis is always 1 here; a single device yields a 1x1 mesh
    that pins every array to that device (how DP replicas get disjoint
    placements without a second code path)."""
    devs = list(devices)
    if not devs:
        raise ValueError("tp_mesh needs at least one device")
    return Mesh(np.asarray(devs).reshape(1, len(devs)), ("data", "model"))


def kv_cache_shardings(mesh: Mesh, cache, strategy: ShardingStrategy):
    """Shardings for a decode-cache pytree, mirroring ``param_rules``'
    kv_heads rule with the same per-leaf divisibility fallback.

    KV caches are recognized structurally (a NamedTuple whose first two
    fields are ``k``/``v`` — ``models.attention.KVCache`` and the
    fabric's synthesis-time cache; importing them here would cycle):
    value leaves ``[L, rows, cols, n_kv, hd]`` shard the kv-head axis
    (-2) over the TP axis, int8 scale rows (``values.shape[:-1]``)
    shard their trailing kv-head axis, and everything else — MLA
    latents (no kv-head axis), recurrent state, hybrid per-layer
    entries that aren't attention — replicates.  A kv-head count that
    does not divide the TP extent replicates that leaf, so every arch
    lowers on every mesh."""
    tp = strategy.tp_axis
    tp_n = mesh.shape.get(tp, 1) if tp is not None else 1
    rep = NamedSharding(mesh, P())

    def axis_spec(leaf, axis: int) -> NamedSharding:
        if tp_n > 1 and leaf.ndim > axis % leaf.ndim \
                and leaf.shape[axis] % tp_n == 0:
            # no trailing Nones: GSPMD canonicalizes specs that way, and a
            # non-canonical device_put sharding would miss the jit cache on
            # the call after the first (sharding is part of the C++ key)
            spec = [None] * (axis % leaf.ndim) + [tp]
            return NamedSharding(mesh, P(*spec))
        return rep

    def walk(node):
        if node is None:
            return None
        fields = getattr(node, "_fields", None)
        if fields is not None and fields[:2] == ("k", "v"):
            return type(node)(
                axis_spec(node.k, -2), axis_spec(node.v, -2),
                *(None if s is None else axis_spec(s, -1)
                  for s in node[2:]))
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)) and fields is None:
            return type(node)(walk(v) for v in node)
        # any other node (MLACache, stacked recurrent state, bare array)
        return jax.tree.map(lambda _: rep, node)

    return walk(cache)


def batch_sharding(mesh: Mesh, strategy: ShardingStrategy,
                   ndim: int = 2) -> NamedSharding:
    """Tokens/targets [B, S, ...]: batch over the dp axes."""
    dp = tuple(a for a in strategy.dp_axes if a in mesh.shape)
    spec = [dp if dp else None] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# In-graph activation constraints (GSPMD hints), context-scoped
# ---------------------------------------------------------------------------
_ctx = threading.local()


@contextlib.contextmanager
def active(mesh: Mesh, strategy: ShardingStrategy) -> Iterator[None]:
    old = getattr(_ctx, "state", None)
    _ctx.state = (mesh, strategy, activation_rules(strategy))
    try:
        yield
    finally:
        _ctx.state = old


def constrain(x: jax.Array, logical_axes: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint against the active rules; no-op outside
    an ``active(...)`` scope, off-mesh, or when every axis resolves to
    replicated (an explicit empty constraint would *force* replication
    and fight propagation — measured as a 10x memory regression on the
    qwen2 prefill cell)."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, _, rules = state
    spec = resolve_spec(P(*logical_axes), x.shape, rules, mesh)
    if not tuple(spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def per_device_bytes(tree, mesh: Mesh, shardings) -> int:
    """Static estimate of per-device bytes for a sharded tree."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for axes in sh.spec:
            if axes is None:
                continue
            shards *= _axis_size(mesh, axes)
        total += n * leaf.dtype.itemsize // max(shards, 1)
    return total
