"""Microbatched pipeline parallelism via shard_map + ppermute (GPipe).

Stages live on a dedicated mesh axis; layer-stacked params are sharded
along it so each device holds one stage's weights.  The schedule runs
``n_micro + n_stages - 1`` ticks: every tick each stage applies its layer
to the activation it holds, then the activation ring-shifts one stage to
the right while the next microbatch enters stage 0.  The bubble fraction
is the classic (S-1)/(T+S-1); the launcher picks ``n_micro >= 4*stages``
to keep it under 6%.

``ppermute`` is differentiable, so ``jax.grad`` through
``pipeline_forward`` yields the reverse-schedule backward pass for free.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.collectives import axis_size
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _shift_right(x: jax.Array, axis_name: str) -> jax.Array:
    n = axis_size(axis_name)
    return lax.ppermute(x, axis_name, [(i, (i + 1) % n) for i in range(n)])


def pipeline_forward(stage_fn: Callable, stage_params, x: jax.Array, *,
                     axis_name: str = "stage") -> jax.Array:
    """Inside-shard_map pipelined apply.

    stage_params: this device's stage weights (leading stage dim removed
    by shard_map).  x: [n_micro, mb, ...] microbatched input, replicated.
    Returns [n_micro, mb, ...] outputs of the *last* stage, replicated.
    """
    n_stages = axis_size(axis_name)
    stage_idx = lax.axis_index(axis_name)
    n_micro = x.shape[0]
    total = n_micro + n_stages - 1
    # shard_map leaves a size-1 stage dim on every param leaf; drop it
    stage_params = jax.tree.map(lambda l: jnp.squeeze(l, 0), stage_params)

    state = jnp.zeros_like(x[0])                 # activation held by stage
    outputs = jnp.zeros_like(x)

    def tick(t, carry):
        state, outputs = carry
        # stage 0 ingests microbatch t (if any remain); others use held state
        mb = jnp.take(x, jnp.minimum(t, n_micro - 1), axis=0)
        inp = jnp.where(stage_idx == 0, mb, state)
        out = stage_fn(stage_params, inp)
        # last stage emits microbatch (t - (n_stages-1)) when it is valid
        emit_idx = t - (n_stages - 1)
        valid = (stage_idx == n_stages - 1) & (emit_idx >= 0)
        outputs = lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(valid, out, jnp.take(outputs, jnp.maximum(emit_idx, 0),
                                           axis=0)),
            jnp.maximum(emit_idx, 0), axis=0)
        state = _shift_right(out, axis_name)
        return state, outputs

    _, outputs = lax.fori_loop(0, total, tick, (state, outputs))
    # every device returns the outputs buffer; only the last stage's is
    # complete -> broadcast it around the ring so the result is replicated
    outputs = _shift_right(outputs, axis_name)   # last -> stage 0
    for _ in range(n_stages - 1):                # replicate to everyone
        nxt = _shift_right(outputs, axis_name)
        outputs = jnp.where(stage_idx == 0, outputs, nxt)
    return outputs


def make_pipelined_apply(stage_fn: Callable, mesh: Mesh, *,
                         axis_name: str = "stage",
                         param_spec: P | None = None) -> Callable:
    """Wrap ``stage_fn(stage_params, x) -> x`` into a mesh-level pipelined
    apply: f(stacked_params [S, ...], x [n_micro, mb, ...]) -> outputs."""
    pspec = param_spec if param_spec is not None else P(axis_name)

    fn = shard_map(
        functools.partial(pipeline_forward, stage_fn, axis_name=axis_name),
        mesh=mesh,
        in_specs=(pspec, P()),   # pspec is a pytree-prefix for the params
        out_specs=P(),
        check_rep=False,
    )

    def apply(stacked_params, x):
        return fn(stacked_params, x)

    return apply


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule (reported by the launcher)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
