"""Collective schedules for shard_map regions (pipeline, compressed DP).

Under ``jit`` GSPMD chooses collective algorithms itself; these helpers
exist for the explicitly-scheduled ``shard_map`` paths where we control
the wire format — ring reduce-scatter/all-gather built from
``ppermute`` so each step moves 1/n of the buffer (overlap-friendly:
chunk k is on the wire while chunk k-1 is being reduced), and the
compressed variants used by ``distributed.compression``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def axis_size(axis_name: str) -> int:
    """Size of a mapped axis inside a shard_map/pmap region.

    ``lax.axis_size`` only exists in newer JAX; ``psum(1, axis)`` is the
    portable spelling (constant-folded to a concrete int at trace time).
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def _ring_perm(n: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % n) for i in range(n)]


def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit ring reduce-scatter: [n*c] -> [c], device i ends with the
    full sum of chunk i.

    n-1 ppermute steps; at step s the partial resident on device i is for
    chunk (i + n-1-s) mod n, and the device folds in its own contribution
    for that chunk.  Each step moves 1/n of the buffer, so compute on the
    previous chunk can overlap the transfer of the next — the gradient
    analogue of the paper's load-weights-while-PEs-compute overlap.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)

    def chunk_at(k):
        return jnp.take(chunks, k % n, axis=0)

    acc = chunk_at(idx + n - 1)
    for s in range(1, n):
        acc = lax.ppermute(acc, axis_name, _ring_perm(n))
        acc = acc + chunk_at(idx + n - 1 - s)
    return acc


def psum_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter via the native collective (lowering-friendly)."""
    return lax.psum_scatter(x, axis_name, tiled=True)


def all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    return lax.all_gather(x, axis_name, tiled=True)


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """reduce-scatter + all-gather decomposition of all-reduce.

    Moves 2*(n-1)/n of the buffer per device instead of the naive
    n-fanout, and exposes the two phases separately so the caller can
    overlap them with compute (the paper's 'load weights while the PEs
    compute' discipline, §3.6.1, applied to gradients).
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    scattered = lax.psum_scatter(flat, axis_name, tiled=True)
    gathered = lax.all_gather(scattered, axis_name, tiled=True)
    return gathered[: x.size].reshape(x.shape)
