"""Error-feedback int8 gradient compression for the DP all-reduce.

Scheme (per gradient leaf, per step):
  1. reduce-scatter the f32 gradient (each device owns 1/n of the sum),
  2. add the local error-feedback residual, quantize the owned shard to
     int8 (per-shard symmetric scale), store the new residual,
  3. all-gather the int8 shards + scales and dequantize.

Wire bytes drop from ~8x size (f32 ring all-reduce) to ~4x + 1x, a ~38%
saving on the gradient-sync collective term, while error feedback keeps
the compression bias from accumulating (the residual re-enters the next
step, so the *time-averaged* update is unbiased — test-asserted).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.collectives import axis_size


class EFState(NamedTuple):
    """Per-leaf error-feedback residual, shaped like the local grad shard."""

    residual: jax.Array


def init_ef_state(local_shard_shape: tuple[int, ...]) -> EFState:
    return EFState(jnp.zeros(local_shard_shape, jnp.float32))


def _quantize_shard(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce(g: jax.Array, ef: EFState, axis_name: str,
                         ) -> tuple[jax.Array, EFState]:
    """Mean-all-reduce of ``g`` over ``axis_name`` with int8 wire format.

    Must run inside shard_map.  Returns (mean gradient, new EF state).
    The EF residual has the shape of the local reduce-scatter shard
    (padded flat size / axis size).
    """
    n = axis_size(axis_name)
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    owned = lax.psum_scatter(flat, axis_name, tiled=True) / n   # f32, 1/n
    owned = owned + ef.residual
    q, scale = _quantize_shard(owned)
    new_resid = owned - q.astype(jnp.float32) * scale
    q_all = lax.all_gather(q, axis_name, tiled=True)            # int8 wire
    s_all = lax.all_gather(scale.reshape(1), axis_name, tiled=True)  # [n]
    deq = q_all.astype(jnp.float32).reshape(n, -1) * s_all[:, None]
    out = deq.reshape(-1)[: g.size].reshape(g.shape)
    return out.astype(g.dtype), EFState(new_resid)


def compressed_allreduce_tree(grads, ef_tree, axis_name: str):
    """Apply ``compressed_allreduce`` leaf-wise over a gradient pytree."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_tree)
    outs, states = [], []
    for g, e in zip(flat_g, flat_e):
        o, s = compressed_allreduce(g, e, axis_name)
        outs.append(o)
        states.append(s)
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, states)


def init_ef_tree(grads_abstract, n_devices: int):
    """EF state tree matching ``compressed_allreduce``'s shard shapes."""
    def one(leaf):
        flat = 1
        for d in leaf.shape:
            flat *= d
        shard = (flat + (-flat) % n_devices) // n_devices
        return init_ef_state((shard,))

    return jax.tree.map(one, grads_abstract)


def wire_bytes(n_params: int, n_devices: int, compressed: bool) -> int:
    """Per-device wire traffic of one gradient sync (reporting helper)."""
    if not compressed:
        return int(2 * (n_devices - 1) / n_devices * n_params * 4)
    rs = (n_devices - 1) / n_devices * n_params * 4   # f32 reduce-scatter
    ag = (n_devices - 1) / n_devices * n_params * 1   # int8 all-gather
    return int(rs + ag)
