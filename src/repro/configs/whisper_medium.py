"""whisper-medium  [arXiv:2212.04356; unverified]

Enc-dec, 24 encoder + 24 decoder layers, d_model=1024 16H (kv=16) d_ff=4096
vocab=51865.  Conv audio frontend is a STUB per assignment: ``input_specs``
supplies precomputed frame embeddings (1500 x d_model after conv downsampling).
"""
from repro.configs.base import ArchConfig, EncDecConfig, FrontendConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,  # decoder layers
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4_096,
    vocab_size=51_865,
    head_dim=64,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    max_position_embeddings=4_096,
    source="arXiv:2212.04356",
    encdec=EncDecConfig(num_encoder_layers=24, encoder_seq_len=1_500),
    frontend=FrontendConfig(kind="audio", num_tokens=1_500, feature_dim=1_024),
)
