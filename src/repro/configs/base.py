"""Architecture + shape configuration dataclasses.

Every assigned architecture (plus the paper's own evaluation networks) is a
frozen ``ArchConfig``.  A config is pure data: the model zoo in
``repro.models`` interprets it, the launcher lowers it, and the ADAPTOR core
(``repro.core``) builds runtime-adaptive engines whose *maxima* are taken from
one of these configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    experts_per_token: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    # Layers [0, first_k_dense) use a dense FFN of size ``dense_d_ff`` instead
    # of the MoE block (DeepSeek-V3 uses 3 dense layers).
    first_k_dense: int = 0
    dense_d_ff: int = 0
    router_scale: float = 1.0
    # Expert capacity factor: C = ceil(S * k / E * capacity_factor); tokens
    # routed past capacity are dropped (residual keeps them intact).
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V3) configuration."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective-SSM configuration."""

    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid (RG-LRU + local attention) configuration."""

    # Block pattern, repeated over depth: 'r' = RG-LRU block, 'a' = local attn.
    pattern: tuple[str, ...] = ("r", "r", "a")
    lru_width: int = 0  # 0 -> d_model
    attention_window: int = 2048


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder/decoder (Whisper) configuration."""

    num_encoder_layers: int
    # Length of the (stub) frontend output fed to the encoder.  For Whisper
    # this is n_audio_frames / 2 after the conv stack.
    encoder_seq_len: int = 1500


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: ``input_specs`` provides precomputed embeddings."""

    kind: str  # 'vision' | 'audio'
    num_tokens: int  # patch / frame token count delivered by the stub
    feature_dim: int  # embedding dim delivered by the stub (== d_model)


@dataclass(frozen=True)
class ArchConfig:
    """A single architecture from the assigned pool (or the paper's own)."""

    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu | relu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    positional: str = "rope"  # rope | learned | none
    tie_embeddings: bool = False
    max_position_embeddings: int = 131_072
    source: str = ""  # provenance tag from the assignment table

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None
    frontend: FrontendConfig | None = None
    # Multi-token prediction depth (DeepSeek-V3 MTP); 0 disables.
    num_mtp_modules: int = 0

    def __post_init__(self) -> None:
        self.validate()

    def validate(self) -> "ArchConfig":
        """Construction-time shape sanity: the mistakes rejected here used
        to surface as cryptic reshape errors deep inside jit."""
        if self.num_heads > 0:
            # the paper's own encoder networks use deliberately odd dims
            # (custom-encoder: 200/3) and define head_dim = floor(d/h); the
            # decode families have no such convention, so reject there
            if self.head_dim == 0 and self.mla is None \
                    and self.family != "encoder" \
                    and self.d_model % self.num_heads:
                raise ValueError(
                    f"{self.name}: d_model={self.d_model} is not divisible "
                    f"by num_heads={self.num_heads} (and no explicit "
                    "head_dim is set); pick a head count that divides "
                    "d_model or set head_dim explicitly")
            if self.num_kv_heads <= 0 or self.num_heads % self.num_kv_heads:
                raise ValueError(
                    f"{self.name}: num_kv_heads={self.num_kv_heads} must be "
                    f"a positive divisor of num_heads={self.num_heads} "
                    "(each KV head serves an equal group of query heads)")
        return self

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_full_attention_decode(self) -> bool:
        return self.family != "encoder"

    @property
    def is_subquadratic(self) -> bool:
        """True if serve-time cost is sub-quadratic in sequence length."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytical parameter count (embedding + per-layer), used by the
        roofline model's 6·N·D term and by DESIGN/EXPERIMENTS reporting."""
        from repro.core.analytical import arch_param_count

        return arch_param_count(self)

    def active_param_count(self) -> int:
        from repro.core.analytical import arch_param_count

        return arch_param_count(self, active_only=True)


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes.  ``decode_*`` / ``long_*`` lower ``serve_step``
# (one new token against a KV cache of seq_len), not ``train_step``.
TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def cell_is_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and if not, why (for the report).

    Per assignment: ``long_500k`` needs sub-quadratic attention -> skipped for
    pure full-attention archs; encoder-only archs have no decode step.
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "skip: full quadratic attention at 512k context"
    if shape.is_decode and not cfg.supports_full_attention_decode:
        return False, "skip: encoder-only arch has no decode step"
    return True, ""


def reduced(cfg: ArchConfig, **overrides: Any) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps every structural feature (GQA ratio, MoE routing, MLA, SSM, hybrid
    pattern, enc-dec) while shrinking width/depth/vocab.
    """
    small: dict[str, Any] = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        d_ff=128,
        vocab_size=128,
        max_position_embeddings=512,
    )
    # Preserve the GQA grouping ratio with >=1 kv head.
    ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = 4
    small["num_heads"] = heads
    small["num_kv_heads"] = max(1, heads // ratio)
    small["head_dim"] = 16
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            num_experts=4,
            experts_per_token=min(2, cfg.moe.experts_per_token),
            expert_d_ff=32,
            num_shared_experts=cfg.moe.num_shared_experts,
            shared_expert_d_ff=32 if cfg.moe.num_shared_experts else 0,
            first_k_dense=min(1, cfg.moe.first_k_dense),
            dense_d_ff=128 if cfg.moe.first_k_dense else 0,
        )
    if cfg.mla is not None:
        small["mla"] = MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(state_dim=8, conv_kernel=4, expand=2, dt_rank=8)
    if cfg.hybrid is not None:
        small["hybrid"] = HybridConfig(
            pattern=cfg.hybrid.pattern, lru_width=0, attention_window=32
        )
        small["num_layers"] = len(cfg.hybrid.pattern)  # one full pattern period
    if cfg.encdec is not None:
        small["encdec"] = EncDecConfig(num_encoder_layers=2, encoder_seq_len=16)
    if cfg.frontend is not None:
        small["frontend"] = FrontendConfig(
            kind=cfg.frontend.kind, num_tokens=8, feature_dim=64
        )
    if cfg.num_mtp_modules:
        small["num_mtp_modules"] = 1
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


DEFAULT_PARAM_DTYPE = jnp.float32
DEFAULT_COMPUTE_DTYPE = jnp.bfloat16
