"""qwen1.5-0.5b  [hf:Qwen/Qwen1.5-0.5B; hf]

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936 — QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2_816,
    vocab_size=151_936,
    head_dim=64,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
