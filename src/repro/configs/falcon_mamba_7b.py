"""falcon-mamba-7b  [arXiv:2410.05355; unverified]

64L d_model=4096 (attention-free) vocab=65024, mamba-1 selective SSM,
ssm_state=16, conv 4, expand 2 (d_inner 8192), dt_rank = ceil(4096/16) = 256.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4_096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    activation="swiglu",  # mamba gate uses SiLU
    norm="rmsnorm",
    positional="none",
    source="arXiv:2410.05355",
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2, dt_rank=256),
)
