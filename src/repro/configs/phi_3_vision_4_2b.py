"""phi-3-vision-4.2b  [hf:microsoft/Phi-3-vision-128k-instruct; hf]

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064 — phi3-mini backbone +
CLIP vision frontend.  Per assignment the frontend is a STUB: ``input_specs``
supplies precomputed patch embeddings of shape (num_tokens, d_model).
"""
from repro.configs.base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3_072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8_192,
    vocab_size=32_064,
    head_dim=96,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    max_position_embeddings=131_072,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    frontend=FrontendConfig(kind="vision", num_tokens=576, feature_dim=3_072),
)
