"""phi3-mini-3.8b  [arXiv:2404.14219; unverified]

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064 — RoPE SwiGLU GQA.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3_072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8_192,
    vocab_size=32_064,
    head_dim=96,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    source="arXiv:2404.14219",
)
