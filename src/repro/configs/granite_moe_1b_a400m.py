"""granite-moe-1b-a400m  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 32 experts top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,  # per-expert width (routed FFN); no dense FFN layers
    vocab_size=49_155,
    head_dim=64,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    moe=MoEConfig(
        num_experts=32,
        experts_per_token=8,
        expert_d_ff=512,
    ),
)
