"""Config registry: ``get_config(name)`` / ``--arch <id>``.

The 10 assigned architectures plus the paper's own three evaluation networks.
"""
from __future__ import annotations

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ArchConfig,
    EncDecConfig,
    FrontendConfig,
    HybridConfig,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    ShapeSpec,
    cell_is_applicable,
    reduced,
)

from repro.configs import (  # noqa: E402  (registry imports)
    adaptor_bert,
    codeqwen1_5_7b,
    custom_encoder,
    deepseek_v3_671b,
    falcon_mamba_7b,
    granite_moe_1b_a400m,
    phi3_mini_3_8b,
    phi_3_vision_4_2b,
    qwen1_5_0_5b,
    qwen2_72b,
    recurrentgemma_2b,
    shallow_transformer,
    whisper_medium,
)

# The 10 assigned pool architectures, in assignment order.
ASSIGNED: tuple[ArchConfig, ...] = (
    granite_moe_1b_a400m.CONFIG,
    deepseek_v3_671b.CONFIG,
    phi_3_vision_4_2b.CONFIG,
    qwen1_5_0_5b.CONFIG,
    qwen2_72b.CONFIG,
    phi3_mini_3_8b.CONFIG,
    codeqwen1_5_7b.CONFIG,
    falcon_mamba_7b.CONFIG,
    recurrentgemma_2b.CONFIG,
    whisper_medium.CONFIG,
)

# The paper's own evaluation networks (ADAPTOR §6, Table 1, Fig. 11).
PAPER_NETWORKS: tuple[ArchConfig, ...] = (
    adaptor_bert.CONFIG,
    shallow_transformer.CONFIG,
    custom_encoder.CONFIG,
)

ALL_CONFIGS: tuple[ArchConfig, ...] = ASSIGNED + PAPER_NETWORKS
REGISTRY: dict[str, ArchConfig] = {c.name: c for c in ALL_CONFIGS}


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(REGISTRY))
        raise KeyError(f"unknown arch {name!r}; known: {known}") from None


def get_shape(name: str) -> ShapeSpec:
    try:
        return SHAPES_BY_NAME[name]
    except KeyError:
        known = ", ".join(sorted(SHAPES_BY_NAME))
        raise KeyError(f"unknown shape {name!r}; known: {known}") from None


__all__ = [
    "ALL_CONFIGS",
    "ALL_SHAPES",
    "ASSIGNED",
    "ArchConfig",
    "DECODE_32K",
    "EncDecConfig",
    "FrontendConfig",
    "HybridConfig",
    "LONG_500K",
    "MLAConfig",
    "MoEConfig",
    "PAPER_NETWORKS",
    "PREFILL_32K",
    "REGISTRY",
    "SHAPES_BY_NAME",
    "SSMConfig",
    "ShapeSpec",
    "TRAIN_4K",
    "cell_is_applicable",
    "get_config",
    "get_shape",
    "reduced",
]
