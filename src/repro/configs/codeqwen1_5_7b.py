"""codeqwen1.5-7b  [hf:Qwen/CodeQwen1.5-7B; hf]

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416 — qwen1.5 arch.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13_440,
    vocab_size=92_416,
    head_dim=128,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B",
)
