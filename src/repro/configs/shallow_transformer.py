"""shallow-transformer — paper Table 1 Network #1.

The 'shallow transformer' baseline used by Fang et al. [44] / Qi et al.
[19, 33]: 2 encoder layers, d_model=512, 8 heads, d_ff=2048, SL 64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="shallow-transformer",
    family="encoder",
    num_layers=2,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2_048,
    vocab_size=30_522,
    head_dim=64,
    activation="relu",
    norm="layernorm",
    positional="learned",
    max_position_embeddings=512,
    source="paper Table 1 Network #1",
)
