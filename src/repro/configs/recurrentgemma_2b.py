"""recurrentgemma-2b  [arXiv:2402.19427; hf]

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 (GeGLU) vocab=256000,
RG-LRU + local attention in a 1:2 ratio — pattern (r, r, a) repeated,
attention window 2048.
"""
from repro.configs.base import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2_560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7_680,
    vocab_size=256_000,
    head_dim=256,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.19427",
    hybrid=HybridConfig(pattern=("r", "r", "a"), lru_width=2_560, attention_window=2_048),
)
