"""deepseek-v3-671b  [arXiv:2412.19437; hf]

61L d_model=7168 128H d_ff=2048(routed) vocab=129280, MoE 256e top-8,
MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128),
1 shared + 256 routed experts, first 3 layers dense (d_ff 18432), MTP depth 1.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7_168,
    num_heads=128,
    num_kv_heads=128,  # MLA: all heads read the shared compressed latent
    d_ff=2_048,  # routed expert width
    vocab_size=129_280,
    head_dim=128,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    source="arXiv:2412.19437",
    moe=MoEConfig(
        num_experts=256,
        experts_per_token=8,
        expert_d_ff=2_048,
        num_shared_experts=1,
        shared_expert_d_ff=2_048,
        first_k_dense=3,
        dense_d_ff=18_432,
        router_scale=2.5,
    ),
    mla=MLAConfig(
        q_lora_rank=1_536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    num_mtp_modules=1,
)
