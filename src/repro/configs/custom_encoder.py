"""custom-encoder — paper Fig. 11 / Table 1 Network #2.

Custom TNN encoder used for the portability experiment: embedding dim 200,
3 attention heads, 2 encoder layers, sequence length 64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="custom-encoder",
    family="encoder",
    num_layers=2,
    d_model=200,
    num_heads=3,
    num_kv_heads=3,
    d_ff=800,
    vocab_size=8_000,
    head_dim=0,  # 200 // 3 = 66 (the paper's odd dims exercise non-128-aligned tiling)
    activation="relu",
    norm="layernorm",
    positional="learned",
    max_position_embeddings=512,
    source="paper Fig. 11 / Table 1 Network #2",
)
