"""adaptor-bert — the paper's primary evaluation network (§6).

BERT-base-like variant used to evaluate ADAPTOR: d_model=768, 12 heads,
12 encoder layers, default sequence length 64, GELU + LayerNorm.
Encoder-only: no decode shapes.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="adaptor-bert",
    family="encoder",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3_072,
    vocab_size=30_522,
    head_dim=64,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    max_position_embeddings=512,
    source="paper §6 (BERT variant [10])",
)
