"""Deterministic, sharded, checkpointable data pipeline.

Properties the trainer relies on (all test-asserted):

* determinism   — batch content is a pure function of (seed, step, host),
  via PRNG fold-in; no global state.
* sharding      — hosts draw disjoint slices of the global batch; the
  union over hosts is independent of the host count layout.
* resumability  — ``state()`` is a tiny dict; ``SyntheticLMStream.restore``
  (or the constructor) reproduces the stream exactly from it, so a
  restarted job sees the very next batch it would have seen.
* packing       — documents of random length are packed into fixed
  seq_len rows with EOS separators (the LM-pretraining layout).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMStream:
    """Synthetic packed-document LM stream (Zipf-ish token distribution)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    eos_id: int = 0
    step: int = 0
    mean_doc_len: int = 64

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.host_batch = self.global_batch // self.n_hosts

    # -- determinism --------------------------------------------------
    def _row_key(self, step: int, row: int) -> jax.Array:
        k = jax.random.PRNGKey(self.seed)
        k = jax.random.fold_in(k, step)
        global_row = self.host_id * self.host_batch + row
        return jax.random.fold_in(k, global_row)

    def _pack_row(self, key: jax.Array) -> np.ndarray:
        """Pack documents (geometric lengths) into one seq_len+1 row."""
        out = np.empty(self.seq_len + 1, np.int32)
        pos = 0
        i = 0
        while pos <= self.seq_len:
            dk = jax.random.fold_in(key, i)
            ln = int(jax.random.geometric(
                dk, p=1.0 / self.mean_doc_len))
            ln = max(1, min(ln, self.seq_len + 1 - pos))
            # Zipf-flavoured tokens: square a uniform to skew low ids
            u = jax.random.uniform(jax.random.fold_in(dk, 1), (ln,))
            toks = 1 + (np.asarray(u) ** 2 * (self.vocab_size - 2)) \
                .astype(np.int32)
            out[pos: pos + ln] = toks
            pos += ln
            if pos <= self.seq_len:
                out[pos] = self.eos_id
                pos += 1
            i += 1
        return out

    def next(self) -> dict[str, jnp.ndarray]:
        rows = [self._pack_row(self._row_key(self.step, r))
                for r in range(self.host_batch)]
        arr = np.stack(rows)
        self.step += 1
        return {"tokens": jnp.asarray(arr[:, :-1]),
                "targets": jnp.asarray(arr[:, 1:])}

    # -- checkpointing ------------------------------------------------
    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step,
                "host_id": self.host_id, "n_hosts": self.n_hosts}

    @classmethod
    def restore(cls, state: dict, **fixed) -> "SyntheticLMStream":
        return cls(**{**fixed, "seed": state["seed"], "step": state["step"],
                      "host_id": state["host_id"],
                      "n_hosts": state["n_hosts"]})


@dataclasses.dataclass
class MemorizationStream:
    """Tiny fixed corpus cycled forever — examples/quickstart convergence."""

    vocab_size: int
    seq_len: int
    batch: int
    seed: int = 0
    n_rows: int = 16
    step: int = 0

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        self.corpus = jax.random.randint(
            key, (self.n_rows, self.seq_len + 1), 1, self.vocab_size)

    def next(self) -> dict[str, jnp.ndarray]:
        idx = (self.step * self.batch + jnp.arange(self.batch)) % self.n_rows
        rows = self.corpus[idx]
        self.step += 1
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}
