"""``repro.analysis`` — the jit-discipline analyzer.

Every perf claim this repo makes rests on invariants nothing in the
type system enforces: the fused serving step compiles exactly once, the
KV cache and ``SlotState`` are actually donated, no host sync rides
inside the decode loop, and the Pallas grids divide the ``MemorySpec``
block geometry.  This package checks them at review time instead of in
a benchmark three PRs later:

* ``lint``            — AST walk over ``src/repro`` flagging host-sync
  calls / traced-Python-``if`` / use-after-donate / mutable dataclass
  defaults / per-slot device_gets (rules RA001..RA005, suppressible
  with ``# ra: ignore[RAxxx]``).
* ``jaxpr_audit``     — traces each supported fused step with
  ``jax.make_jaxpr`` / ``.lower()`` and asserts no callback primitives,
  no f64 promotion, donation actually applied, and per-step
  primitive-count budgets.
* ``census``          — compiles every point of the supported
  (family x layout x kv_dtype x backend x scheduler) matrix once and
  writes ``ANALYSIS.json`` (compile counts + jaxpr fingerprints) that
  CI diffs against the committed baseline.
* ``pallas_contracts``— statically checks the three serving Pallas
  kernels' grid/BlockSpec tile math against the ``MemorySpec`` geometry
  and the bounds of the scalar-prefetched block-table index maps.

CLI: ``python -m repro.analysis --check`` runs all four passes and
exits non-zero on any finding.  ``--update-baseline`` regenerates
``ANALYSIS.json`` after an intentional lowering change.
"""
from __future__ import annotations

from repro.analysis.lint import Finding, lint_paths, lint_source  # noqa: F401
from repro.analysis.pallas_contracts import (  # noqa: F401
    KernelGeometry, check_contracts, check_geometry)
