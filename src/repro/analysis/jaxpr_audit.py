"""Jaxpr audit: what the fused steps are allowed to lower to.

The lint pass reads *source*; this pass reads the *trace*.  For audited
matrix points (see :mod:`repro.analysis.census`) the fused decode step
is traced with ``jax.make_jaxpr`` on the engine's real buffers — no
execution — and the closed jaxpr is walked recursively:

* **no callback primitives** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` and friends each punch a host round trip into the
  device step, exactly the class of bug rules RA001/RA005 catch in
  source form.  A callback that reaches the jaxpr got past the linter.
* **no f64 promotion** — serving math is bf16/f32 (and int8 codecs); a
  float64 aval anywhere means a Python float leaked into an op without
  ``jnp.asarray(..., dtype)`` and doubled that tensor's bandwidth.
* **primitive-count budget** — the flattened equation count of each
  audited step must stay under a per-point budget (generous ~2x
  headroom over the measured count).  The budget catches quadratic
  trace blowups (an unrolled Python loop over layers or slots) long
  before they show up as compile-time regressions.
* **donation applied** — the step is ``.lower().compile()``d under a
  warnings trap; any "donated buffers were not usable" warning fails
  the audit (the KV cache and SlotState must alias, not copy — the
  same check ``core.jitutil.strict_jit`` enforces at runtime under
  ``REPRO_STRICT=1``).
"""
from __future__ import annotations

import warnings
from typing import Any, Iterable

from repro.analysis.census import MatrixPoint, _point_by_name, build_engine
from repro.core.jitutil import _is_donation_warning, platform_donates

# Primitives that re-enter Python from inside a traced computation.
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "python_callback",
    "callback", "host_callback_call", "outside_call",
})

# Flattened equation budgets per audited point (measured count ~half).
DEFAULT_BUDGETS: dict[str, int] = {
    "gqa-dense-xla-bucketed": 700,     # measured 332
    "gqa-paged-xla-chunked": 800,      # measured 383
    "gqa-paged-int8kv-chunked": 950,   # measured 453
    "mla-dense-xla-chunked": 1400,     # measured 688
}

# The cheap subset the audit drives by default (each exercises a
# different lowering family: dense, paged, int8 codec, MLA).
AUDITED_POINTS = tuple(DEFAULT_BUDGETS)


def _sub_jaxprs(params: dict[str, Any]) -> Iterable[Any]:
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for item in vals:
            if hasattr(item, "jaxpr"):        # ClosedJaxpr
                yield item.jaxpr
            elif hasattr(item, "eqns"):       # raw Jaxpr
                yield item


def walk_eqns(jaxpr) -> Iterable[Any]:
    """Every equation in the jaxpr and all nested sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)    # unwrap ClosedJaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from walk_eqns(sub)


def count_primitives(jaxpr) -> int:
    return sum(1 for _ in walk_eqns(jaxpr))


def audit_jaxpr(jaxpr, *, budget: int | None = None,
                label: str = "step") -> list[str]:
    """Callback / f64 / budget violations of one closed jaxpr."""
    violations: list[str] = []
    callbacks: set[str] = set()
    f64_ops: set[str] = set()
    n = 0
    for eqn in walk_eqns(jaxpr):
        n += 1
        name = eqn.primitive.name
        if name in CALLBACK_PRIMITIVES or "callback" in name:
            callbacks.add(name)
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and str(getattr(aval, "dtype", "")) \
                    == "float64":
                f64_ops.add(name)
    if callbacks:
        violations.append(
            f"{label}: callback primitives in the traced step: "
            f"{sorted(callbacks)} — host round trips inside the fused "
            "program")
    if f64_ops:
        violations.append(
            f"{label}: float64 avals produced by {sorted(f64_ops)} — a "
            "Python float promoted the compute dtype")
    if budget is not None and n > budget:
        violations.append(
            f"{label}: {n} primitives exceeds the {budget} budget — "
            "trace blowup (unrolled loop?)")
    return violations


def audit_donation(eng) -> list[str]:
    """Compile the fused decode step and trap donation warnings."""
    if not platform_donates():
        return []
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng._decode.lower(eng.params, eng.cache, eng.state,
                          eng.block_tables).compile()
    bad = [str(w.message) for w in caught
           if _is_donation_warning(w.message)]
    return [f"decode: donation not applied: {m}" for m in bad]


def audit_point(name: str, *, budget: int | None = None) -> list[str]:
    """Full audit of one census matrix point (trace + compile)."""
    import jax

    budget = budget if budget is not None else DEFAULT_BUDGETS.get(name)
    eng = build_engine(_point_by_name(name))
    jaxpr = jax.make_jaxpr(eng._decode_impl)(
        eng.params, eng.cache, eng.state, eng.block_tables)
    violations = audit_jaxpr(jaxpr, budget=budget, label=f"{name}/decode")
    violations += [f"{name}/{v}" for v in audit_donation(eng)]
    return violations


def run_audit(names: Iterable[str] | None = None,
              progress=None) -> dict[str, list[str]]:
    """Audit the default (or given) points; {name: violations} for
    the points that failed."""
    bad: dict[str, list[str]] = {}
    for name in (names or AUDITED_POINTS):
        if progress:
            progress(name)
        v = audit_point(name)
        if v:
            bad[name] = v
    return bad
