"""AST lint for jit discipline (rules RA001..RA005).

The walker knows which functions are *jit-region* code — traced by XLA,
where a host sync or a Python branch on a traced value breaks the
compile-once contract — and which are host-side control.  A function is
a jit region when any of:

* it is decorated with ``jax.jit`` / ``functools.partial(jax.jit, ...)``
  / ``strict_jit`` / ``partial(shard_map, ...)``,
* it is passed to ``jax.jit(...)`` / ``strict_jit(...)`` /
  ``pl.pallas_call(...)`` / ``shard_map(...)`` anywhere in its module
  (the serving engine's ``self._decode = strict_jit(self._decode_impl,
  ...)`` pattern; a ``shard_map`` body is traced exactly like a jit
  body, so explicitly-scheduled collective code gets the same rules),
* its ``def`` line (or the line above it / above its first decorator)
  carries a ``# jit-region`` marker — the registry for functions that
  are only ever *called from inside* another module's jitted step
  (``Model.decode_step``, the fabric steps, ``sample_per_slot``).

Nested ``def``s inside a jit region are jit regions too.

Rules
-----
RA001  host-sync call inside a jit region: ``jax.device_get``,
       ``.item()`` / ``.tolist()`` / ``.block_until_ready()``,
       ``np.asarray`` / ``np.array`` on anything, or ``float()`` /
       ``int()`` / ``bool()`` applied to a traced value.
RA002  Python ``if`` / ``while`` on a traced value inside a jit region
       (``is [not] None`` / ``in`` structure tests are static and
       exempt — pytree structure is a trace constant).
RA003  use-after-donate: a call to a jitted function with
       ``donate_argnums`` whose donated argument expressions are not
       rebound from the call's result (the donated buffer is dead; any
       later read is undefined behaviour).
RA004  mutable or array-valued default in a dataclass field (shared
       across instances and baked at import; use ``default_factory``).
RA005  two or more per-slot ``jax.device_get`` calls (scalar-subscripted
       operands) in one host function — each is a blocking round trip;
       batch them into one bulk transfer.

Suppression: append ``# ra: ignore[RA001]`` (or a comma list, or bare
``# ra: ignore`` for all rules) to the flagged line.

Taint model: function parameters (minus ``self``/``cls``) are traced;
taint flows through expressions and simple assignments, and is *cut* by
static accessors (``.shape`` / ``.dtype`` / ``.ndim`` / ``.size``,
``len()`` / ``isinstance()`` / ``hasattr()``) and by ``is`` / ``in``
comparisons (structure, not values).  It is a one-pass heuristic, not a
dataflow engine — precise enough that the tree lints clean without
blessing real violations.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

_SUPPRESS_RE = re.compile(r"#\s*ra:\s*ignore(?:\[([A-Za-z0-9_,\s]+)\])?")
_MARKER_RE = re.compile(r"#\s*jit-region\b")

# Attribute calls that force a device->host sync.
_SYNC_ATTRS = frozenset({
    "device_get", "item", "tolist", "block_until_ready",
    "copy_to_host_async",
})
# Static accessors that cut taint (shape metadata is a trace constant).
_STATIC_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "sharding",
                           "aval", "weak_type"})
_STATIC_CALLS = frozenset({"len", "isinstance", "hasattr", "getattr",
                           "type", "id", "repr", "str"})
_CAST_CALLS = frozenset({"float", "int", "bool", "complex"})
# Dataclass defaults that allocate a shared mutable / array object.
_MUTABLE_FACTORIES = frozenset({"list", "dict", "set", "bytearray"})
_ARRAY_FACTORIES = frozenset({"array", "asarray", "zeros", "ones", "full",
                              "arange", "empty", "zeros_like", "ones_like"})

HINTS = {
    "RA001": "move the sync out of the jitted step (harvest at the sync "
             "point) or keep the value on device",
    "RA002": "branch with jnp.where / lax.cond / lax.select, or hoist the "
             "decision to the host and pass it as data",
    "RA003": "rebind the donated operands from the call result "
             "(`x, y = step(.., x, y)`) or drop them from donate_argnums",
    "RA004": "use dataclasses.field(default_factory=...) so each instance "
             "gets its own object",
    "RA005": "batch the per-slot reads into ONE bulk jax.device_get and "
             "slice host-side",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    @property
    def hint(self) -> str:
        return HINTS.get(self.code, "")

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.code} {self.message}"
                f"\n    fix: {self.hint}")


# ---------------------------------------------------------------------------
# Module scan: jit regions, donation registry, suppressions
# ---------------------------------------------------------------------------
def _call_name(node: ast.expr) -> str | None:
    """Trailing identifier of a Name / dotted Attribute ('jax.jit'->'jit')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _is_jit_wrapper(func: ast.expr) -> bool:
    # shard_map bodies are traced like jit bodies: same host-sync and
    # traced-branch hazards, plus collectives scheduled by hand
    return _call_name(func) in ("jit", "strict_jit", "shard_map")


@dataclasses.dataclass
class _StaticInfo:
    """Which parameters of a jit-region function are trace-STATIC."""
    names: set[str] = dataclasses.field(default_factory=set)
    nums: set[int] = dataclasses.field(default_factory=set)
    bound: int = 0  # leading params bound by functools.partial (pallas)


def _static_kwargs(call: ast.Call, info: _StaticInfo) -> None:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = kw.value.elts if isinstance(kw.value,
                                               (ast.Tuple, ast.List)) \
                else [kw.value]
            info.names.update(v.value for v in vals
                              if isinstance(v, ast.Constant))
        elif kw.arg == "static_argnums":
            vals = kw.value.elts if isinstance(kw.value,
                                               (ast.Tuple, ast.List)) \
                else [kw.value]
            info.nums.update(v.value for v in vals
                             if isinstance(v, ast.Constant))


def _jitted_targets(tree: ast.Module) -> dict[str, _StaticInfo]:
    """Functions passed to jax.jit / strict_jit / pl.pallas_call, with
    their static-parameter info (static_argnames/nums, partial-bound
    leading args of a pallas kernel are Python values at trace time)."""
    out: dict[str, _StaticInfo] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node.func)
        bound = 0
        if name in ("jit", "strict_jit") and node.args:
            target = node.args[0]
        elif name in ("pallas_call", "shard_map") and node.args:
            target = node.args[0]
            # pl.pallas_call(functools.partial(_kernel, s1, s2, ...), ...)
            # and shard_map(partial(body, cfg, ...), mesh=..., ...): the
            # partial-bound leading args are Python values at trace time
            if isinstance(target, ast.Call) and \
                    _call_name(target.func) == "partial" and target.args:
                bound = len(target.args) - 1
                target = target.args[0]
        else:
            continue
        tname = _call_name(target)
        if tname is None:
            continue
        info = out.setdefault(tname, _StaticInfo())
        info.bound = max(info.bound, bound)
        _static_kwargs(node, info)
    return out


def _jit_decorator_info(node: ast.FunctionDef) -> _StaticInfo | None:
    """StaticInfo if decorated with [partial(]jax.jit[, static_...]]."""
    for dec in node.decorator_list:
        if _is_jit_wrapper(dec):
            return _StaticInfo()
        if isinstance(dec, ast.Call):
            if _is_jit_wrapper(dec.func) or (
                    _call_name(dec.func) == "partial" and dec.args and
                    _is_jit_wrapper(dec.args[0])):
                info = _StaticInfo()
                _static_kwargs(dec, info)
                return info
    return None


def _has_marker(node: ast.FunctionDef, lines: list[str]) -> bool:
    candidates = [node.lineno, node.lineno - 1]
    if node.decorator_list:
        candidates.append(node.decorator_list[0].lineno - 1)
    for ln in candidates:
        if 1 <= ln <= len(lines) and _MARKER_RE.search(lines[ln - 1]):
            return True
    return False


def _donation_registry(tree: ast.Module) -> dict[str, tuple[int, ...]]:
    """name -> donated positions, from `x = [strict_]jit(f, donate_argnums=)`
    assignments (the name is the *assigned* binding the call sites use)."""
    reg: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and
                isinstance(node.value, ast.Call) and
                _is_jit_wrapper(node.value.func)):
            continue
        donated: tuple[int, ...] = ()
        for kw in node.value.keywords:
            if kw.arg != "donate_argnums":
                continue
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                donated = tuple(e.value for e in kw.value.elts
                                if isinstance(e, ast.Constant))
            elif isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, int):
                donated = (kw.value.value,)
        if not donated:
            continue
        for tgt in node.targets:
            tname = _call_name(tgt)
            if tname is not None:
                reg[tname] = donated
    return reg


def _suppressed(lines: list[str], lineno: int, code: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    m = _SUPPRESS_RE.search(lines[lineno - 1])
    if not m:
        return False
    if m.group(1) is None:
        return True
    return code in {c.strip().upper() for c in m.group(1).split(",")}


# ---------------------------------------------------------------------------
# Taint heuristic
# ---------------------------------------------------------------------------
def _expr_tainted(node: ast.expr, tainted: set[str]) -> bool:
    """Does this expression (transitively) read a traced value?"""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Constant):
        return False
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Compare):
        # `x is None` / `"k" in params`: pytree STRUCTURE, trace-static
        if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
               for op in node.ops):
            return False
        # `kind == "r"`: traced values are numeric arrays, so equality
        # against a string literal is static config dispatch
        if any(isinstance(c, ast.Constant) and isinstance(c.value, str)
               for c in [node.left, *node.comparators]):
            return False
        return (_expr_tainted(node.left, tainted)
                or any(_expr_tainted(c, tainted) for c in node.comparators))
    if isinstance(node, ast.Call):
        if _call_name(node.func) in _STATIC_CALLS:
            return False
        parts = list(node.args) + [kw.value for kw in node.keywords]
        if isinstance(node.func, ast.Attribute) and \
                _expr_tainted(node.func.value, tainted):
            return True
        return any(_expr_tainted(p, tainted) for p in parts)
    return any(_expr_tainted(child, tainted)
               for child in ast.iter_child_nodes(node)
               if isinstance(child, ast.expr))


def _taint_targets(target: ast.expr, value_tainted: bool,
                   tainted: set[str]) -> None:
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            if value_tainted:
                tainted.add(node.id)
            else:
                tainted.discard(node.id)


# ---------------------------------------------------------------------------
# Per-function linters
# ---------------------------------------------------------------------------
class _RegionLinter(ast.NodeVisitor):
    """RA001 + RA002 inside one jit-region function (incl. nested defs)."""

    def __init__(self, fn: ast.FunctionDef, path: str, lines: list[str],
                 np_aliases: set[str], static: _StaticInfo | None = None,
                 outer_taint: set[str] | None = None):
        self.path, self.lines = path, lines
        self.np_aliases = np_aliases
        self.findings: list[Finding] = []
        static = static or _StaticInfo()
        args = fn.args
        positional = [a.arg for a in (args.posonlyargs + args.args)]
        names = positional + [a.arg for a in args.kwonlyargs]
        if args.vararg:
            names.append(args.vararg.arg)
        if args.kwarg:
            names.append(args.kwarg.arg)
        skip = set(static.names)
        skip.update(positional[:static.bound])
        skip.update(positional[i] for i in static.nums
                    if i < len(positional))
        self.tainted: set[str] = set(outer_taint or ())
        self.tainted.update(n for n in names
                            if n not in ("self", "cls") and n not in skip)
        for stmt in fn.body:
            self.visit(stmt)

    def _flag(self, node: ast.AST, code: str, message: str) -> None:
        if not _suppressed(self.lines, node.lineno, code):
            self.findings.append(Finding(self.path, node.lineno, code,
                                         message))

    # -- taint propagation ------------------------------------------------
    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        vt = _expr_tainted(node.value, self.tainted)
        for tgt in node.targets:
            _taint_targets(tgt, vt, self.tainted)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if _expr_tainted(node.value, self.tainted):
            _taint_targets(node.target, True, self.tainted)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None:
            _taint_targets(node.target,
                           _expr_tainted(node.value, self.tainted),
                           self.tainted)

    def visit_For(self, node: ast.For) -> None:
        it, tgt = node.iter, node.target
        if (isinstance(it, ast.Call) and _call_name(it.func) == "zip"
                and isinstance(tgt, ast.Tuple)
                and len(tgt.elts) == len(it.args)):
            # zip over mixed static/traced sequences: taint elementwise
            for elt, seq in zip(tgt.elts, it.args):
                _taint_targets(elt, _expr_tainted(seq, self.tainted),
                               self.tainted)
        else:
            _taint_targets(tgt, _expr_tainted(it, self.tainted),
                           self.tainted)
        self.generic_visit(node)

    # -- RA002: Python control flow on traced values ----------------------
    def visit_If(self, node: ast.If) -> None:
        if _expr_tainted(node.test, self.tainted):
            self._flag(node, "RA002",
                       f"Python `if` on traced value "
                       f"`{ast.unparse(node.test)}` inside a jit region "
                       "(concretization error or silent retrace)")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if _expr_tainted(node.test, self.tainted):
            self._flag(node, "RA002",
                       f"Python `while` on traced value "
                       f"`{ast.unparse(node.test)}` inside a jit region")
        self.generic_visit(node)

    # -- RA001: host syncs ------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            if func.attr in _SYNC_ATTRS:
                self._flag(node, "RA001",
                           f"host sync `{ast.unparse(func)}(...)` inside a "
                           "jit region")
            elif func.attr in ("asarray", "array") and \
                    isinstance(func.value, ast.Name) and \
                    func.value.id in self.np_aliases:
                self._flag(node, "RA001",
                           f"`{ast.unparse(func)}(...)` materializes a host "
                           "numpy array inside a jit region")
        elif isinstance(func, ast.Name) and func.id in _CAST_CALLS:
            if any(_expr_tainted(a, self.tainted) for a in node.args):
                self._flag(node, "RA001",
                           f"`{func.id}()` on a traced value forces a host "
                           "sync inside a jit region")
        self.generic_visit(node)

    # nested defs trace under the same jit region, with the outer taint
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        sub = _RegionLinter(node, self.path, self.lines, self.np_aliases,
                            outer_taint=self.tainted)
        self.findings.extend(sub.findings)


def _lint_donation_sites(tree: ast.Module, path: str, lines: list[str],
                         registry: dict[str, tuple[int, ...]]
                         ) -> list[Finding]:
    """RA003: every call of a donated jit must rebind its donated args."""
    if not registry:
        return []
    findings: list[Finding] = []
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node.func)
        if name not in registry:
            continue
        if isinstance(node.func, ast.Name) and name in ("jit", "strict_jit"):
            continue
        donated = [ast.unparse(node.args[p]) for p in registry[name]
                   if p < len(node.args)]
        if not donated:
            continue
        parent = parents.get(node)
        # unwrap `x, y = call(...)`; anything else (bare expr, nested use)
        # leaves the donated operands dead with no rebinding
        targets: set[str] = set()
        if isinstance(parent, ast.Assign) and parent.value is node:
            for tgt in parent.targets:
                elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                    else [tgt]
                targets.update(ast.unparse(e) for e in elts)
        dead = [d for d in donated if d not in targets]
        if dead and not _suppressed(lines, node.lineno, "RA003"):
            findings.append(Finding(
                path, node.lineno, "RA003",
                f"donated argument(s) {', '.join(dead)} of `{name}` are "
                "not rebound from the result — the buffers are invalid "
                "after donation"))
    return findings


def _lint_dataclass_defaults(tree: ast.Module, path: str,
                             lines: list[str]) -> list[Finding]:
    """RA004: mutable / array defaults shared across dataclass instances."""
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        is_dc = any(_call_name(d.func if isinstance(d, ast.Call) else d)
                    == "dataclass" for d in node.decorator_list)
        if not is_dc:
            continue
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign) and
                    stmt.value is not None):
                continue
            default = stmt.value
            bad = None
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                bad = "mutable literal"
            elif isinstance(default, ast.Call):
                cname = _call_name(default.func)
                if cname in _MUTABLE_FACTORIES:
                    bad = "mutable constructor"
                elif cname in _ARRAY_FACTORIES:
                    bad = "array constructor"
            if bad and not _suppressed(lines, stmt.lineno, "RA004"):
                findings.append(Finding(
                    path, stmt.lineno, "RA004",
                    f"dataclass field `{ast.unparse(stmt.target)}` has a "
                    f"{bad} default `{ast.unparse(default)}` — one shared "
                    "object for every instance (and every pytree leaf)"))
    return findings


def _lint_per_slot_gets(tree: ast.Module, path: str,
                        lines: list[str]) -> list[Finding]:
    """RA005: >= 2 scalar-subscripted device_get calls in one function."""

    def scalar_subscripted(expr: ast.expr) -> bool:
        for sub in ast.walk(expr):
            if not isinstance(sub, ast.Subscript):
                continue
            idx = sub.slice
            head = idx.elts[0] if isinstance(idx, ast.Tuple) and idx.elts \
                else idx
            if isinstance(head, ast.Name) or (
                    isinstance(head, ast.Constant) and
                    isinstance(head.value, int)):
                return True
        return False

    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        hits = []
        for call in ast.walk(node):
            if isinstance(call, ast.Call) and \
                    _call_name(call.func) == "device_get" and call.args and \
                    scalar_subscripted(call.args[0]):
                hits.append(call)
        if len(hits) < 2:
            continue
        for call in hits:
            if not _suppressed(lines, call.lineno, "RA005"):
                findings.append(Finding(
                    path, call.lineno, "RA005",
                    f"{len(hits)} per-slot `jax.device_get` round trips in "
                    f"`{node.name}` — each one blocks the dispatch queue"))
    return findings


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint one module's source; returns findings sorted by line."""
    tree = ast.parse(source)
    lines = source.splitlines()
    np_aliases = {"np", "numpy", "onp"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "numpy":
                    np_aliases.add(alias.asname or "numpy")
    jitted = _jitted_targets(tree)
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        static = jitted.get(node.name) or _jit_decorator_info(node)
        if static is None and not _has_marker(node, lines):
            continue
        findings.extend(_RegionLinter(node, path, lines, np_aliases,
                                      static=static).findings)
    findings.extend(_lint_donation_sites(tree, path, lines,
                                         _donation_registry(tree)))
    findings.extend(_lint_dataclass_defaults(tree, path, lines))
    findings.extend(_lint_per_slot_gets(tree, path, lines))
    # a nested jit region reached both via its own marker and via its
    # parent would double-report; dedupe on (line, code, message)
    seen: set[tuple] = set()
    out = []
    for f in sorted(findings, key=lambda f: (f.line, f.code)):
        key = (f.line, f.code, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def lint_paths(root: str | Path) -> list[Finding]:
    """Lint every .py file under ``root`` (or the single file)."""
    root = Path(root)
    files = [root] if root.is_file() else sorted(root.rglob("*.py"))
    findings: list[Finding] = []
    for f in files:
        try:
            findings.extend(lint_source(f.read_text(), str(f)))
        except SyntaxError as e:
            findings.append(Finding(str(f), e.lineno or 0, "RA000",
                                    f"syntax error: {e.msg}"))
    return findings
