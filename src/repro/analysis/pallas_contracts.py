"""Static contracts for the serving Pallas kernels.

The three kernels (``flash_attention``, ``paged_decode_attention``,
``chunked_prefill_attention``) encode their correctness conditions in
tile arithmetic: grids must cover the operands exactly, padded head
groups must land on TPU sublane/lane multiples, and the scalar-
prefetched block-table index maps must only ever address rows that
exist in the pool.  None of that is visible to the type system and all
of it silently miscomputes (or OOMs VMEM) when violated.

This pass re-derives the tile math from a :class:`KernelGeometry`
(head counts + ``MemorySpec`` pool geometry) and checks it *statically*
— no kernel execution — then optionally traces each kernel with
``jax.eval_shape`` so the real BlockSpec/grid consistency checks inside
``pallas_call`` run against abstract operands.

Checked invariants
------------------
* ``num_heads % num_kv_heads == 0`` — the query-group reshape
  ``[B, kv, n_rep, hd]`` requires exact head grouping.
* ``R = rup(max(n_rep, 8), 8)`` is sublane-aligned (``% 8``) and
  ``hdp = rup(hd, 128)`` lane-aligned (``% 128``) — the padded query
  group tile must sit on TPU register boundaries.
* the block table is wide enough: ``nblk * block_size >= max_len``
  (a slot at ``max_len`` tokens must have a physical block for every
  logical block the grid walks).
* null-block safety: pool arrays have ``num_blocks + 1`` rows, the
  allocator hands out ids ``1..num_blocks``, and ``NULL_BLOCK == 0`` —
  so every value a block table can hold addresses a real pool row.
* a per-program VMEM footprint estimate (query tile + two KV tiles +
  scratch triple) stays under the 16 MiB TPU VMEM budget.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core.paging import NULL_BLOCK, blocks_for_tokens
from repro.core.spec import MemorySpec

VMEM_BUDGET_BYTES = 16 * 1024 * 1024  # per-core TPU VMEM


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """Everything the serving kernels' tile math depends on."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    max_batch: int
    max_len: int
    block_size: int
    num_blocks: int          # usable blocks (pool rows = num_blocks + 1)
    kv_dtype: str = "compute"    # "compute" | "int8"
    chunk_lanes: int = 1         # W query lanes of the mixed step

    @classmethod
    def from_spec(cls, mem: MemorySpec, *, num_heads: int,
                  num_kv_heads: int, head_dim: int,
                  chunk_lanes: int = 1) -> "KernelGeometry":
        return cls(num_heads=num_heads, num_kv_heads=num_kv_heads,
                   head_dim=head_dim, max_batch=mem.max_batch,
                   max_len=mem.max_len, block_size=mem.block_size,
                   num_blocks=mem.resolved_num_blocks,
                   kv_dtype=mem.kv_dtype, chunk_lanes=chunk_lanes)

    # derived tile quantities (must mirror the kernels' own formulas)
    @property
    def n_rep(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def R(self) -> int:
        return _rup(max(self.n_rep, 8), 8)

    @property
    def hdp(self) -> int:
        return _rup(self.head_dim, 128)

    @property
    def blocks_per_slot(self) -> int:
        return blocks_for_tokens(self.max_len, self.block_size)

    @property
    def pool_rows(self) -> int:
        return self.num_blocks + 1

    def vmem_tile_bytes(self, lanes: int = 1) -> int:
        """Resident VMEM of one paged-attention program (f32 worst case)."""
        q_tile = lanes * self.R * self.hdp
        kv_tiles = 2 * self.block_size * self.hdp
        scales = 2 * self.block_size if self.kv_dtype == "int8" else 0
        scratch = self.R * self.hdp + 2 * self.R
        return 4 * (q_tile + kv_tiles + scales + scratch)


def check_geometry(geo: KernelGeometry) -> list[str]:
    """Static tile-math violations (empty list == contract holds)."""
    v: list[str] = []
    if geo.num_kv_heads <= 0 or geo.num_heads <= 0:
        v.append(f"non-positive head counts: h={geo.num_heads} "
                 f"kv={geo.num_kv_heads}")
        return v
    if geo.num_heads % geo.num_kv_heads != 0:
        v.append(f"num_heads={geo.num_heads} is not a multiple of "
                 f"num_kv_heads={geo.num_kv_heads}: the [B, kv, n_rep, hd] "
                 "query-group reshape cannot be formed")
    if geo.R % 8 != 0 or geo.R < geo.n_rep:
        v.append(f"query-group rows R={geo.R} not sublane-aligned for "
                 f"n_rep={geo.n_rep} (need R % 8 == 0 and R >= n_rep)")
    if geo.hdp % 128 != 0 or geo.hdp < geo.head_dim:
        v.append(f"padded head dim hdp={geo.hdp} not lane-aligned for "
                 f"head_dim={geo.head_dim} (need hdp % 128 == 0)")
    if geo.block_size <= 0:
        v.append(f"block_size={geo.block_size} must be positive")
        return v
    if geo.blocks_per_slot * geo.block_size < geo.max_len:
        v.append(f"block table width nblk={geo.blocks_per_slot} covers "
                 f"only {geo.blocks_per_slot * geo.block_size} tokens < "
                 f"max_len={geo.max_len}: the kv index map would walk "
                 "past the last logical block")
    if geo.num_blocks < geo.blocks_per_slot:
        v.append(f"pool of {geo.num_blocks} usable blocks cannot hold one "
                 f"max_len sequence ({geo.blocks_per_slot} blocks): a "
                 "full-length request could never be admitted")
    if NULL_BLOCK != 0:
        v.append(f"NULL_BLOCK={NULL_BLOCK} is not row 0: idle-slot writes "
                 "would land on a live pool block")
    # allocator ids are 1..num_blocks; every table value must be a row
    max_table_entry = geo.num_blocks
    if max_table_entry >= geo.pool_rows:
        v.append(f"max block-table entry {max_table_entry} addresses past "
                 f"the pool ({geo.pool_rows} rows)")
    vmem = geo.vmem_tile_bytes(lanes=1)
    if vmem > VMEM_BUDGET_BYTES:
        v.append(f"per-program VMEM estimate {vmem} B exceeds the "
                 f"{VMEM_BUDGET_BYTES} B budget (R={geo.R} hdp={geo.hdp} "
                 f"bs={geo.block_size})")
    return v


def trace_kernels(geo: KernelGeometry) -> list[str]:
    """Trace the three kernels abstractly at this geometry.

    ``jax.eval_shape`` runs the real grid/BlockSpec consistency checks
    inside ``pallas_call`` without executing anything; a contract the
    static pass missed surfaces here as the kernel's own error.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.chunked_prefill import chunked_prefill_attention
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.paged_attention import paged_decode_attention

    B, h, kv, hd = (geo.max_batch, geo.num_heads, geo.num_kv_heads,
                    geo.head_dim)
    bs, nblk, rows = geo.block_size, geo.blocks_per_slot, geo.pool_rows
    W = max(geo.chunk_lanes, 1)
    kv_dt = jnp.int8 if geo.kv_dtype == "int8" else jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    pool = sds((rows, bs, kv, hd), kv_dt)
    tables = sds((B, nblk), jnp.int32)
    lens = sds((B,), jnp.int32)
    scales = {}
    if geo.kv_dtype == "int8":
        scales = {"k_scale": sds((rows, bs, kv), jnp.float32),
                  "v_scale": sds((rows, bs, kv), jnp.float32)}

    failures: list[str] = []

    def _trace(name, fn, *args, **kw):
        try:
            out = jax.eval_shape(functools.partial(fn, **kw), *args)
        except Exception as e:  # the kernel's own contract fired
            failures.append(f"{name}: {type(e).__name__}: {e}")
            return
        want = args[0].shape
        if tuple(out.shape) != tuple(want):
            failures.append(f"{name}: output shape {tuple(out.shape)} != "
                            f"query shape {tuple(want)}")

    _trace("paged_decode_attention", paged_decode_attention,
           sds((B, h, hd), jnp.bfloat16), pool, pool, tables, lens,
           interpret=True, **scales)
    _trace("chunked_prefill_attention", chunked_prefill_attention,
           sds((B, W, h, hd), jnp.bfloat16), pool, pool, tables, lens,
           interpret=True, **scales)
    # flash takes KV already repeated to the query head count
    seq = _rup(geo.max_len, 8)
    _trace("flash_attention", flash_attention,
           sds((B * h, seq, hd), jnp.bfloat16),
           sds((B * h, seq, hd), jnp.bfloat16),
           sds((B * h, seq, hd), jnp.bfloat16),
           causal=True, interpret=True)
    return failures


def check_contracts(geometries: dict[str, KernelGeometry],
                    trace: bool = True) -> dict[str, list[str]]:
    """Run all contracts for a set of named geometries.

    Returns {name: [violations]} containing only the names that failed.
    """
    bad: dict[str, list[str]] = {}
    for name, geo in geometries.items():
        v = check_geometry(geo)
        if not v and trace:
            v = trace_kernels(geo)
        if v:
            bad[name] = v
    return bad
