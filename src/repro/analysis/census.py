"""Compile census: one compilation per fused program, for the whole
support matrix, asserted in CI.

The engine's headline invariant — *steady-state decode compiles exactly
once, no matter the workload* — currently lives in a handful of tests
that each pin one configuration.  The census makes it a property of the
**support matrix**: every supported point of

    (family) x (cache layout) x (kv dtype) x (kernel backend) x (scheduler)

is driven end-to-end on a reduced architecture, and for each point we
record

* the engine's compile counts (``decode`` must be exactly 1 everywhere;
  ``prefill`` is 1 under the chunked scheduler and the bucket count
  under the legacy policy), and
* a sha256 fingerprint of the fused decode step's jaxpr — the canonical
  "what program does this point actually run".

``run_census`` produces the report; ``ANALYSIS.json`` at the repo root
is the committed baseline, and ``compare`` diffs a fresh report against
it so CI fails when a change grows the compile count or silently swaps
the lowering of a supported configuration.  Fingerprints are compared
only when the installed jax version matches the baseline's (lowering
drifts across jax releases are not regressions of *this* repo); the
compilations == 1 assertion holds unconditionally.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Any

BASELINE = Path(__file__).resolve().parents[3] / "ANALYSIS.json"

_ADDR_RE = re.compile(r"0x[0-9a-fA-F]+")


@dataclasses.dataclass(frozen=True)
class MatrixPoint:
    """One supported configuration of the serving matrix."""

    name: str
    arch: str = "qwen1.5-0.5b"       # registry name (reduced() at build)
    cache_layout: str = "dense"      # MemorySpec.cache_layout
    kv_dtype: str = "compute"        # MemorySpec.kv_dtype
    matmul_backend: str = "xla"      # ExecutionSpec.matmul_backend
    paged_attn_impl: str = "gather"  # ExecutionSpec.paged_attn_impl
    policy: str = "bucketed"         # SchedulerSpec.policy
    fleet: bool = False              # multi-topology (maxima) mode
    prefix_cache: bool = False       # MemorySpec.prefix_cache
    harness: bool = False            # drive via repro.harness.replay
    tp: int = 1                      # MeshSpec.tp (GSPMD mesh width)
    dp: int = 1                      # MeshSpec.dp (EngineCluster replicas)
    spec_k: int = 0                  # SpeculationSpec.k (0 = off)


def support_matrix() -> tuple[MatrixPoint, ...]:
    """The curated census points, smallest/cheapest first.

    One point per *distinct lowering* of the fused step — every cache
    layout, codec, kernel backend, scheduler, and family that routes a
    different program through ``_decode_impl``.
    """
    return (
        # the two cheapest points double as the test-suite round trip
        MatrixPoint("gqa-dense-xla-bucketed"),
        MatrixPoint("gqa-dense-xla-chunked", policy="chunked"),
        MatrixPoint("gqa-paged-xla-chunked", cache_layout="paged",
                    policy="chunked"),
        MatrixPoint("gqa-dense-int8kv-bucketed", kv_dtype="int8"),
        MatrixPoint("gqa-paged-int8kv-chunked", cache_layout="paged",
                    kv_dtype="int8", policy="chunked"),
        MatrixPoint("gqa-paged-pallas-attn-chunked", cache_layout="paged",
                    paged_attn_impl="pallas", policy="chunked"),
        MatrixPoint("gqa-dense-pallas-matmul-bucketed",
                    matmul_backend="pallas"),
        MatrixPoint("mla-dense-xla-chunked", arch="deepseek-v3-671b",
                    policy="chunked"),
        MatrixPoint("mla-paged-int8kv-chunked", arch="deepseek-v3-671b",
                    cache_layout="paged", kv_dtype="int8",
                    policy="chunked"),
        MatrixPoint("moe-paged-xla-chunked", arch="granite-moe-1b-a400m",
                    cache_layout="paged", policy="chunked"),
        MatrixPoint("fleet-paged-xla-chunked", cache_layout="paged",
                    policy="chunked", fleet=True),
        # prefix sharing is host-side bookkeeping: these three points
        # prove decode still compiles exactly once (and lowers to the
        # same program as their sharing-off twins) with the trie on
        MatrixPoint("gqa-paged-prefix-chunked", cache_layout="paged",
                    policy="chunked", prefix_cache=True),
        MatrixPoint("gqa-paged-prefix-int8kv-chunked", cache_layout="paged",
                    kv_dtype="int8", policy="chunked", prefix_cache=True),
        MatrixPoint("fleet-paged-prefix-chunked", cache_layout="paged",
                    policy="chunked", fleet=True, prefix_cache=True),
        # the load harness replays a seeded bursty trace through the
        # lifecycle-event path — proves event emission + metric reduction
        # ride the same once-compiled programs as direct submission
        MatrixPoint("gqa-paged-harness-chunked", cache_layout="paged",
                    policy="chunked", harness=True),
        # mesh points: the fused step lowered onto a (1, tp) GSPMD mesh
        # must keep the one-compilation invariant (canonical shardings —
        # a trailing-None PartitionSpec would recompile on call two),
        # and every DP replica behind the cluster queue compiles once
        MatrixPoint("gqa-paged-tp2-chunked", cache_layout="paged",
                    policy="chunked", tp=2),
        MatrixPoint("gqa-paged-dp2-chunked", cache_layout="paged",
                    policy="chunked", dp=2),
        # speculative decoding: the draft-propose / target-verify /
        # accept-rollback step must still be ONE decode compilation, and
        # the workload must actually accept draft tokens (run_point
        # asserts a non-vacuous acceptance count)
        MatrixPoint("gqa-paged-spec-chunked", cache_layout="paged",
                    policy="chunked", spec_k=2),
        MatrixPoint("gqa-paged-spec-int8kv-chunked", cache_layout="paged",
                    kv_dtype="int8", policy="chunked", spec_k=2),
        MatrixPoint("fleet-paged-spec-chunked", cache_layout="paged",
                    policy="chunked", fleet=True, spec_k=2),
    )


def _point_by_name(name: str) -> MatrixPoint:
    for p in support_matrix():
        if p.name == name:
            return p
    raise KeyError(name)


def build_engine(point: MatrixPoint):
    """Reduced engine + loaded params for one matrix point."""
    import dataclasses as dc

    import jax

    from repro.configs import REGISTRY, reduced
    from repro.core.spec import (ExecutionSpec, MemorySpec, MeshSpec,
                                 RuntimeSpec, SchedulerSpec, SpeculationSpec,
                                 maxima_for)
    from repro.models.model import Model
    from repro.serving.cluster import EngineCluster
    from repro.serving.engine import ServingEngine
    from repro.serving.sampling import SamplingParams

    cfg = reduced(REGISTRY[point.arch])
    maxima = None
    cfg_b = None
    if point.fleet:
        cfg_b = dc.replace(cfg, name=cfg.name + "-b", num_layers=1,
                           d_model=48, num_heads=3, num_kv_heads=3,
                           d_ff=96, vocab_size=96)
        maxima = maxima_for(cfg, cfg_b, seq_max=64)
    # spec points self-draft (draft arch == target arch, same weights):
    # maximal acceptance with no second checkpoint, which is exactly what
    # the non-vacuity assertion needs
    speculation = SpeculationSpec(draft_model=cfg, k=point.spec_k) \
        if point.spec_k else None
    spec = RuntimeSpec(
        arch=cfg, maxima=maxima,
        execution=ExecutionSpec(matmul_backend=point.matmul_backend,
                                paged_attn_impl=point.paged_attn_impl),
        memory=MemorySpec(cache_layout=point.cache_layout,
                          kv_dtype=point.kv_dtype,
                          max_batch=4, max_len=64, block_size=8,
                          prefix_cache=point.prefix_cache),
        scheduler=SchedulerSpec(policy=point.policy),
        mesh=MeshSpec(tp=point.tp, dp=point.dp),
        speculation=speculation)
    if point.dp > 1:
        eng = EngineCluster(spec)
    else:
        eng = ServingEngine(
            spec, sampling=SamplingParams(),
            **({"max_models": 2} if maxima is not None else {}))
    params = Model(cfg).init(jax.random.PRNGKey(0))
    eng.load(params, **({"draft": params} if speculation else {}))
    if point.fleet:
        eng.add_model(Model(cfg_b).init(jax.random.PRNGKey(1)), cfg_b)
    return eng


def fingerprint_decode(eng) -> str:
    """sha256 of the fused decode step's canonicalized jaxpr."""
    import jax

    params, cache = eng.params, eng.cache
    if getattr(eng, "speculation", None) is not None:
        # the speculative step's operands are (target, draft) pairs —
        # the same tuples _dispatch composes
        params = (eng.params, eng.draft_params)
        cache = (eng.cache, eng.draft_cache)
    jaxpr = jax.make_jaxpr(eng._decode_impl)(
        params, cache, eng.state, eng.block_tables)
    text = _ADDR_RE.sub("0x0", str(jaxpr))
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def run_point(point: MatrixPoint) -> dict[str, Any]:
    """Drive one matrix point end to end; returns its census record.

    Prefix-cache points run a shared-prefix workload in two waves (the
    trie registers a prompt at prefill completion, so the first wave
    must drain before the second can hit) and additionally assert that
    sharing actually occurred — a silent all-miss would vacuously pass
    the compile-count check.

    Harness points replay a seeded bursty trace through
    ``repro.harness.replay`` instead of submitting directly, so the
    census also covers the lifecycle-event emission path; the record
    carries the step-based (deterministic) harness metrics."""
    eng = build_engine(point)
    if point.harness:
        from repro.harness import bursty_trace, replay

        trace = bursty_trace(8, burst_size=4, gap_steps=6, max_len=24,
                             max_new=3, seed=13)
        res = replay(eng, trace)
        comp = eng.compilations
        m = res.metrics
        record = {
            "compilations": {"decode": comp["decode"],
                             "prefill": comp["prefill"],
                             "prefill_buckets": comp["prefill_buckets"]},
            "completed": len(res.finished),
            "fingerprint": fingerprint_decode(eng),
            "harness": {"n_finished": m.n_finished,
                        "peak_concurrency": m.peak_concurrency,
                        "steps": m.steps,
                        "ttft_steps_p50": m.ttft_steps_p50,
                        "ttft_steps_p99": m.ttft_steps_p99},
        }
        if comp["decode"] != 1:
            record["violation"] = (f"decode compiled {comp['decode']}x "
                                   "(the one-compilation invariant)")
        if comp["prefill"] != 1:
            record["violation"] = (f"chunked prefill compiled "
                                   f"{comp['prefill']}x")
        if m.n_finished != len(trace):
            record["violation"] = (f"harness replay finished "
                                   f"{m.n_finished}/{len(trace)} requests")
        return record
    done = []
    if point.prefix_cache:
        shared = list(range(1, 17))            # two full 8-token blocks
        eng.submit(shared + [20], max_new_tokens=3)
        done += eng.run_to_completion()        # warm + register
        prompts = [shared + [21], shared + [22, 23], [4, 5]]
    else:
        prompts = [[1, 2, 3], [4, 5], list(range(1, 9))]
    for p in prompts:
        eng.submit(p, max_new_tokens=3)
    done += eng.run_to_completion()
    if point.dp > 1:
        # every replica must hold the invariant on its own; the record
        # keeps the worst replica so a single offender fails compare()
        reps = eng.compilations
        comp = {k: max(c[k] for c in reps)
                for k in ("decode", "prefill", "prefill_buckets")}
        probe = eng.replicas[0]
    else:
        comp = eng.compilations
        probe = eng
    record = {
        "compilations": {"decode": comp["decode"],
                         "prefill": comp["prefill"],
                         "prefill_buckets": comp["prefill_buckets"]},
        "completed": len(done),
        "fingerprint": fingerprint_decode(probe),
    }
    expected = len(prompts) + (1 if point.prefix_cache else 0)
    if comp["decode"] != 1:
        record["violation"] = (f"decode compiled {comp['decode']}x "
                               "(the one-compilation invariant)")
    if point.policy == "chunked" and comp["prefill"] != 1:
        record["violation"] = (f"chunked prefill compiled "
                               f"{comp['prefill']}x")
    if len(done) != expected:
        record["violation"] = (f"only {len(done)}/{expected} requests "
                               "completed")
    if point.prefix_cache and eng.stats["prefix_hits"] < 2:
        record["violation"] = (
            f"prefix cache hit {eng.stats['prefix_hits']}x on a workload "
            "with 2 shared-prefix requests — sharing is not engaging")
    if point.spec_k:
        record["spec_accepted"] = eng.stats["spec_accepted"]
        record["spec_steps"] = eng.stats["spec_steps"]
        if eng.stats["spec_accepted"] < 1:
            record["violation"] = (
                "speculation accepted 0 draft tokens on a self-drafting "
                "greedy workload — the compile-count check is vacuous")
    return record


def run_census(names: list[str] | None = None,
               progress=None) -> dict[str, Any]:
    """Full census report for the given (default: all) matrix points."""
    import jax

    points = ([_point_by_name(n) for n in names] if names
              else list(support_matrix()))
    report: dict[str, Any] = {"jax_version": jax.__version__, "points": {}}
    for point in points:
        if progress:
            progress(point.name)
        report["points"][point.name] = run_point(point)
    return report


def compare(report: dict[str, Any], baseline: dict[str, Any], *,
            subset: bool = False) -> list[str]:
    """Diffs that should fail CI (empty == census matches the baseline).

    Fingerprints participate only on a matching jax version; violations
    and compile-count drifts always do.  ``subset=True`` skips the
    missing-point check (the report covered only part of the matrix).
    """
    diffs: list[str] = []
    same_jax = report.get("jax_version") == baseline.get("jax_version")
    base_pts = baseline.get("points", {})
    for name, rec in report["points"].items():
        if "violation" in rec:
            diffs.append(f"{name}: {rec['violation']}")
            continue
        base = base_pts.get(name)
        if base is None:
            diffs.append(f"{name}: not in the committed baseline "
                         "(run --update-baseline)")
            continue
        if rec["compilations"] != base["compilations"]:
            diffs.append(f"{name}: compile counts {rec['compilations']} "
                         f"!= baseline {base['compilations']}")
        elif same_jax and rec["fingerprint"] != base["fingerprint"]:
            diffs.append(f"{name}: decode jaxpr fingerprint "
                         f"{rec['fingerprint']} != baseline "
                         f"{base['fingerprint']} (lowering changed; if "
                         "intentional, run --update-baseline)")
    if not subset:
        for name in base_pts:
            if name not in report["points"]:
                diffs.append(f"{name}: in the baseline but not produced "
                             "by this census (matrix point removed?)")
    return diffs


def load_baseline(path: Path = BASELINE) -> dict[str, Any] | None:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def write_baseline(report: dict[str, Any], path: Path = BASELINE) -> None:
    path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
