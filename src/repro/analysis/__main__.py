"""``python -m repro.analysis`` — run the jit-discipline analyzer.

Modes
-----
--check            all four passes (lint, pallas contracts, jaxpr audit,
                   compile census vs the committed ANALYSIS.json).  This
                   is what CI runs; exit 1 on any finding.
--fast             lint + static pallas contracts only (no engine
                   builds, no tracing) — a pre-commit-speed subset.
--update-baseline  re-run the census and rewrite ANALYSIS.json (after
                   an intentional lowering change).
--lint PATH ...    lint specific files/directories instead of src/repro.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

SRC_ROOT = Path(__file__).resolve().parents[2]


def _lint(paths: list[str]) -> int:
    from repro.analysis.lint import lint_paths

    findings = []
    for p in paths:
        findings.extend(lint_paths(p))
    for f in findings:
        print(f.render())
    print(f"lint: {len(findings)} finding(s) over {', '.join(paths)}")
    return len(findings)


def _contracts(trace: bool) -> int:
    from repro.analysis.census import support_matrix
    from repro.analysis.pallas_contracts import (KernelGeometry,
                                                 check_contracts)
    from repro.configs import REGISTRY, reduced
    from repro.core.spec import MemorySpec, SchedulerSpec

    geometries = {}
    for point in support_matrix():
        cfg = reduced(REGISTRY[point.arch])
        if not cfg.num_kv_heads:
            continue
        mem = MemorySpec(cache_layout=point.cache_layout,
                         kv_dtype=point.kv_dtype,
                         max_batch=4, max_len=64, block_size=8)
        geometries[point.name] = KernelGeometry.from_spec(
            mem, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            chunk_lanes=SchedulerSpec().chunk_size)
    bad = check_contracts(geometries, trace=trace)
    for name, violations in bad.items():
        for v in violations:
            print(f"pallas-contract: {name}: {v}")
    print(f"pallas contracts: {sum(map(len, bad.values()))} violation(s) "
          f"over {len(geometries)} geometries"
          f"{' (traced)' if trace else ' (static only)'}")
    return sum(map(len, bad.values()))


def _audit() -> int:
    from repro.analysis.jaxpr_audit import run_audit

    bad = run_audit(progress=lambda n: print(f"  auditing {n} ..."))
    for violations in bad.values():
        for v in violations:
            print(f"jaxpr-audit: {v}")
    print(f"jaxpr audit: {sum(map(len, bad.values()))} violation(s)")
    return sum(map(len, bad.values()))


def _census(update: bool, names: list[str] | None) -> int:
    from repro.analysis import census

    report = census.run_census(
        names, progress=lambda n: print(f"  census {n} ..."))
    if update:
        census.write_baseline(report)
        print(f"census: baseline written to {census.BASELINE}")
        return 0
    baseline = census.load_baseline()
    if baseline is None:
        print(f"census: no baseline at {census.BASELINE} — run "
              "`python -m repro.analysis --update-baseline` and commit it")
        return 1
    diffs = census.compare(report, baseline, subset=names is not None)
    for d in diffs:
        print(f"census: {d}")
    print(f"census: {len(diffs)} diff(s) over "
          f"{len(report['points'])} matrix points")
    return len(diffs)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jit-discipline analyzer: AST lint, pallas contracts, "
                    "jaxpr audit, compile census")
    ap.add_argument("--check", action="store_true",
                    help="run all four passes (CI mode)")
    ap.add_argument("--fast", action="store_true",
                    help="lint + static contracts only (no tracing)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-run the census and rewrite ANALYSIS.json")
    ap.add_argument("--census-points", nargs="*", default=None,
                    help="restrict census/audit to these matrix points")
    ap.add_argument("--lint", nargs="*", default=None, metavar="PATH",
                    help="lint these paths instead of src/repro")
    args = ap.parse_args(argv)

    # the mesh census points need a multi-device host platform; this
    # must land in XLA_FLAGS before anything imports jax (all the jax
    # imports below are function-local for exactly this reason)
    from repro.launch.mesh import ensure_host_devices
    ensure_host_devices(4)

    lint_paths = args.lint if args.lint else [str(SRC_ROOT / "repro")]

    if args.update_baseline:
        return 1 if _census(True, args.census_points) else 0

    failures = 0
    failures += _lint(lint_paths)
    failures += _contracts(trace=not args.fast)
    if not args.fast:
        failures += _audit()
        failures += _census(False, args.census_points)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
