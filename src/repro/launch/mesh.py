"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh() -> Mesh:
    """Whatever this process actually has (CPU smoke / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
