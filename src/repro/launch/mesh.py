"""Production meshes + the host-platform device bootstrap.

Mesh builders are FUNCTIONS and ``jax`` is imported inside them so
importing this module never touches jax device state — the dry-run (and
every CLI entry point taking ``--devices``) must set ``XLA_FLAGS``
before any device query.
"""
from __future__ import annotations

import os
import warnings


def ensure_host_devices(n: int, *, allow_oversubscribe: bool = True) -> int:
    """Ask XLA for ``n`` host-platform (virtual CPU) devices.

    Must run before jax initializes its backends: appends
    ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS`` (the
    CLI entry points call this from ``--devices N`` before importing
    anything heavy).  When ``n`` exceeds the physical core count we warn
    — forced host devices are threads, so an oversubscribed mesh is
    correct but slower than its device count suggests.  Step-based
    metrics stay exact; wall metrics do not.  Pass
    ``allow_oversubscribe=False`` to clamp to the core count instead
    (production posture; the dev/CI posture keeps the requested count so
    a 1-core runner can still exercise a 4-device GSPMD partition).

    Returns the device count actually requested.
    """
    if n < 1:
        raise ValueError(f"ensure_host_devices needs n >= 1, got {n}")
    cores = os.cpu_count() or 1
    if n > cores:
        if allow_oversubscribe:
            warnings.warn(
                f"forcing {n} host devices on {cores} core(s): the mesh "
                "oversubscribes the host — partitioning is real, wall "
                "speedups are not", stacklevel=2)
        else:
            warnings.warn(
                f"clamping forced host devices {n} -> {cores} (host core "
                "count)", stacklevel=2)
            n = cores
    flag = f"--xla_force_host_platform_device_count={n}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        # an explicit earlier choice (e.g. tests/conftest.py) wins unless
        # it is too small for the requested mesh
        import re
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
        if m and int(m.group(1)) >= n:
            return int(m.group(1))
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+", flag,
                       flags)
        os.environ["XLA_FLAGS"] = flags
        return n
    os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()
    return n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh():
    """Whatever this process actually has (CPU smoke / examples)."""
    import jax
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
