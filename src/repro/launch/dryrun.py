import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the host
# device count at first backend initialization, and the production meshes
# below need 512 placeholder devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each applicable cell this driver:
  1. builds the model + abstract (ShapeDtypeStruct) state — no allocation,
  2. jits the real step (train_step with AdamW update / prefill / decode)
     with in/out shardings from the DP/TP/EP strategy,
  3. ``.lower().compile()`` against the 16x16 single-pod mesh and the
     2x16x16 multi-pod mesh,
  4. records memory_analysis / cost_analysis / parsed collective bytes to
     ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Failures here (sharding mismatch, unsupported collective) are bugs.
Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, REGISTRY, SHAPES_BY_NAME, cell_is_applicable
from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.analytical import (V5E, model_flops, roofline,
                                   scan_undercount_correction,
                                   train_multiplier)
from repro.core.jitutil import strict_jit
from repro.distributed import sharding as shd
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh, mesh_device_count
from repro.models import backend
from repro.models.attention import KVCache, MLACache
from repro.models.model import Model, ModelOptions
from repro.models.rglru import LRUState
from repro.models.ssm import SSMState
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainStepConfig, abstract_state,
                                       batch_shardings, make_step_fn,
                                       state_shardings)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3|f8e5m2)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum wire bytes of every collective op in optimized HLO.

    Per-op heuristic on the *per-device* result shapes in the SPMD module:
      all-reduce         ring RS+AG      -> 2x result bytes
      all-gather         (n-1)/n x out   -> ~1x result bytes
      reduce-scatter     (n-1) x out     -> input ~= out x n; count in
      all-to-all         1x result bytes
      collective-permute 1x result bytes
    """
    totals: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        _, rvalue = stripped.split(" = ", 1)
        # rvalue: "<result shapes> <op-name>(operands), attrs"
        m = re.search(r"\b(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(",
                      rvalue)
        if not m or m.group(2) == "-done":  # count start, skip done
            continue
        op = m.group(1)
        head = rvalue[: m.start()]          # result shapes only
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(head):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        group = re.search(r"replica_groups=\{\{([0-9,]+)\}", stripped)
        n_group = len(group.group(1).split(",")) if group else 0
        if not n_group:
            g2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", stripped)
            n_group = int(g2.group(2)) if g2 else 2
        if op == "all-reduce":
            wire = 2.0 * nbytes * (n_group - 1) / max(n_group, 1)
        elif op == "all-gather":
            wire = nbytes * (n_group - 1) / max(n_group, 1)
        elif op == "reduce-scatter":
            wire = nbytes * (n_group - 1)
        else:
            wire = float(nbytes)
        totals[op] += wire
        counts[op] += 1
    totals["total_per_device"] = sum(totals[k] for k in _COLLECTIVES)
    totals["op_counts"] = counts  # type: ignore[assignment]
    return totals


# ---------------------------------------------------------------------------
# Cache shardings (decode cells)
# ---------------------------------------------------------------------------
def _div(mesh: Mesh, axes, size: int):
    if axes is None:
        return None
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    ax = tuple(a for a in ax if a in mesh.shape)
    if not ax:
        return None
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    if n == 0 or size % n:
        return None
    return ax[0] if len(ax) == 1 else ax


def cache_shardings(cfg: ArchConfig, cache, mesh: Mesh,
                    strategy: shd.ShardingStrategy,
                    opt: frozenset = frozenset()):
    dp = tuple(a for a in strategy.dp_axes if a in mesh.shape)
    tp = strategy.tp_axis

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    def kv_specs(kv_size: int, hd_size: int):
        """kv-head spec + head-dim spec.  'kvhd' opt: when the kv-head
        count doesn't divide the TP axis (qwen2: 8 kv over 16), shard the
        head_dim instead of replicating the whole cache TP-ways."""
        kv_spec = _div(mesh, tp, kv_size)
        hd_spec = None
        if kv_spec is None and "kvhd" in opt:
            hd_spec = _div(mesh, tp, hd_size)
        return kv_spec, hd_spec

    def kv_stacked(c: KVCache):  # [L,B,S,kv,hd]
        s = c.k.shape
        kv_spec, hd_spec = kv_specs(s[3], s[4])
        spec = ns(None, _div(mesh, dp, s[1]), None, kv_spec, hd_spec)
        return KVCache(spec, spec)

    def kv_window(c: KVCache):  # [B,w,kv,hd]
        s = c.k.shape
        kv_spec, hd_spec = kv_specs(s[2], s[3])
        sp = ns(_div(mesh, dp, s[0]), None, kv_spec, hd_spec)
        return KVCache(sp, sp)

    if cfg.family == "ssm":
        conv, h = cache  # [L,B,k,d], [L,B,d,n]
        return SSMState(
            ns(None, _div(mesh, dp, conv.shape[1]), None,
               _div(mesh, tp, conv.shape[3])),
            ns(None, _div(mesh, dp, h.shape[1]),
               _div(mesh, tp, h.shape[2]), None))
    if cfg.mla is not None:
        return MLACache(
            ns(None, _div(mesh, dp, cache.c_kv.shape[1]), None, None),
            ns(None, _div(mesh, dp, cache.k_rope.shape[1]), None, None))
    if cfg.family == "hybrid":
        out = []
        for st in cache:
            if isinstance(st, LRUState):  # conv [B,k,w], h [B,w]
                out.append(LRUState(
                    ns(_div(mesh, dp, st.conv.shape[0]), None,
                       _div(mesh, tp, st.conv.shape[2])),
                    ns(_div(mesh, dp, st.h.shape[0]),
                       _div(mesh, tp, st.h.shape[1]))))
            else:
                out.append(kv_window(st))
        return out
    if cfg.encdec is not None:
        return {"self": kv_stacked(cache["self"]),
                "cross": kv_stacked(cache["cross"])}
    return kv_stacked(cache)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------
def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               remat: str = "full", unroll: bool = True,
               opt: frozenset = frozenset()):
    """Returns (lowered, n_chips).  Raises on sharding bugs.

    ``unroll=True`` emits straight-line layers so cost_analysis is exact
    (a lax.scan body is counted once, not x trip-count); ``unroll=False``
    is the production form (compact HLO, realistic buffer assignment) —
    used for the memory pass and the multi-pod pass/fail check.

    ``opt`` selects beyond-baseline §Perf variants:
      'sp'    sequence-parallel residual stream (RS+AG collectives)
      'int8'  int8 serving weights (paper C6 at deployment)
      'kvhd'  shard the KV-cache head_dim when kv-heads don't divide TP
      'dots'  remat policy: save matmul outputs (no dispatch recompute)
      'gqa'   grouped GQA decode contraction (no repeat_kv cache copy)
      'nofsdp' turn off FSDP param sharding for train
    """
    strategy = shd.strategy_for_mesh(
        mesh, fsdp=(shape.kind == "train" and "nofsdp" not in opt),
        sp="sp" in opt)
    if "dots" in opt:
        remat = "dots"
    opts = ModelOptions(remat=remat if shape.kind == "train" else "none",
                        unroll_layers=unroll, grouped_gqa="gqa" in opt)
    model = Model(cfg, opts)

    def params_trio():
        """(abstract, axes) trees, int8-quantized under the 'int8' opt."""
        abstract, axes = model.abstract(), model.axes()
        if "int8" in opt and shape.kind != "train":
            from repro.core.serve_quant import quantize_abstract, quantize_axes
            return quantize_abstract(abstract), quantize_axes(axes, abstract)
        return abstract, axes
    specs = inp.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype=jnp.bfloat16 if cfg.param_count() > 1e11
            else jnp.float32)
        step_cfg = TrainStepConfig(optimizer=opt_cfg, donate=True)
        st_sh = state_shardings(model, mesh, strategy)
        b_sh = batch_shardings(mesh, strategy, specs)
        raw = make_step_fn(model, step_cfg)

        def wrapped(state, batch):
            with shd.active(mesh, strategy):
                return raw(state, batch)

        jitted = strict_jit(wrapped, in_shardings=(st_sh, b_sh),
                            out_shardings=(st_sh, NamedSharding(mesh, P())),
                            donate_argnums=(0,))
        with backend.faithful():
            lowered = jitted.lower(abstract_state(model, opt_cfg), specs)
    elif shape.kind == "prefill":
        abstract, axes = params_trio()
        p_sh = shd.tree_param_shardings(mesh, axes, abstract, strategy)
        b_sh = batch_shardings(mesh, strategy, specs)
        logits_sh = NamedSharding(mesh, P(
            tuple(a for a in strategy.dp_axes if a in mesh.shape), None,
            _div(mesh, strategy.tp_axis, cfg.vocab_size)))
        cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                     abstract=True)
        c_sh = cache_shardings(cfg, cache_abs, mesh, strategy, opt)

        def prefill(params, batch):
            with shd.active(mesh, strategy):
                return model.prefill(params, batch, max_len=shape.seq_len)

        jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                         out_shardings=(logits_sh, c_sh))
        with backend.faithful():
            lowered = jitted.lower(abstract, specs)
    else:  # decode
        abstract, axes = params_trio()
        p_sh = shd.tree_param_shardings(mesh, axes, abstract, strategy)
        cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                     abstract=True)
        c_sh = cache_shardings(cfg, cache_abs, mesh, strategy, opt)
        dp = tuple(a for a in strategy.dp_axes if a in mesh.shape)
        tok_sh = NamedSharding(mesh, P(
            _div(mesh, dp, shape.global_batch), None))
        idx_sh = NamedSharding(mesh, P(_div(mesh, dp, shape.global_batch)))
        logits_sh = NamedSharding(mesh, P(
            _div(mesh, dp, shape.global_batch), None,
            _div(mesh, strategy.tp_axis, cfg.vocab_size)))

        def decode(params, cache, tokens, cache_index):
            with shd.active(mesh, strategy):
                return model.decode_step(params, cache, tokens, cache_index)

        jitted = strict_jit(decode,
                            in_shardings=(p_sh, c_sh, tok_sh, idx_sh),
                            out_shardings=(logits_sh, c_sh),
                            donate_argnums=(1,))
        with backend.faithful():
            lowered = jitted.lower(
                abstract, cache_abs, specs["tokens"],
                specs["cache_index"])
    return lowered, mesh_device_count(mesh)


def run_cell(cfg: ArchConfig, shape: ShapeSpec, mesh_kind: str,
             out_dir: str, force: bool = False,
             opt: frozenset = frozenset()) -> dict:
    name = f"{cfg.name}__{shape.name}__{mesh_kind}"
    if opt:
        name += "__opt-" + "-".join(sorted(opt))
    path = os.path.join(out_dir, name.replace("/", "_") + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    rec: dict = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_kind,
                 "opt": sorted(opt)}
    try:
        mesh = make_production_mesh(multi_pod=mesh_kind == "multi")
        ok, why = cell_is_applicable(cfg, shape)
        if not ok:
            rec.update(status="skipped", reason=why)
        else:
            # multi-pod: production (scanned) form; proving lower+compile
            # on the pod axis is the requirement, and compiles ~10x faster.
            unroll = mesh_kind == "single"
            lowered, n_chips = lower_cell(cfg, shape, mesh, unroll=unroll,
                                          opt=opt)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            def mem_fields(comp):
                try:
                    ma = comp.memory_analysis()
                    return {k: getattr(ma, k) for k in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                        "alias_size_in_bytes") if hasattr(ma, k)}
                except Exception as e:  # CPU backend may not implement it
                    return {"error": str(e)}

            ca = compiled.cost_analysis() or {}
            mem = mem_fields(compiled)
            if unroll:
                # memory realism pass: the production (scan) form is what
                # actually runs; its buffer assignment is the honest
                # per-device footprint
                try:
                    scan_lowered, _ = lower_cell(cfg, shape, mesh,
                                                 unroll=False, opt=opt)
                    rec["memory_analysis_scan"] = mem_fields(
                        scan_lowered.compile())
                except Exception as e:
                    rec["memory_analysis_scan"] = {"error": str(e)}
            coll = collective_bytes(compiled.as_text())
            # cost_analysis is for the per-device SPMD module -> scale up
            flops = float(ca.get("flops", 0.0)) * n_chips
            bytes_hbm = float(ca.get("bytes accessed", 0.0)) * n_chips
            corr = scan_undercount_correction(cfg, shape)
            if shape.kind == "train":
                corr *= train_multiplier()
            flops += corr
            mf = model_flops(cfg, shape)
            rl = roofline(flops, bytes_hbm,
                          coll["total_per_device"] * n_chips, n_chips, V5E)
            rec["scan_flops_correction"] = corr
            rec.update(
                status="ok", n_chips=n_chips,
                lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                cost_analysis={k: ca[k] for k in sorted(ca)
                               if isinstance(ca[k], (int, float))},
                memory_analysis=mem,
                collectives=coll,
                hlo_flops=flops, hlo_bytes=bytes_hbm,
                model_flops=mf,
                model_over_hlo=round(mf / flops, 4) if flops else None,
                roofline={
                    "t_compute_s": rl.t_compute, "t_memory_s": rl.t_memory,
                    "t_collective_s": rl.t_collective,
                    "dominant": rl.dominant,
                    "compute_fraction": round(rl.compute_fraction, 4),
                },
            )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = rec.get("status")
    extra = rec.get("reason") or rec.get("error", "")
    print(f"[{rec['wall_s']:7.1f}s] {name}: {status} {extra}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated perf variants: sp,int8,kvhd,"
                         "dots,nofsdp (records go to --out)")
    args = ap.parse_args()
    opt = frozenset(o for o in args.opt.split(",") if o)

    archs = list(ASSIGNED) if (args.all or not args.arch) \
        else [REGISTRY[args.arch]]
    shapes = list(SHAPES_BY_NAME.values()) if (args.all or not args.shape) \
        else [SHAPES_BY_NAME[args.shape]]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for c in archs:
            for s in shapes:
                ok, why = cell_is_applicable(c, s)
                print(f"{c.name:24s} {s.name:12s} "
                      f"{'RUN' if ok else why}")
        return

    n_ok = n_fail = 0
    for mesh_kind in meshes:
        for c in archs:
            for s in shapes:
                rec = run_cell(c, s, mesh_kind, args.out, args.force,
                               opt=opt)
                if rec.get("status") == "error":
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"done: {n_ok} ok/skipped, {n_fail} errors", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
