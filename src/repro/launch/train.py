"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

CPU-runnable end-to-end: reduced config by default (--full lowers the real
config; only sensible on a real cluster).  Wires the full substrate: data
pipeline -> sharded train step -> checkpointing -> fault-tolerant restart.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import REGISTRY, reduced
from repro.data.pipeline import SyntheticLMStream
from repro.distributed import sharding as shd
from repro.launch.mesh import make_dev_mesh
from repro.models.model import Model, ModelOptions
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainStepConfig, init_state,
                                       make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if not args.full:
        cfg = reduced(cfg)
    mesh = make_dev_mesh()
    strategy = shd.strategy_for_mesh(mesh)
    model = Model(cfg, ModelOptions())
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    step_cfg = TrainStepConfig(optimizer=opt_cfg, accum_steps=args.accum)

    stream = SyntheticLMStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch)
    state = init_state(model, jax.random.PRNGKey(0), opt_cfg)
    start_step = 0
    if args.resume and args.ckpt_dir:
        got = ckpt.restore_latest(args.ckpt_dir, state)
        if got is not None:
            state, meta = got
            start_step = meta["step"]
            stream = SyntheticLMStream.restore(
                meta["data_state"], vocab_size=cfg.vocab_size,
                seq_len=args.seq, global_batch=args.batch)
            print(f"resumed from step {start_step}")

    batch0 = stream.next()
    specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch0.items()}
    jitted, _, _ = make_train_step(model, mesh, strategy, step_cfg, specs)

    t0 = time.time()
    batch = batch0
    for i in range(start_step, args.steps):
        state, metrics = jitted(state, batch)
        batch = stream.next()
        if (i + 1) % args.log_every == 0 or i == start_step:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tok_s = (i + 1 - start_step) * args.batch * args.seq / dt
            print(f"step {i + 1:5d}  loss {loss:7.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tok_s:,.0f} tok/s",
                  flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1, state,
                      meta={"data_state": stream.state()}, async_write=True)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state,
                  meta={"data_state": stream.state()})
    print("done")


if __name__ == "__main__":
    main()
