"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Builds one ``core.spec.RuntimeSpec`` from the CLI flags (the single
configuration surface), spins up the serving engine on a reduced config,
submits a demo request mix, and reports tokens/s + the compile-once
accounting.

Multi-topology mode: ``--fleet qwen1.5-0.5b,codeqwen1.5-7b`` serves
several architectures from ONE compiled decode step — shared maxima are
planned with ``maxima_for``, each model is packed into the fabric's
weight table, and requests carry a model id.

Harness mode: ``--trace t.jsonl`` replays an on-disk trace (see
``repro.harness.trace``) through the engine instead of the demo mix and
prints the reduced TTFT/ITL/goodput metrics; ``--tuned`` discards the
hand-picked memory/scheduler flags and lets the analytical autotuner
(``RuntimeSpec.tuned``) choose them — from the trace's own statistics
when ``--trace`` is also given.
"""
from __future__ import annotations

import argparse
import time

from repro.launch.mesh import ensure_host_devices


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--devices", type=int, default=None,
                    help="force this many host-platform devices (must be "
                         "set before jax initializes — which is why every "
                         "heavy import in this driver is deferred)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width: the fused step's weights "
                         "and KV pool shard over a (1, tp) GSPMD mesh")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replicas behind one admission "
                         "queue (serving.cluster.EngineCluster)")
    ap.add_argument("--fleet", default=None,
                    help="comma-separated arch ids served multi-topology "
                         "from one compiled step (overrides --arch)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--sync-every", type=int, default=4,
                    help="fused decode steps dispatched between host syncs")
    ap.add_argument("--kernels", choices=("xla", "pallas"), default="xla",
                    help="matmul routing for prefill/decode")
    ap.add_argument("--quant", choices=("none", "int8"), default="none",
                    help="serving-time weight quantization (C6); works in "
                         "--fleet mode too (int8 fleet weight table)")
    ap.add_argument("--quant-min-size", type=int, default=None,
                    help="param leaves under this many elements stay float")
    ap.add_argument("--kv-dtype", choices=("compute", "int8"),
                    default="compute",
                    help="KV-cache storage codec: bf16 values or "
                         "quantize-on-write int8 (~2x cache capacity)")
    ap.add_argument("--param-dtype", default=None,
                    help="parameter dtype by name, e.g. fp32 / bf16")
    ap.add_argument("--compute-dtype", default=None,
                    help="activation dtype by name, e.g. bf16 / fp32")
    ap.add_argument("--cache-layout", choices=("dense", "paged"),
                    default="dense")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: tokens per KV block")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged layout: pool size (default: dense worst case)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV blocks across requests "
                         "(requires --cache-layout paged; rejected at spec "
                         "construction otherwise)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens proposed per "
                         "fused step (0 disables); the target verifies all "
                         "k+1 positions in one chunk-shaped attend")
    ap.add_argument("--draft", default=None,
                    help="draft arch id for --spec-k (default: the target "
                         "itself, i.e. self-draft; must share the target's "
                         "vocab / tokenizer space)")
    ap.add_argument("--trace", default=None,
                    help="replay this on-disk trace (repro.harness.trace "
                         "format) instead of the demo request mix and print "
                         "harness metrics")
    ap.add_argument("--tuned", action="store_true",
                    help="ignore the memory/scheduler flags and let the "
                         "analytical autotuner pick them (uses the trace's "
                         "workload statistics when --trace is given)")
    ap.add_argument("--slo-ttft-steps", type=int, default=None,
                    help="with --trace: count a request toward goodput only "
                         "if its first token lands within this many steps")
    args = ap.parse_args()
    if args.tuned and args.fleet:
        ap.error("--tuned tunes a single architecture; drop --fleet")
    if args.spec_k and (args.fleet or args.tuned or args.dp > 1):
        ap.error("--spec-k drives one hand-specified engine in this "
                 "driver; drop --fleet/--tuned/--dp")
    if args.dp > 1 and args.fleet:
        ap.error("--dp replicates one architecture; drop --fleet")
    need = args.tp * args.dp
    if args.devices is not None:
        ensure_host_devices(max(args.devices, need))
    elif need > 1:
        ensure_host_devices(need)

    # everything below may initialize jax — after the device bootstrap
    import dataclasses

    import jax

    from repro.configs import REGISTRY, reduced
    from repro.core.spec import (ExecutionSpec, MemorySpec, MeshSpec,
                                 RuntimeSpec, maxima_for)
    from repro.models.model import Model
    from repro.serving.cluster import EngineCluster
    from repro.serving.engine import ServingEngine
    from repro.serving.sampling import SamplingParams

    names = (args.fleet.split(",") if args.fleet else [args.arch])
    cfgs = [reduced(REGISTRY[n]) for n in names]
    maxima = (maxima_for(*cfgs, seq_max=args.max_len)
              if args.fleet else None)
    # string dtype names flow straight into the spec — ExecutionSpec
    # normalizes "bf16"/"fp32"/... at construction
    ex_kw = {}
    if args.param_dtype is not None:
        ex_kw["param_dtype"] = args.param_dtype
    if args.compute_dtype is not None:
        ex_kw["compute_dtype"] = args.compute_dtype
    if args.quant_min_size is not None:
        ex_kw["quant_min_size"] = args.quant_min_size
    trace = None
    if args.trace is not None:
        from repro.harness import load_trace
        trace = load_trace(args.trace)
    execution = ExecutionSpec(matmul_backend=args.kernels,
                              quant=args.quant, **ex_kw)
    if args.tuned:
        from repro.harness import WorkloadProfile
        workload = (WorkloadProfile.from_trace(trace)
                    if trace is not None else None)
        spec = RuntimeSpec.tuned(cfgs[0], workload=workload,
                                 max_len=args.max_len, execution=execution,
                                 allow_int8_kv=args.kv_dtype == "int8")
        m = spec.memory
        print(f"tuned spec: {m.cache_layout} max_batch={m.max_batch} "
              f"policy={spec.scheduler.policy} "
              f"chunk={spec.scheduler.chunk_size} "
              f"kv_dtype={m.kv_dtype} prefix_cache={m.prefix_cache}")
    else:
        speculation = draft_cfg = None
        if args.spec_k:
            from repro.core.spec import SpeculationSpec
            draft_cfg = (reduced(REGISTRY[args.draft]) if args.draft
                         else cfgs[0])
            # a temperature > 0 demo mix needs the rejection-sampling
            # accept path; greedy runs take the exact argmax-match path
            speculation = SpeculationSpec(
                draft_model=draft_cfg, k=args.spec_k,
                greedy_accept=args.temperature <= 0.0)
        spec = RuntimeSpec(
            arch=cfgs[0], maxima=maxima,
            execution=execution,
            memory=MemorySpec(cache_layout=args.cache_layout,
                              max_batch=args.max_batch, max_len=args.max_len,
                              block_size=args.block_size,
                              num_blocks=args.num_blocks,
                              kv_dtype=args.kv_dtype,
                              prefix_cache=args.prefix_cache),
            speculation=speculation)
    if args.tp > 1 or args.dp > 1:
        spec = dataclasses.replace(
            spec, mesh=MeshSpec(tp=args.tp, dp=args.dp))
    sampling = SamplingParams(temperature=args.temperature, top_k=40)
    if args.dp > 1:
        eng = EngineCluster(spec)
    else:
        eng = ServingEngine(spec, max_models=max(len(cfgs), 1),
                            sampling=sampling)
    if args.fleet:
        model_ids = [eng.add_model(Model(c).init(jax.random.PRNGKey(i)), c)
                     for i, c in enumerate(cfgs)]
    else:
        params = Model.from_spec(spec).init(jax.random.PRNGKey(0))
        if args.spec_k:
            draft = (params if draft_cfg == cfgs[0]
                     else Model(draft_cfg).init(jax.random.PRNGKey(1)))
            eng.load(params, draft=draft)
        else:
            eng.load(params)
        model_ids = [0]

    if trace is not None:
        from repro.harness import SLO, replay
        slo = (SLO(ttft_steps=args.slo_ttft_steps)
               if args.slo_ttft_steps is not None else None)
        t0 = time.time()
        res = replay(eng, trace, slo=slo)
        dt = time.time() - t0
        done, m = res.finished, res.metrics
        print(f"trace {trace.name!r} (seed {trace.seed}): "
              f"{m.n_finished}/{m.n_requests} finished over {m.steps} "
              f"fused steps in {dt:.1f}s ({m.tokens_per_s:,.0f} tok/s)")
        print(f"  TTFT p50/p99 {m.ttft_steps_p50}/{m.ttft_steps_p99} steps "
              f"({m.ttft_s_p50 * 1e3:.1f}/{m.ttft_s_p99 * 1e3:.1f} ms)   "
              f"ITL p50/p99 {m.itl_steps_p50}/{m.itl_steps_p99} steps")
        print(f"  peak concurrency {m.peak_concurrency}, "
              f"{m.n_preemptions} preemptions, {m.prefix_hits} prefix hits")
        if slo is not None:
            print(f"  SLO (ttft<={args.slo_ttft_steps} steps): "
                  f"{m.n_slo_met}/{m.n_requests} met, goodput "
                  f"{m.goodput_req_per_1k_steps:.1f} req/1k-steps "
                  f"({m.goodput_req_s:.2f} req/s)")
    else:
        rng = jax.random.PRNGKey(7)
        for i in range(args.requests):
            rng, k = jax.random.split(rng)
            plen = int(jax.random.randint(k, (), 4, args.max_len // 2))
            prompt = list(range(1, plen + 1))
            # the cluster has no engine-level default sampling — pass it
            # per submit (a no-op on the single-engine path)
            eng.submit(prompt, max_new_tokens=args.max_new,
                       sampling=sampling,
                       model=model_ids[i % len(model_ids)])

        t0 = time.time()
        done = (eng.run_to_completion() if args.dp > 1
                else eng.run_to_completion(sync_every=args.sync_every))
        dt = time.time() - t0
        total_new = sum(len(r.generated) for r in done)
        print(f"{len(done)} requests, {total_new} tokens in {dt:.1f}s "
              f"({total_new / dt:,.0f} tok/s)")
    if args.fleet:
        print(f"fleet: {names} served by ONE fused step "
              f"(decode compilations = {eng.compilations['decode']})")
    if args.tp > 1 or args.dp > 1:
        cap = spec.capacity()
        print(f"mesh: tp={args.tp} x dp={args.dp} on {cap.n_devices} "
              f"devices — KV pool {cap.kv_shards}-way sharded, "
              f"{cap.per_device_cache_bytes / 2**20:.2f} MiB cache/device, "
              f"up to {cap.max_concurrent} concurrent")
    if args.dp > 1:
        print("compile accounting per replica:", eng.compilations)
        gets = sum(s["device_gets"] for s in eng.replica_stats())
        print(f"host traffic: {gets} bulk device_gets over "
              f"{eng.stats['decode_steps']} cluster rounds")
        for r in done[:3]:
            print(f"  req {r.uid} (model {r.model}): "
                  f"prompt[:6]={r.prompt[:6]} -> {r.generated[:10]}...")
        return
    print("compile accounting:", eng.compilations)
    if args.spec_k:
        acc, ss = eng.stats["spec_accepted"], eng.stats["spec_steps"]
        mean = acc / ss if ss else 0.0
        print(f"speculation: k={args.spec_k} draft={draft_cfg.name}, "
              f"{acc} draft tokens accepted over {ss} speculative steps "
              f"(mean {mean:.2f}; ~{1 + mean:.2f} tokens/step per "
              "decoding slot)")
    if spec.memory.kv_dtype == "int8":
        hd = cfgs[0].resolved_head_dim
        print(f"int8 KV cache: {2 * hd / (hd + 4):.2f}x fewer cache "
              f"bytes/token than bf16 at head_dim={hd}")
    print(f"host traffic: {eng.stats['device_gets']} bulk device_gets over "
          f"{eng.stats['decode_steps']} fused decode steps")
    if spec.memory.cache_layout == "paged":
        s = eng.memory_stats()
        print(f"paged pool: {s.total_blocks} x "
              f"{spec.memory.block_size}-token blocks, "
              f"{eng.stats['preemptions']} preemptions")
        if spec.memory.prefix_cache:
            print(f"prefix cache: {eng.stats['prefix_hits']} hits / "
                  f"{eng.stats['prefix_hit_tokens']} tokens skipped, "
                  f"{eng.stats['cow_forks']} CoW forks, "
                  f"{eng.stats['prefix_evictions']} evictions; "
                  f"{s.shared_blocks} shared + {s.cached_blocks} parked "
                  "blocks resident")
    for r in done[:3]:
        print(f"  req {r.uid} (model {r.model}): prompt[:6]={r.prompt[:6]} "
              f"-> {r.generated[:10]}...")


if __name__ == "__main__":
    main()
