"""``input_specs``: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero-allocation — the dry-run lowers
against these for all 40 (arch x shape) cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec


def _frontend_spec(cfg: ArchConfig, batch: int):
    if cfg.encdec is not None:
        return jax.ShapeDtypeStruct(
            (batch, cfg.encdec.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.frontend is not None:
        return jax.ShapeDtypeStruct(
            (batch, cfg.frontend.num_tokens, cfg.d_model), jnp.bfloat16)
    return None


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "targets": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    fe = _frontend_spec(cfg, b)
    if fe is not None:
        specs["frontend"] = fe
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    fe = _frontend_spec(cfg, b)
    if fe is not None:
        specs["frontend"] = fe
    return specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """One new token against a cache of depth seq_len (cache itself comes
    from ``Model.init_cache(abstract=True)``)."""
    b = shape.global_batch
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "cache_index": jax.ShapeDtypeStruct((b,), jnp.int32)}


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
