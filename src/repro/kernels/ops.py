"""jit'd public wrappers for the Pallas kernels.

* Block shapes default to the §3.10 tile planner (``core.tiling``) so the
  synthesis-time tile choice is automatic per shape, exactly as the paper
  fixes TS_MHA/TS_FFN per platform.
* ``interpret`` defaults to True off-TPU so the whole suite validates on
  CPU; on TPU the same calls emit real Mosaic kernels.
* Leading batch dims are folded into the row dimension (the paper's
  SL-major layout).
"""
from __future__ import annotations

import functools

import jax

from repro.core.quant import QTensor, quantize_dynamic
from repro.core.tiling import plan_matmul
from repro.kernels import ffn as _ffn
from repro.kernels import flash_attention as _fa
from repro.kernels import int8_matmul as _i8
from repro.kernels import layernorm as _ln
from repro.kernels import qkv_proj as _qkv
from repro.kernels import tiled_matmul as _mm


def _interp() -> bool:
    return jax.default_backend() != "tpu"


@functools.cache
def _blocks(M: int, K: int, N: int, dtype_bytes: int = 2
            ) -> tuple[int, int, int]:
    p = plan_matmul(M, K, N, dtype_bytes)
    return p.bm, p.bk, p.bn


def _fold(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def tiled_matmul(x: jax.Array, w: jax.Array,
                 blocks: tuple[int, int, int] | None = None) -> jax.Array:
    """y[..., n] = x[..., k] w[k, n] via the Fig. 4 kernel."""
    x2, lead = _fold(x)
    bm, bk, bn = blocks or _blocks(x2.shape[0], w.shape[0], w.shape[1])
    y = _mm.tiled_matmul(x2, w, bm=bm, bk=bk, bn=bn, interpret=_interp())
    return y.reshape(lead + (w.shape[1],))


def qkv_proj(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
             blocks: tuple[int, int, int] | None = None):
    x2, lead = _fold(x)
    bm, bk, bn = blocks or _blocks(x2.shape[0], wq.shape[0],
                                   min(wq.shape[1], wk.shape[1]))
    q, k, v = _qkv.qkv_proj(x2, wq, wk, wv, bm=bm, bk=bk, bn=bn,
                            interpret=_interp())
    return (q.reshape(lead + (wq.shape[1],)),
            k.reshape(lead + (wk.shape[1],)),
            v.reshape(lead + (wv.shape[1],)))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 512,
                    bkv: int = 512) -> jax.Array:
    """q/k/v: [B, S, H, hd] (kv already head-repeated) -> [B, S, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, bq=bq, bkv=bkv,
                            interpret=_interp())
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def ffn1(x: jax.Array, w1: jax.Array, b1: jax.Array,
         activation: str = "relu") -> jax.Array:
    x2, lead = _fold(x)
    bm, bk, bn = _blocks(x2.shape[0], w1.shape[0], w1.shape[1])
    y = _ffn.ffn1(x2, w1, b1, activation=activation, bm=bm, bk=bk, bn=bn,
                  interpret=_interp())
    return y.reshape(lead + (w1.shape[1],))


def ffn1_gated(x: jax.Array, w1: jax.Array, wg: jax.Array,
               activation: str = "swiglu") -> jax.Array:
    x2, lead = _fold(x)
    bm, bk, bn = _blocks(x2.shape[0], w1.shape[0], w1.shape[1])
    y = _ffn.ffn1_gated(x2, w1, wg, activation=activation, bm=bm, bk=bk,
                        bn=bn, interpret=_interp())
    return y.reshape(lead + (w1.shape[1],))


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    x2, lead = _fold(x)
    y = _ln.layernorm(x2, gamma, beta, interpret=_interp())
    return y.reshape(lead + (x.shape[-1],))


def rmsnorm(x: jax.Array, gamma: jax.Array) -> jax.Array:
    x2, lead = _fold(x)
    y = _ln.rmsnorm(x2, gamma, interpret=_interp())
    return y.reshape(lead + (x.shape[-1],))


def quantized_dense(x: jax.Array, qw: QTensor) -> jax.Array:
    """Serving-path int8 dense: dynamic activation quant + int8 kernel."""
    x2, lead = _fold(x)
    qx = quantize_dynamic(x2)
    bm, bk, bn = _blocks(x2.shape[0], qw.values.shape[0],
                         qw.values.shape[1], dtype_bytes=1)
    y = _i8.int8_matmul(qx.values, qx.scale, qw.values, qw.scale,
                        bm=bm, bk=bk, bn=bn, interpret=_interp(),
                        out_dtype=x.dtype)
    return y.reshape(lead + (qw.values.shape[1],))
