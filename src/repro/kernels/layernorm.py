"""LN unit as a Pallas kernel (paper §3.5, Algorithm 8).

The paper's LN unit makes four passes over each row (mean, variance,
normalize, scale+shift).  On TPU one row block fits VMEM whole, so all
four fuse into a single read-compute-write pass on the VPU — the same
module boundary, one HBM round trip instead of four.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(eps: float, d_live: int, x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # [br, Dp]
    mask = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) < d_live
    x = jnp.where(mask, x, 0.0)
    n = float(d_live)
    mu = jnp.sum(x, axis=-1, keepdims=True) / n
    cent = jnp.where(mask, x - mu, 0.0)
    var = jnp.sum(cent * cent, axis=-1, keepdims=True) / n
    y = cent * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.where(mask, y, 0.0).astype(o_ref.dtype)


def _rms_kernel(eps: float, d_live: int, x_ref, g_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    mask = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) < d_live
    x = jnp.where(mask, x, 0.0)
    var = jnp.sum(x * x, axis=-1, keepdims=True) / float(d_live)
    y = x * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.where(mask, y, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
              eps: float = 1e-5, br: int = 256,
              interpret: bool = False) -> jax.Array:
    """Row-wise LayerNorm: x [R, D] -> [R, D]."""
    R, D = x.shape
    br = min(br, _rup(R, 8))
    Rp, Dp = _rup(R, br), _rup(D, 128)
    x = jnp.pad(x, ((0, Rp - R), (0, Dp - D)))
    g = jnp.pad(gamma, ((0, Dp - D),)).reshape(1, Dp)
    b = jnp.pad(beta, ((0, Dp - D),)).reshape(1, Dp)
    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps, D),
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, Dp), lambda i: (i, 0)),
                  pl.BlockSpec((1, Dp), lambda i: (0, 0)),
                  pl.BlockSpec((1, Dp), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, Dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, Dp), x.dtype),
        interpret=interpret,
    )(x, g, b)
    return out[:R, :D]


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-6,
            br: int = 256, interpret: bool = False) -> jax.Array:
    """Row-wise RMSNorm: x [R, D] -> [R, D]."""
    R, D = x.shape
    br = min(br, _rup(R, 8))
    Rp, Dp = _rup(R, br), _rup(D, 128)
    x = jnp.pad(x, ((0, Rp - R), (0, Dp - D)))
    g = jnp.pad(gamma, ((0, Dp - D),)).reshape(1, Dp)
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps, D),
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, Dp), lambda i: (i, 0)),
                  pl.BlockSpec((1, Dp), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, Dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, Dp), x.dtype),
        interpret=interpret,
    )(x, g)
    return out[:R, :D]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
