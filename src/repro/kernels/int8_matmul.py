"""Quantized matmul kernel — the paper's fixed-point path (C6) on TPU.

int8 activations x int8 weights with int32 accumulation, per-output-
channel weight scales and a per-tensor activation scale applied at the
final write-back, inside the same Fig. 4 K-tiled grid as the float
kernel.  Halves the HBM weight traffic and doubles effective MXU
throughput relative to bf16 — the same motivation as the paper's
fixed-point quantization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QTensor, quantize_dynamic


def _int8_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        scale = sx_ref[0, 0] * sw_ref[...].astype(jnp.float32)  # [1, bn]
        o_ref[...] = (acc[...].astype(jnp.float32) * scale) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "out_dtype"))
def int8_matmul(qx_values: jax.Array, qx_scale: jax.Array,
                qw_values: jax.Array, qw_scale: jax.Array, *,
                bm: int = 512, bk: int = 512, bn: int = 512,
                interpret: bool = False, out_dtype=jnp.bfloat16) -> jax.Array:
    """[M,K]i8 @ [K,N]i8 -> [M,N] out_dtype, rescaled by sx * sw[n]."""
    M, K = qx_values.shape
    N = qw_values.shape[1]
    bm, bk, bn = min(bm, _rup(M, 8)), min(bk, _rup(K, 8)), min(bn, _rup(N, 8))
    Mp, Kp, Np = _rup(M, bm), _rup(K, bk), _rup(N, bn)
    x = jnp.pad(qx_values, ((0, Mp - M), (0, Kp - K)))
    w = jnp.pad(qw_values, ((0, Kp - K), (0, Np - N)))
    sw = jnp.pad(qw_scale.reshape(1, N), ((0, 0), (0, Np - N)))
    sx = qx_scale.reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _int8_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                  pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
                  pl.BlockSpec((1, bn), lambda i, j, k: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w, sx, sw)
    return out[:M, :N]


def quantized_dense(x: jax.Array, qw: QTensor, *, interpret: bool = False,
                    **blocks) -> jax.Array:
    """Dynamic-quant serving dense: float x -> int8 -> kernel -> x.dtype."""
    qx = quantize_dynamic(x)
    return int8_matmul(qx.values, qx.scale, qw.values, qw.scale,
                       interpret=interpret, out_dtype=x.dtype, **blocks)


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
