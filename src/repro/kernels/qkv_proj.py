"""QKV_PM as a Pallas kernel: fused Q/K/V projection (paper §3.6.1).

Algorithm 9 computes Q, K and V in the *same* pipelined loop so the input
tile x[i][j] is read from BRAM once and feeds three MAC chains.  The TPU
version does the same: each grid step loads one (bm x bk) X block into
VMEM once and contracts it against the Q, K and V weight blocks, keeping
three f32 accumulators resident.  GQA is handled by masking the writes of
the K/V outputs to their narrower head range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qkv_kernel(nkv_blocks: int, x_ref, wq_ref, wk_ref, wv_ref,
                q_ref, k_ref, v_ref, acc_q, acc_k, acc_v):
    j = pl.program_id(1)
    kk = pl.program_id(2)
    last = kk == pl.num_programs(2) - 1

    @pl.when(kk == 0)
    def _init():
        acc_q[...] = jnp.zeros_like(acc_q)
        acc_k[...] = jnp.zeros_like(acc_k)
        acc_v[...] = jnp.zeros_like(acc_v)

    x = x_ref[...]  # one VMEM load feeds all three MAC chains (Alg. 9)
    acc_q[...] += jnp.dot(x, wq_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j < nkv_blocks)
    def _kv():
        acc_k[...] += jnp.dot(x, wk_ref[...],
                              preferred_element_type=jnp.float32)
        acc_v[...] += jnp.dot(x, wv_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        q_ref[...] = acc_q[...].astype(q_ref.dtype)

    @pl.when(last & (j < nkv_blocks))
    def _flush_kv():
        k_ref[...] = acc_k[...].astype(k_ref.dtype)
        v_ref[...] = acc_v[...].astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def qkv_proj(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array, *,
             bm: int = 512, bk: int = 512, bn: int = 256,
             interpret: bool = False
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: [M, D]; wq: [D, Nq]; wk/wv: [D, Nkv] (Nkv <= Nq, GQA).

    Returns (q [M, Nq], k [M, Nkv], v [M, Nkv]).
    """
    M, D = x.shape
    Nq, Nkv = wq.shape[1], wk.shape[1]
    assert wv.shape[1] == Nkv and wk.shape[0] == D and wv.shape[0] == D
    bm, bk = min(bm, _rup(M, 8)), min(bk, _rup(D, 8))
    bn = min(bn, _rup(min(Nq, Nkv), 8))
    Mp, Dp = _rup(M, bm), _rup(D, bk)
    Nqp, Nkvp = _rup(Nq, bn), _rup(Nkv, bn)
    x = jnp.pad(x, ((0, Mp - M), (0, Dp - D)))
    wq = jnp.pad(wq, ((0, Dp - D), (0, Nqp - Nq)))
    wk = jnp.pad(wk, ((0, Dp - D), (0, Nkvp - Nkv)))
    wv = jnp.pad(wv, ((0, Dp - D), (0, Nkvp - Nkv)))
    nkv_blocks = Nkvp // bn
    kv_map = lambda i, j, k: (k, jnp.minimum(j, nkv_blocks - 1))
    kv_out_map = lambda i, j, k: (i, jnp.minimum(j, nkv_blocks - 1))
    q, k, v = pl.pallas_call(
        functools.partial(_qkv_kernel, nkv_blocks),
        grid=(Mp // bm, Nqp // bn, Dp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                  pl.BlockSpec((bk, bn), kv_map),
                  pl.BlockSpec((bk, bn), kv_map)],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bm, bn), kv_out_map),
                   pl.BlockSpec((bm, bn), kv_out_map)],
        out_shape=[jax.ShapeDtypeStruct((Mp, Nqp), x.dtype),
                   jax.ShapeDtypeStruct((Mp, Nkvp), x.dtype),
                   jax.ShapeDtypeStruct((Mp, Nkvp), x.dtype)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32) for _ in range(3)],
        interpret=interpret,
    )(x, wq, wk, wv)
    return q[:M, :Nq], k[:M, :Nkv], v[:M, :Nkv]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
