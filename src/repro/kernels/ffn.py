"""FFN_PM + bias-add + activation as one Pallas kernel (paper §3.7/3.8).

The paper keeps FFN1_PM, the bias unit and the ReLU unit as separate RTL
modules chained through BRAMs.  The TPU adaptation fuses them: the f32
accumulator already sits in VMEM when the K loop finishes, so bias and
activation are applied in-register before the single write-back —
removing one full HBM round trip of the [M, d_ff] intermediate.  The
gated variant (SwiGLU/GeGLU) keeps two accumulators and fuses the gate
product too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind in ("silu", "swiglu"):
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def _ffn_kernel(activation: str, x_ref, w1_ref, b1_ref, o_ref, acc):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(x_ref[...], w1_ref[...],
                        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        y = acc[...] + b1_ref[...].astype(jnp.float32)
        o_ref[...] = _act(y, activation).astype(o_ref.dtype)


def _gated_kernel(activation: str, x_ref, w1_ref, wg_ref, o_ref, acc1, accg):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        accg[...] = jnp.zeros_like(accg)

    x = x_ref[...]
    acc1[...] += jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    accg[...] += jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (_act(accg[...], activation) * acc1[...]) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bk", "bn",
                                             "interpret"))
def ffn1(x: jax.Array, w1: jax.Array, b1: jax.Array, *,
         activation: str = "relu", bm: int = 512, bk: int = 512,
         bn: int = 512, interpret: bool = False) -> jax.Array:
    """act(x @ w1 + b1): [M, D] @ [D, F] -> [M, F]."""
    M, D = x.shape
    F = w1.shape[1]
    bm, bk, bn = min(bm, _rup(M, 8)), min(bk, _rup(D, 8)), min(bn, _rup(F, 8))
    Mp, Dp, Fp = _rup(M, bm), _rup(D, bk), _rup(F, bn)
    x = jnp.pad(x, ((0, Mp - M), (0, Dp - D)))
    w1 = jnp.pad(w1, ((0, Dp - D), (0, Fp - F)))
    b1 = jnp.pad(b1, ((0, Fp - F),)).reshape(1, Fp)
    out = pl.pallas_call(
        functools.partial(_ffn_kernel, activation),
        grid=(Mp // bm, Fp // bn, Dp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                  pl.BlockSpec((1, bn), lambda i, j, k: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w1, b1)
    return out[:M, :F]


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bk", "bn",
                                             "interpret"))
def ffn1_gated(x: jax.Array, w1: jax.Array, wg: jax.Array, *,
               activation: str = "swiglu", bm: int = 512, bk: int = 512,
               bn: int = 512, interpret: bool = False) -> jax.Array:
    """act(x @ wg) * (x @ w1): the SwiGLU/GeGLU first half."""
    M, D = x.shape
    F = w1.shape[1]
    bm, bk, bn = min(bm, _rup(M, 8)), min(bk, _rup(D, 8)), min(bn, _rup(F, 8))
    Mp, Dp, Fp = _rup(M, bm), _rup(D, bk), _rup(F, bn)
    x = jnp.pad(x, ((0, Mp - M), (0, Dp - D)))
    w1 = jnp.pad(w1, ((0, Dp - D), (0, Fp - F)))
    wg = jnp.pad(wg, ((0, Dp - D), (0, Fp - F)))
    out = pl.pallas_call(
        functools.partial(_gated_kernel, activation),
        grid=(Mp // bm, Fp // bn, Dp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w1, wg)
    return out[:M, :F]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
