"""Fig. 4 as a Pallas TPU kernel: K-tiled accumulating matmul.

The paper streams weight tiles DRAM->BRAM and accumulates partial products
across tiles ("the final output is the cumulative sum of the outputs
computed for all tiles").  Here each grid step streams one (bm x bk) A
block and one (bk x bn) B block HBM->VMEM, multiplies on the MXU, and
accumulates into a VMEM-resident f32 scratch; the output block is written
back once, on the last K step — the exact Fig. 4 discipline with VMEM in
the BRAM role and the K grid dimension in the tile-iteration role.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(a_ref[...], b_ref[...],
                        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "out_dtype"))
def tiled_matmul(a: jax.Array, b: jax.Array, *, bm: int = 512, bk: int = 512,
                 bn: int = 512, interpret: bool = False,
                 out_dtype=None) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with (bm, bk, bn) VMEM blocks.

    Dims need not divide the blocks; inputs are zero-padded and the output
    sliced (the paper pads the last tile the same way).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bk, bn = min(bm, _rup(M, 8)), min(bk, _rup(K, 8)), min(bn, _rup(N, 8))
    Mp, Kp, Np = _rup(M, bm), _rup(K, bk), _rup(N, bn)
    if (Mp, Kp) != (M, K):
        a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
