"""Pure-jnp oracles for every Pallas kernel (the C-simulation analogue).

Each ``*_ref`` computes the same mathematical function as its kernel with
plain jnp ops; the kernel test suite sweeps shapes/dtypes and asserts
allclose between kernel (interpret mode) and oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def tiled_matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a.astype(jnp.float32),
                      b.astype(jnp.float32)).astype(out_dtype)


def qkv_proj_ref(x, wq, wk, wv):
    f = lambda w: jnp.matmul(x.astype(jnp.float32),
                             w.astype(jnp.float32)).astype(x.dtype)
    return f(wq), f(wk), f(wv)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: [BH, Sq, hd]; k/v: [BH, Skv, hd]."""
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)) \
        .astype(q.dtype)


def _act(x, kind):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind in ("gelu", "geglu"):
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def ffn1_ref(x, w1, b1, activation="relu"):
    y = jnp.matmul(x.astype(jnp.float32), w1.astype(jnp.float32)) \
        + b1.astype(jnp.float32)
    return _act(y, activation).astype(x.dtype)


def ffn1_gated_ref(x, w1, wg, activation="swiglu"):
    y1 = jnp.matmul(x.astype(jnp.float32), w1.astype(jnp.float32))
    yg = jnp.matmul(x.astype(jnp.float32), wg.astype(jnp.float32))
    return (_act(yg, activation) * y1).astype(x.dtype)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)) \
        .astype(x.dtype)


def rmsnorm_ref(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)) \
        .astype(x.dtype)


def int8_matmul_ref(qx_values, qx_scale, qw_values, qw_scale,
                    out_dtype=jnp.bfloat16):
    acc = jnp.matmul(qx_values.astype(jnp.int32), qw_values.astype(jnp.int32))
    out = acc.astype(jnp.float32) * qx_scale * qw_scale.reshape(1, -1)
    return out.astype(out_dtype)
