"""QK_PM + softmax + SV_PM fused — flash attention as the TPU-native
composition of the paper's attention pipeline (§3.6.2-3.6.3).

The FPGA stores the full S = QK^T score matrix in BRAM between the QK_PM
and SV_PM modules; at 32k context that matrix alone would be 4 GiB.  The
TPU adaptation keeps the *paper's fusion insight* (scores never leave
on-chip memory) but replaces the materialized S with an online softmax:
each grid step loads one KV block, updates a running (max, sum, weighted
accumulator) triple held in VMEM scratch, and only the final O block is
written to HBM.  This is exactly the ADAPTOR tiling discipline applied to
the score matrix instead of the weight matrix.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(scale: float, causal: bool, kv_len: int, bq: int, bkv: int,
                  q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0]                       # [bq, hd]
    k = k_ref[0]                       # [bkv, hd]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < kv_len               # padded KV tail never contributes
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]                  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)             # [bq, bkv]
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_s[...] = m_new
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0] = (acc[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 512, bkv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, hd]; k/v: [BH, Skv, hd] -> [BH, Sq, hd].

    KV heads must already be repeated to the query head count (the GQA
    grouping happens at the wrapper level, as in ``models.attention``).
    """
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, _rup(Sq, 8))
    bkv = min(bkv, _rup(Skv, 8))
    Sqp, Skvp = _rup(Sq, bq), _rup(Skv, bkv)
    hdp = _rup(hd, 128)
    q = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, hdp - hd)))
    k = jnp.pad(k, ((0, 0), (0, Skvp - Skv), (0, hdp - hd)))
    v = jnp.pad(v, ((0, 0), (0, Skvp - Skv), (0, hdp - hd)))
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale, causal, Skv, bq, bkv),
        grid=(BH, Sqp // bq, Skvp // bkv),
        in_specs=[pl.BlockSpec((1, bq, hdp), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, bkv, hdp), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, bkv, hdp), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, bq, hdp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, hdp), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hdp), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq, :hd]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
