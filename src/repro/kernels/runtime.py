"""Runtime platform probes shared by the Pallas kernel call sites.

``interpret_default()`` answers "must Pallas kernels run in interpret
mode here?" exactly once per process: every fused serving step used to
re-evaluate ``jax.default_backend() != "tpu"`` at call time (a dict
lookup plus backend initialization check inside the hot dispatch path);
the engine and fabric now read one cached value computed at
construction.
"""
from __future__ import annotations

import functools

import jax


@functools.lru_cache(maxsize=None)
def interpret_default() -> bool:
    """True when Pallas TPU kernels need interpret mode (non-TPU hosts)."""
    return jax.default_backend() != "tpu"
