"""Chunked-prefill attention: block-table-aware chunk attention with
causal intra-chunk masking, fused over the paged KV pool.

``paged_attention.paged_decode_attention`` attends ONE query token per
sequence to its scattered pool blocks.  Chunked prefill generalizes the
query side: each slot advances by up to W consecutive *lanes* per fused
step (a prompt chunk, or a single decode token in lane 0), every lane
``l`` sitting at cache position ``start[b] + l``.  The chunk's K/V are
scattered into the pool *before* this kernel runs, so one mask covers
both halves of chunked attention: lane ``l`` sees pool positions
``<= start[b] + l`` — the prior cache plus the causal prefix of its own
chunk.

Grid: (seq, kv_head, lane, block).  Each program attends one lane's
query group (the n_rep query heads sharing a KV head) to one pool block,
accumulating the running (max, sum, acc) triple in VMEM scratch exactly
as in ``paged_attention``; the block table is scalar-prefetched and the
KV BlockSpec index map reads ``table[seq, j]``, so the non-contiguous
pool walk costs no gather in HBM.  Dead lanes (>= the slot's live count)
compute a finite garbage row that the caller drops — the idle-PE
discipline.

int8 KV cache: per-(block entry, kv-head) scales stream in beside the
int8 tiles through the same block-table index map and the dequant fuses
into the dots (see ``paged_attention`` for the layout).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _chunk_kernel(scale: float, bs: int, masked_heads: bool,
                  quantized: bool, *refs):
    refs = list(refs)
    bt_ref, start_ref = refs.pop(0), refs.pop(0)
    live_ref = refs.pop(0) if masked_heads else None
    q_ref, k_ref, v_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    ks_ref = refs.pop(0) if quantized else None
    vs_ref = refs.pop(0) if quantized else None
    o_ref, acc, m_s, l_s = refs
    b = pl.program_id(0)
    g = pl.program_id(1)
    lane = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0, 0]                 # [R, hdp]  (one lane's query group)
    k = k_ref[0, 0]                    # [bs, hdp] (one pool block)
    v = v_ref[0, 0]
    if quantized:
        # dequant fused at the tile: one scale per block entry (row)
        q = q.astype(jnp.float32)
        k = k.astype(jnp.float32) * ks_ref[0, 0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0, 0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # chunk K/V are already in the pool, so the single causal-vs-cache
    # mask is: column position (logical block j * bs + offset) <= the
    # lane's own cache position start[b] + lane
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos <= start_ref[b] + lane, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_s[...] = m_new
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(3) - 1)
    def _flush():
        l = jnp.maximum(l_s[...], 1e-30)
        out = acc[...] / l
        if live_ref is not None:
            # multi-topology serving: KV-head groups >= this sequence's
            # live head count are padded fabric lanes — force the
            # idle-PE contract (exact zeros)
            out = jnp.where(g < live_ref[b], out, 0.0)
        o_ref[0, 0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def chunked_prefill_attention(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, block_tables: jax.Array,
                              start: jax.Array, *,
                              live_kv: jax.Array | None = None,
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None,
                              scale: float | None = None,
                              interpret: bool = False) -> jax.Array:
    """W-lane chunk/decode attention over the pooled KV cache.

    q:            [B, W, h, hd]     W query lanes per sequence; lane l
                                    sits at cache position start[b] + l
    k/v_pool:     [NB, bs, kv, hd]  the shared block pool (row 0 = null)
    block_tables: [B, nblk] int32   physical block of each logical block
    start:        [B] int32         first lane's cache position per slot
    live_kv:      [B] int32 or None live KV-head groups per sequence
                                    (multi-topology head-lane masking)
    k/v_scale:    [NB, bs, kv] f32 or None — the int8 cache codec's
                  per-(block entry, kv-head) scales; when given, pool
                  values are int8 and the dequant fuses into the kernel
    -> [B, W, h, hd]

    Softmax statistics accumulate in f32 VMEM scratch; numerics match
    ``flash_attention``, not bit-exactly the unfused XLA softmax.
    """
    B, W, h, hd = q.shape
    nb_pool, bs, kv, _ = k_pool.shape
    nblk = block_tables.shape[1]
    n_rep = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    R = _rup(max(n_rep, 8), 8)
    hdp = _rup(hd, 128)
    # query groups: head = kv_head * n_rep + rep (repeat_kv's ordering),
    # laid out kv-major so one program streams one lane's group
    qg = q.reshape(B, W, kv, n_rep, hd).transpose(0, 2, 1, 3, 4)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, R - n_rep),
                      (0, hdp - hd)))
    kp = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, hdp - hd))) \
        .swapaxes(1, 2)
    vp = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, hdp - hd))) \
        .swapaxes(1, 2)

    masked_heads = live_kv is not None
    quantized = k_scale is not None
    # index maps take one trailing arg per scalar-prefetch operand
    if masked_heads:
        q_map = lambda b, g, l, j, bt, st, lv: (b, g, l, 0, 0)
        kv_map = lambda b, g, l, j, bt, st, lv: (bt[b, j], g, 0, 0)
        sc_map = lambda b, g, l, j, bt, st, lv: (bt[b, j], g, 0)
        prefetch = (block_tables, start, live_kv)
    else:
        q_map = lambda b, g, l, j, bt, st: (b, g, l, 0, 0)
        kv_map = lambda b, g, l, j, bt, st: (bt[b, j], g, 0, 0)
        sc_map = lambda b, g, l, j, bt, st: (bt[b, j], g, 0)
        prefetch = (block_tables, start)
    in_specs = [
        pl.BlockSpec((1, 1, 1, R, hdp), q_map),
        pl.BlockSpec((1, 1, bs, hdp), kv_map),
        pl.BlockSpec((1, 1, bs, hdp), kv_map),
    ]
    operands = [qg, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, bs), sc_map),
                     pl.BlockSpec((1, 1, bs), sc_map)]
        operands += [k_scale.swapaxes(1, 2), v_scale.swapaxes(1, 2)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(B, kv, W, nblk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, R, hdp), q_map),
        scratch_shapes=[pltpu.VMEM((R, hdp), jnp.float32),
                        pltpu.VMEM((R, 1), jnp.float32),
                        pltpu.VMEM((R, 1), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_chunk_kernel, scale, bs, masked_heads, quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kv, W, R, hdp),
                                       jnp.float32 if quantized else q.dtype),
        interpret=interpret,
    )(*prefetch, *operands)
    return out[:, :, :, :n_rep, :hd].transpose(0, 2, 1, 3, 4) \
        .reshape(B, W, h, hd).astype(q.dtype)


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
