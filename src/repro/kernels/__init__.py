"""ADAPTOR processing modules as Pallas TPU kernels.

Paper module -> kernel map:
  QKV_PM (Alg. 9)            -> qkv_proj
  QK_PM + softmax + SV_PM    -> flash_attention (fused, online softmax)
  paged KV decode            -> paged_attention (block-table gather fused
                                into the flash-decode grid)
  FFN1/2/3_PM + bias + act   -> ffn (ffn1 / ffn1_gated) + tiled_matmul
  LN unit (Alg. 8)           -> layernorm (layernorm / rmsnorm)
  Fig. 4 tiling discipline   -> tiled_matmul (K-tiled accumulation)
  fixed-point path (C6)      -> int8_matmul

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a pure-jnp oracle in
ref.py, and a jit'd wrapper in ops.py with planner-chosen block shapes.
Validated with interpret=True on CPU; TPU is the deployment target.
"""
