"""Paged flash-decode attention: block-table gather fused into the
online-softmax loop.

The ``flash_attention`` kernel tiles a *contiguous* KV sequence; serving
with a paged cache makes the sequence non-contiguous — a slot's tokens
live in scattered pool blocks addressed by its block table.  The XLA
reference path (``models.attention.gqa_decode_paged`` impl="gather")
first materializes the contiguous view in HBM and then attends; this
kernel removes that copy by letting the *grid itself* walk the block
table: the tables are scalar-prefetched (SMEM), and the KV BlockSpec
index map reads ``table[seq, j]`` to DMA pool block ``j`` of each
sequence straight into VMEM — the ADAPTOR discipline of computing
addresses in registers while tiles stream through on-chip memory.

Grid: (seq, kv_head, block).  Each program attends one sequence's query
group (the n_rep query heads sharing a KV head) to one token block,
accumulating the running (max, sum, acc) triple in VMEM scratch exactly
as in ``flash_attention``; entries past the slot's live length — and
whole blocks whose table entry is the null block — are masked to -inf,
so they contribute exactly zero.

int8 KV cache (``MemorySpec.kv_dtype="int8"``): the per-(block entry,
kv-head) scales ride the *same* block-table index map as the values —
one f32 scale row per pool block per head streams into VMEM beside its
int8 tile and the dequant multiply fuses into the score/value dots, so
the quantized pool never takes a round trip through HBM at float width.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _paged_kernel(scale: float, bs: int, masked_heads: bool,
                  quantized: bool, *refs):
    refs = list(refs)
    bt_ref, len_ref = refs.pop(0), refs.pop(0)
    live_ref = refs.pop(0) if masked_heads else None
    q_ref, k_ref, v_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    ks_ref = refs.pop(0) if quantized else None
    vs_ref = refs.pop(0) if quantized else None
    o_ref, acc, m_s, l_s = refs
    b = pl.program_id(0)
    g = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0]                    # [R, hdp]  (query group)
    k = k_ref[0, 0]                    # [bs, hdp] (one pool block)
    v = v_ref[0, 0]
    if quantized:
        # dequant fused at the tile: one scale per block entry (row)
        q = q.astype(jnp.float32)
        k = k.astype(jnp.float32) * ks_ref[0, 0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0, 0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # token position of each column = logical block j * bs + offset; the
    # block table already routed us to the right *physical* block, so
    # only the live-length mask remains (null-block columns are >= len)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_s[...] = m_new
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_s[...], 1e-30)
        out = acc[...] / l
        if live_ref is not None:
            # multi-topology serving: KV-head groups >= this sequence's
            # live head count are padded fabric lanes — their q/k/v may
            # hold garbage, so force the idle-PE contract (exact zeros)
            out = jnp.where(g < live_ref[b], out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           live_kv: jax.Array | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """One-token decode attention over the pooled KV cache.

    q:            [B, h, hd]        one query token per sequence
    k/v_pool:     [NB, bs, kv, hd]  the shared block pool (row 0 = null)
    block_tables: [B, nblk] int32   physical block of each logical block
    lengths:      [B] int32         live positions per sequence (index+1)
    live_kv:      [B] int32 or None live KV-head groups per sequence —
                  multi-topology serving pads the head axis to the fabric
                  maxima, and groups past a slot's live count are masked
                  to exact zeros (idle PE lanes)
    k/v_scale:    [NB, bs, kv] f32 or None — the int8 cache codec's
                  per-(block entry, kv-head) scales; when given, pool
                  values are int8 and the dequant fuses into the kernel,
                  the scales walking the same block-table index map
    -> [B, h, hd]

    Softmax statistics accumulate in f32 VMEM scratch; numerics match
    ``flash_attention``, not bit-exactly the unfused XLA softmax.
    """
    B, h, hd = q.shape
    nb_pool, bs, kv, _ = k_pool.shape
    nblk = block_tables.shape[1]
    n_rep = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    R = _rup(max(n_rep, 8), 8)
    hdp = _rup(hd, 128)
    # query groups: head = kv_head * n_rep + rep (repeat_kv's ordering)
    qg = q.reshape(B, kv, n_rep, hd)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, R - n_rep), (0, hdp - hd)))
    # kv-major pool view [NB, kv, bs, hdp]: the (bs, hdp) block trailing
    # dims are lane/sublane aligned.  On TPU a production pool would be
    # stored in this layout outright; the interpret-mode validation pays
    # the transpose here.
    kp = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, hdp - hd))) \
        .swapaxes(1, 2)
    vp = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, hdp - hd))) \
        .swapaxes(1, 2)

    masked_heads = live_kv is not None
    quantized = k_scale is not None
    # index maps take one trailing arg per scalar-prefetch operand
    if masked_heads:
        q_map = lambda b, g, j, bt, ln, lv: (b, g, 0, 0)
        kv_map = lambda b, g, j, bt, ln, lv: (bt[b, j], g, 0, 0)
        sc_map = lambda b, g, j, bt, ln, lv: (bt[b, j], g, 0)
        prefetch = (block_tables, lengths, live_kv)
    else:
        q_map = lambda b, g, j, bt, ln: (b, g, 0, 0)
        kv_map = lambda b, g, j, bt, ln: (bt[b, j], g, 0, 0)
        sc_map = lambda b, g, j, bt, ln: (bt[b, j], g, 0)
        prefetch = (block_tables, lengths)
    in_specs = [
        pl.BlockSpec((1, 1, R, hdp), q_map),
        pl.BlockSpec((1, 1, bs, hdp), kv_map),
        pl.BlockSpec((1, 1, bs, hdp), kv_map),
    ]
    operands = [qg, kp, vp]
    if quantized:
        # scales in the same kv-major layout as the pool; the BlockSpec
        # rides the identical scalar-prefetched table walk
        in_specs += [pl.BlockSpec((1, 1, bs), sc_map),
                     pl.BlockSpec((1, 1, bs), sc_map)]
        operands += [k_scale.swapaxes(1, 2), v_scale.swapaxes(1, 2)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(B, kv, nblk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, R, hdp), q_map),
        scratch_shapes=[pltpu.VMEM((R, hdp), jnp.float32),
                        pltpu.VMEM((R, 1), jnp.float32),
                        pltpu.VMEM((R, 1), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale, bs, masked_heads, quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kv, R, hdp),
                                       jnp.float32 if quantized else q.dtype),
        interpret=interpret,
    )(*prefetch, *operands)
    return out[:, :, :n_rep, :hd].reshape(B, h, hd).astype(q.dtype)


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
