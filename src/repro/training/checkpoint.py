"""Checkpointing: atomic, resumable, async — the restart half of fault
tolerance.

Layout (one directory per step):
    <dir>/step_000123/
        arrays.npz          flattened state leaves, keyed by tree path
        meta.json           step, data-pipeline state, config fingerprint
        COMMITTED           written last; partial checkpoints are invisible

Writes go to ``step_X.tmp`` and are renamed only after COMMITTED exists,
so a host failure mid-save can never corrupt the restore path.  ``save``
optionally detaches to a background thread after the device->host copy
(async checkpointing: the train loop continues while the npz is written).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(path): np.asarray(leaf) for path, leaf in flat}


def save(directory: str, step: int, state: Any, *,
         meta: dict | None = None, keep: int = 3,
         async_write: bool = False) -> threading.Thread | None:
    """Write one checkpoint.  Returns the writer thread if async."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    # unique tmp per call: an async writer and a later sync writer of the
    # same step must never collide (the rename stays atomic either way)
    tmp = final + f".tmp{os.getpid()}_{threading.get_ident()}_{time.time_ns()}"
    # device -> host copy happens here, synchronously (consistent snapshot)
    arrays = _flatten(jax.tree.map(lambda x: jax.device_get(x), state))

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        try:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except OSError:
            # a concurrent writer of the same step won the rename; the
            # committed content is identical — drop our copy
            if os.path.exists(os.path.join(final, "COMMITTED")):
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                raise
        _gc(directory, keep)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "COMMITTED")):
                s = int(d.split("_")[1])
                best = s if best is None else max(best, s)
    return best


def restore(directory: str, step: int, like: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (abstract or concrete tree).
    With ``shardings`` given, leaves are placed sharded (elastic restart
    onto a different mesh re-shards here)."""
    d = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat[0]:
        arr = data[_path_str(path)]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint/param shape mismatch at "
                             f"{_path_str(path)}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, sh: jax.device_put(x, sh), tree, shardings)
    return tree, meta


def restore_latest(directory: str, like: Any,
                   shardings: Any | None = None) -> tuple[Any, dict] | None:
    step = latest_step(directory)
    if step is None:
        return None
    return restore(directory, step, like, shardings)
