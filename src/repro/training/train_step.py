"""Sharded train step: loss -> grads -> AdamW, compiled once per mesh.

The ADAPTOR discipline at training scale: ``make_train_step`` is the
"synthesis" (jit against one mesh + sharding strategy + maxima shapes);
step-to-step variation (learning rate, data) flows through traced inputs.

Features: mixed precision (f32 master params, bf16 compute inside the
model), per-layer remat, microbatch gradient accumulation (lax.scan),
donated state buffers, and optional int8 error-feedback gradient
compression on the DP axis (shard_map variant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.jitutil import strict_jit
from repro.distributed import sharding as shd
from repro.models.model import Model
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    accum_steps: int = 1          # microbatch gradient accumulation
    donate: bool = True


def init_state(model: Model, rng: jax.Array,
               opt_cfg: AdamWConfig) -> TrainState:
    params = model.init(rng)
    return TrainState(params, adamw_init(params, opt_cfg))


def abstract_state(model: Model, opt_cfg: AdamWConfig) -> TrainState:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    params = model.abstract()
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, opt_cfg.moment_dtype)
    return TrainState(params, AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(zeros, params), v=jax.tree.map(zeros, params)))


def state_shardings(model: Model, mesh: Mesh,
                    strategy: shd.ShardingStrategy) -> TrainState:
    axes = model.axes()
    abstract = model.abstract()
    p_sh = shd.tree_param_shardings(mesh, axes, abstract, strategy)
    # moments shard exactly like their parameter (ZeRO under fsdp)
    return TrainState(p_sh, AdamWState(
        step=shd.replicated(mesh), m=p_sh, v=p_sh))


def batch_shardings(mesh: Mesh, strategy: shd.ShardingStrategy,
                    batch_abstract: dict) -> dict:
    return {k: shd.batch_sharding(mesh, strategy, ndim=v.ndim)
            for k, v in batch_abstract.items()}


def loss_and_grads(model: Model, params, batch):
    def lf(p):
        loss, aux = model.loss(p, batch)
        return loss, aux

    (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, aux, grads


def make_step_fn(model: Model, cfg: TrainStepConfig):
    """The pure step function (pre-jit): (state, batch) -> (state, metrics).

    With ``accum_steps > 1`` the batch's leading dim is split into
    microbatches and gradients are averaged via a scan — each microbatch's
    backward overlaps the next one's forward in the XLA schedule.
    """

    def step(state: TrainState, batch: dict):
        if cfg.accum_steps > 1:
            def micro(acc, mb):
                loss, aux, grads = loss_and_grads(model, state.params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(lambda g: g / cfg.accum_steps,
                                                grads))
                return acc, loss

            micro_batches = jax.tree.map(
                lambda x: x.reshape((cfg.accum_steps,
                                     x.shape[0] // cfg.accum_steps)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, losses = jax.lax.scan(micro, zero, micro_batches)
            loss = jnp.mean(losses)
            aux = {"xent": loss}
        else:
            loss, aux, grads = loss_and_grads(model, state.params, batch)
        params, opt, om = adamw_update(state.params, grads, state.opt,
                                       cfg.optimizer)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **om}
        return TrainState(params, opt), metrics

    return step


def make_train_step(model: Model, mesh: Mesh,
                    strategy: shd.ShardingStrategy,
                    cfg: TrainStepConfig,
                    batch_abstract: dict):
    """jit-compiled sharded train step + its sharding pytrees.

    Returns (jitted_step, state_shardings, batch_shardings).
    """
    st_sh = state_shardings(model, mesh, strategy)
    b_sh = batch_shardings(mesh, strategy, batch_abstract)
    raw = make_step_fn(model, cfg)

    def wrapped(state, batch):
        with shd.active(mesh, strategy):
            return raw(state, batch)

    # strict_jit: a donated TrainState that XLA cannot alias (a dtype or
    # sharding drift between state in and state out) raises under
    # REPRO_STRICT=1 instead of doubling optimizer-state memory silently
    jitted = strict_jit(
        wrapped,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, NamedSharding(mesh, P())),
        donate_argnums=(0,) if cfg.donate else (),
    )
    return jitted, st_sh, b_sh
