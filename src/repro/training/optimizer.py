"""AdamW + schedules, built from scratch (no optax in this environment).

Optimizer state lives in the same tree structure as the params, so the
sharding rules that partition a parameter partition its moments
identically — with ``fsdp`` enabled this is ZeRO-style optimizer-state
sharding for free.  ``moment_dtype=bfloat16`` halves optimizer memory for
the very largest configs (the 671B note in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array   # int32 scalar
    m: Any            # first-moment tree
    v: Any            # second-moment tree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def cosine_lr(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), norm


def _is_decayed(path: tuple) -> bool:
    """Weight decay applies to matrices, not to norms/biases (standard)."""
    last = str(path[-1]) if path else ""
    return not any(t in last for t in ("bias", "scale", "ln", "_g", "_b",
                                       "b1", "b2", "bq", "bk", "bv", "bo",
                                       "conv_b", "gate_in_b", "gate_a_b",
                                       "a_param", "d_skip"))


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig
                 ) -> tuple[Any, AdamWState, dict]:
    """One AdamW step.  Returns (params', state', metrics)."""
    grads, grad_norm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_lr(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)

    new_p, new_m, new_v = [], [], []
    for path, p, g, m, v in zip(paths, flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        upd = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if cfg.weight_decay and _is_decayed(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m32.astype(cfg.moment_dtype))
        new_v.append(v32.astype(cfg.moment_dtype))

    metrics = {"lr": lr, "grad_norm": grad_norm}
    return (jax.tree.unflatten(tdef, new_p),
            AdamWState(step, jax.tree.unflatten(tdef, new_m),
                       jax.tree.unflatten(tdef, new_v)),
            metrics)
