"""Fault tolerance & elasticity: heartbeats, stragglers, re-mesh plans.

At 1000+ nodes the failure model is: hosts stop heartbeating (hard fail),
or keep heartbeating but fall behind (straggler).  The controller below is
deterministic and host-agnostic so the whole policy is unit-testable in
this single-process container; on a real cluster the inputs come from the
coordination service and the output plan drives ``jax.distributed``
re-initialization + ``checkpoint.restore(..., shardings=new)``.

Policy:
* hard failure  -> shrink the data axis to the largest feasible size,
  restore the latest committed checkpoint onto the new mesh (elastic
  downscale); model-axis loss is fatal for TP-sharded weights, so model
  columns are only ever removed in whole data-slices.
* straggler     -> first mitigate in-band (the step itself is synchronous,
  so one slow host gates the step): re-assign its data shard and mark it
  for eviction at the next checkpoint boundary.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


@dataclass
class HostState:
    host_id: int
    last_heartbeat: float = 0.0
    step_times: list[float] = field(default_factory=list)
    evicted: bool = False


@dataclass(frozen=True)
class ReMeshPlan:
    """What the controller decides after failures: the new mesh and the
    restart point."""

    old_mesh: tuple[int, ...]
    new_mesh: tuple[int, ...]
    axis_names: tuple[str, ...]
    restore_step: int
    dropped_hosts: tuple[int, ...]
    reason: str

    @property
    def new_device_count(self) -> int:
        return math.prod(self.new_mesh)


class ClusterMonitor:
    """Heartbeat + straggler tracking over deterministic, injected time."""

    def __init__(self, n_hosts: int, *, heartbeat_timeout: float = 60.0,
                 straggler_factor: float = 2.0, min_samples: int = 5):
        self.hosts = {i: HostState(i) for i in range(n_hosts)}
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.min_samples = min_samples

    def heartbeat(self, host_id: int, now: float) -> None:
        self.hosts[host_id].last_heartbeat = now

    def record_step(self, host_id: int, seconds: float) -> None:
        h = self.hosts[host_id]
        h.step_times.append(seconds)
        if len(h.step_times) > 50:
            h.step_times.pop(0)

    def dead_hosts(self, now: float) -> list[int]:
        return [i for i, h in self.hosts.items()
                if not h.evicted
                and now - h.last_heartbeat > self.heartbeat_timeout]

    def stragglers(self) -> list[int]:
        """Hosts whose median step time exceeds factor x cluster median."""
        med = {}
        for i, h in self.hosts.items():
            if h.evicted or len(h.step_times) < self.min_samples:
                continue
            ts = sorted(h.step_times)
            med[i] = ts[len(ts) // 2]
        if len(med) < 2:
            return []
        cluster = sorted(med.values())[len(med) // 2]
        return [i for i, m in med.items()
                if m > self.straggler_factor * cluster]

    def evict(self, host_id: int) -> None:
        self.hosts[host_id].evicted = True

    @property
    def live_count(self) -> int:
        return sum(1 for h in self.hosts.values() if not h.evicted)


def plan_remesh(mesh_shape: tuple[int, ...], axis_names: tuple[str, ...],
                devices_per_host: int, failed_hosts: list[int],
                last_checkpoint_step: int, *, data_axes: tuple[str, ...] =
                ("pod", "data"), reason: str = "host failure") -> ReMeshPlan:
    """Shrink data-parallel axes to fit the surviving device count.

    TP ('model') extent is preserved — model-sharded weights cannot lose
    columns.  The data extent is rounded down to the largest value such
    that the new mesh fits the surviving devices.
    """
    total = math.prod(mesh_shape)
    survivors = total - devices_per_host * len(failed_hosts)
    sizes = dict(zip(axis_names, mesh_shape))
    model = sizes.get("model", 1)
    fixed = model
    budget = survivors // fixed
    if budget < 1:
        raise RuntimeError("not enough survivors to keep the model axis; "
                           "full restart required")
    # greedily shrink the innermost data axis first, dropping 'pod' last
    new_sizes = dict(sizes)
    names_in_order = [a for a in axis_names if a in data_axes]
    while math.prod(new_sizes[a] for a in names_in_order) > budget:
        for a in reversed(names_in_order):
            if new_sizes[a] > 1:
                new_sizes[a] -= 1
                break
        else:
            break
    new_mesh = tuple(new_sizes[a] for a in axis_names)
    return ReMeshPlan(old_mesh=mesh_shape, new_mesh=new_mesh,
                      axis_names=axis_names,
                      restore_step=last_checkpoint_step,
                      dropped_hosts=tuple(failed_hosts), reason=reason)


@dataclasses.dataclass
class TrainController:
    """Glue: monitor -> plan -> (restore + recompile) decisions."""

    monitor: ClusterMonitor
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    devices_per_host: int
    last_checkpoint_step: int = 0

    def on_checkpoint(self, step: int) -> None:
        self.last_checkpoint_step = step

    def poll(self, now: float) -> ReMeshPlan | None:
        dead = self.monitor.dead_hosts(now)
        if dead:
            for h in dead:
                self.monitor.evict(h)
            return plan_remesh(self.mesh_shape, self.axis_names,
                               self.devices_per_host, dead,
                               self.last_checkpoint_step)
        slow = self.monitor.stragglers()
        if slow:
            for h in slow:
                self.monitor.evict(h)
            return plan_remesh(self.mesh_shape, self.axis_names,
                               self.devices_per_host, slow,
                               self.last_checkpoint_step,
                               reason="straggler eviction")
        return None
