"""Mixture-of-experts FFN with capacity-bounded scatter dispatch.

Design notes (TPU adaptation of the paper's FFN_PM tiling):

* Dispatch is *gather/scatter based*, not the one-hot-einsum dispatch of
  the Mixtral reference — the einsum form costs O(T^2 k/E) matmul FLOPs,
  which would swamp the expert compute in the roofline.  Scatter costs
  zero MXU FLOPs; only the router and the expert matmuls hit the MXU, so
  HLO FLOPs track 6·N_active·D.
* Capacity is per sequence (`C = ceil(S*k/E * capacity_factor)`), so the
  batch dimension stays cleanly sharded over the data axis and the expert
  dimension over the model axis (expert parallelism).
* Tokens over capacity are dropped (standard capacity-factor semantics);
  the residual connection keeps them intact.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.layers import build_dense, apply_dense, is_gated


def capacity(seq_len: int, m: MoEConfig) -> int:
    c = math.ceil(seq_len * m.experts_per_token / m.num_experts
                  * m.capacity_factor)
    return min(max(c, min(seq_len, 4)), seq_len)


def build_ffn(b, cfg: ArchConfig, d_ff: int, use_bias: bool = False) -> dict:
    """Dense (non-expert) FFN params — the paper's FFN1/FFN2(/FFN3)."""
    d = cfg.d_model
    p = {"w1": build_dense(b, d, d_ff, ("embed", "ffn"), use_bias=use_bias)}
    if is_gated(cfg.activation):
        p["wg"] = build_dense(b, d, d_ff, ("embed", "ffn"), use_bias=use_bias)
    p["w2"] = build_dense(b, d_ff, d, ("ffn", "embed"), use_bias=use_bias)
    return p


def apply_ffn(x: jax.Array, p: dict, activation: str) -> jax.Array:
    h = apply_dense(x, p["w1"])
    if is_gated(activation):
        h = layers.activate(apply_dense(x, p["wg"]), activation) * h
    else:
        h = layers.activate(h, activation)
    h = constrain(h, ("batch",) + (None,) * (h.ndim - 2) + ("ffn",))
    return apply_dense(h, p["w2"])


def build_moe(b, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": b.param((d, m.num_experts), ("embed", "experts")),
        "w1": b.param((m.num_experts, d, m.expert_d_ff),
                      ("experts", "embed", "ffn")),
        "w2": b.param((m.num_experts, m.expert_d_ff, d),
                      ("experts", "ffn", "embed")),
    }
    if is_gated(cfg.activation):
        p["wg"] = b.param((m.num_experts, d, m.expert_d_ff),
                          ("experts", "embed", "ffn"))
    if m.num_shared_experts:
        p["shared"] = build_ffn(
            b, cfg, m.num_shared_experts * m.shared_expert_d_ff)
    return p


def route(x: jax.Array, router_w: jax.Array, m: MoEConfig
          ) -> tuple[jax.Array, jax.Array]:
    """Top-k routing.  Returns (weights [.., k], expert ids [.., k]).

    Softmax gating re-normalized over the selected k (Mixtral/granite
    style), scaled by ``router_scale`` (DeepSeek's routed_scaling_factor).
    """
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return (m.router_scale * top_p), top_i


def _dispatch_one(x, top_w, top_i, p, m: MoEConfig, activation: str, cap: int):
    """Per-sequence expert dispatch.  x: [S, d]; top_*: [S, k]."""
    s, d = x.shape
    k = m.experts_per_token
    flat_e = top_i.reshape(s * k)                        # expert of each slot
    flat_w = top_w.reshape(s * k)
    # position of each slot within its expert (order-preserving)
    onehot = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot            # [S*k, E]
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    dropped = flat_pos >= cap
    # scatter tokens into the [E, C, d] expert buffers ('drop' discards o.o.b.)
    src = jnp.repeat(x, k, axis=0)                       # [S*k, d] token copies
    e_idx = jnp.where(dropped, m.num_experts, flat_e)    # row E == trash
    buf = jnp.zeros((m.num_experts, cap, d), x.dtype)
    buf = buf.at[e_idx, jnp.minimum(flat_pos, cap - 1)].set(src, mode="drop")
    # expert FFNs, batched over E
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
        h = layers.activate(g, activation) * h
    else:
        h = layers.activate(h, activation)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    # gather back and combine with routing weights
    got = out_buf[e_idx.clip(0, m.num_experts - 1), jnp.minimum(flat_pos, cap - 1)]
    got = jnp.where(dropped[:, None], 0.0, got) * flat_w[:, None].astype(x.dtype)
    return got.reshape(s, k, d).sum(axis=1)


def apply_moe(x: jax.Array, p: dict, cfg: ArchConfig) -> jax.Array:
    """x: [B, S, d] -> [B, S, d].  Routed experts + optional shared expert."""
    m = cfg.moe
    b_, s, d = x.shape
    cap = capacity(s, m)
    top_w, top_i = route(x, p["router"], m)
    routed = jax.vmap(
        lambda xi, wi, ii: _dispatch_one(xi, wi, ii, p, m, cfg.activation, cap)
    )(x, top_w, top_i)
    routed = constrain(routed, ("batch", None, None))
    if "shared" in p:
        routed = routed + apply_ffn(x, p["shared"], cfg.activation)
    return routed


def load_balance_loss(x: jax.Array, router_w: jax.Array, m: MoEConfig) -> jax.Array:
    """Auxiliary load-balancing loss (Switch/GShard form): E * sum_e f_e * p_e."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_i = jax.lax.top_k(probs, m.experts_per_token)
    chosen = jax.nn.one_hot(top_i, m.num_experts).sum(axis=-2)  # [..., E]
    f = jnp.mean(chosen.reshape(-1, m.num_experts), axis=0) / m.experts_per_token
    pbar = jnp.mean(probs.reshape(-1, m.num_experts), axis=0)
    return m.num_experts * jnp.sum(f * pbar)
