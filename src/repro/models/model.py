"""The model zoo facade: one ``Model`` class interpreting any ArchConfig.

Families: dense / vlm (dense + stub patch embeddings) / moe (+MLA, MTP) /
ssm (mamba-1) / hybrid (RG-LRU + local attention) / audio (whisper enc-dec,
stub frame embeddings) / encoder (the paper's own BERT-style networks).

Layout discipline:
* Homogeneous layer stacks are *stacked* (leading layer dim) and driven by
  ``lax.scan`` — compact HLO at 80 layers, remat-friendly.
* Heterogeneous stacks (hybrid pattern, MoE dense prefix) unroll in Python.
* Every parameter is created through ``ParamBuilder`` so the same code
  yields real arrays, ShapeDtypeStructs (dry-run) or logical
  PartitionSpecs (sharding) — ADAPTOR's synthesis/runtime split.

Decode: ``init_cache`` + ``decode_step`` implement one-new-token serving
with per-family state (KV / MLA latent / SSM / rolling window).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.kv_quant import CacheCodec
from repro.core.paging import PagingConfig
from repro.core.spec import CHUNKABLE_FAMILIES, KV_QUANTIZABLE_FAMILIES
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import backend
from repro.models import layers, moe, rglru, ssm
from repro.models.attention import KVCache, MLACache
from repro.models.params import ParamBuilder


def _is_causal(cfg: ArchConfig) -> bool:
    return cfg.family != "encoder"


def _with_backend(fn):
    """Run a model entry point under its configured matmul backend (the
    routing is read at trace time, so jitted callers bake it in)."""
    @functools.wraps(fn)
    def wrapped(self, *args, **kwargs):
        with self._mm_ctx():
            return fn(self, *args, **kwargs)
    return wrapped


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Build-time execution options (the 'synthesis parameters')."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "none"  # none | full  (per-layer rematerialization)
    mtp_loss_weight: float = 0.3
    moe_aux_weight: float = 0.01
    # Unroll layer stacks into straight-line HLO instead of lax.scan.
    # Needed by the dry-run: XLA's cost_analysis counts a while-loop body
    # once, not x trip-count, so scanned-layer FLOPs/bytes/collectives
    # would be undercounted by ~num_layers.
    unroll_layers: bool = False
    # Decode attention: GQA-grouped contraction (no repeat_kv copy of the
    # KV cache to the full head count) — §Perf optimization.
    grouped_gqa: bool = False
    # Matmul routing for every dense in this model: "xla" (default) or
    # "pallas" (the ADAPTOR tiled kernels; int8 weights take the C6
    # int8_matmul path).  Applied at trace time, so jitted callers bake
    # the choice into their compiled executable.
    matmul_backend: str = "xla"
    # Paged decode attention: "gather" (XLA block-table gather + the dense
    # contraction, bit-identical to the dense layout) or "pallas" (the
    # fused paged-decode kernel with the gather folded into the
    # flash-decode loop).  Only consulted when decode_step receives
    # block tables.
    paged_attn_impl: str = "gather"
    # KV-cache storage codec: "compute" (bf16 values, historical) or
    # "int8" (quantize-on-write with per-row f32 scales; see
    # core.kv_quant).  Lowered from MemorySpec.kv_dtype by from_spec.
    kv_dtype: str = "compute"

    @classmethod
    def from_execution(cls, ex, memory=None) -> "ModelOptions":
        """Lower a ``core.spec.ExecutionSpec`` (and optionally the
        ``MemorySpec`` holding the cache codec) onto the zoo's build-time
        options — the one place the vocabularies meet."""
        return cls(param_dtype=ex.param_dtype,
                   compute_dtype=ex.compute_dtype,
                   grouped_gqa=ex.grouped_gqa,
                   matmul_backend=ex.matmul_backend,
                   paged_attn_impl=ex.paged_attn_impl,
                   kv_dtype="compute" if memory is None else memory.kv_dtype)


class Model:
    def __init__(self, cfg: ArchConfig, options: ModelOptions | None = None):
        self.cfg = cfg
        self.opt = options or ModelOptions()

    @classmethod
    def from_spec(cls, spec) -> "Model":
        """Build the zoo model a ``core.spec.RuntimeSpec`` describes; every
        execution knob is read from ``spec.execution`` (single source),
        the cache codec from ``spec.memory.kv_dtype``."""
        return cls(spec.arch, ModelOptions.from_execution(spec.execution,
                                                          spec.memory))

    @property
    def codec(self) -> CacheCodec:
        """The cache codec this model's decode state uses."""
        return CacheCodec(self.opt.kv_dtype)

    def _mm_ctx(self):
        if self.opt.matmul_backend != "xla":
            return backend.use(self.opt.matmul_backend)
        return contextlib.nullcontext()

    # ------------------------------------------------------------------
    # Parameter construction (init / abstract / axes via ParamBuilder)
    # ------------------------------------------------------------------
    def build(self, b: ParamBuilder) -> dict:
        cfg = self.cfg
        p: dict[str, Any] = {"embed": layers.build_embedding(b, cfg.vocab_size,
                                                             cfg.d_model)}
        if cfg.positional == "learned":
            p["pos_embed"] = {"table": b.param(
                (cfg.max_position_embeddings, cfg.d_model), ("pos", "embed"))}
        if not cfg.tie_embeddings:
            p["lm_head"] = {"table": b.param(
                (cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
        p["final_norm"] = layers.build_norm(b, cfg.d_model, cfg.norm)

        if cfg.family == "ssm":
            with b.stacked(cfg.num_layers):
                p["layers"] = self._build_ssm_layer(b)
        elif cfg.family == "hybrid":
            p["layers"] = [self._build_hybrid_layer(b, kind)
                           for kind in self._hybrid_kinds()]
        elif cfg.family == "moe":
            k = cfg.moe.first_k_dense
            if k:
                dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.dense_d_ff)
                p["dense_prefix"] = [self._build_dense_layer(b, dense_cfg)
                                     for _ in range(k)]
            with b.stacked(cfg.num_layers - k):
                p["layers"] = self._build_moe_layer(b)
            if cfg.num_mtp_modules:
                p["mtp"] = self._build_mtp(b)
        elif cfg.encdec is not None:
            with b.stacked(cfg.encdec.num_encoder_layers):
                p["enc_layers"] = self._build_dense_layer(b, cfg, causal=False)
            with b.stacked(cfg.num_layers):
                p["layers"] = self._build_cross_layer(b)
            p["enc_final_norm"] = layers.build_norm(b, cfg.d_model, cfg.norm)
            p["enc_pos_embed"] = {"table": b.param(
                (cfg.encdec.encoder_seq_len, cfg.d_model), ("pos", "embed"))}
        else:  # dense / vlm / encoder
            with b.stacked(cfg.num_layers):
                p["layers"] = self._build_dense_layer(b, cfg)
        return p

    def _build_attn(self, b, cfg: ArchConfig) -> dict:
        if cfg.mla is not None:
            return attn.build_mla(b, cfg)
        return attn.build_gqa(b, cfg)

    def _build_dense_layer(self, b, cfg: ArchConfig, causal: bool = True) -> dict:
        use_bias = cfg.norm == "layernorm"  # paper-style FFN carries biases
        return {
            "ln1": layers.build_norm(b, cfg.d_model, cfg.norm),
            "attn": self._build_attn(b, cfg),
            "ln2": layers.build_norm(b, cfg.d_model, cfg.norm),
            "ffn": moe.build_ffn(b, cfg, cfg.d_ff, use_bias=use_bias),
        }

    def _build_moe_layer(self, b) -> dict:
        cfg = self.cfg
        return {
            "ln1": layers.build_norm(b, cfg.d_model, cfg.norm),
            "attn": self._build_attn(b, cfg),
            "ln2": layers.build_norm(b, cfg.d_model, cfg.norm),
            "moe": moe.build_moe(b, cfg),
        }

    def _build_ssm_layer(self, b) -> dict:
        cfg = self.cfg
        return {"ln": layers.build_norm(b, cfg.d_model, cfg.norm),
                "ssm": ssm.build_ssm(b, cfg)}

    def _hybrid_kinds(self) -> list[str]:
        pat = self.cfg.hybrid.pattern
        return [pat[i % len(pat)] for i in range(self.cfg.num_layers)]

    def _build_hybrid_layer(self, b, kind: str) -> dict:
        cfg = self.cfg
        p = {"ln1": layers.build_norm(b, cfg.d_model, cfg.norm),
             "ln2": layers.build_norm(b, cfg.d_model, cfg.norm),
             "ffn": moe.build_ffn(b, cfg, cfg.d_ff)}
        if kind == "r":
            p["rglru"] = rglru.build_rglru(b, cfg)
        else:
            p["attn"] = attn.build_gqa(b, cfg)
        return p

    def _build_cross_layer(self, b) -> dict:
        cfg = self.cfg
        return {
            "ln1": layers.build_norm(b, cfg.d_model, cfg.norm),
            "attn": self._build_attn(b, cfg),
            "ln_cross": layers.build_norm(b, cfg.d_model, cfg.norm),
            "cross": attn.build_gqa(b, cfg),
            "ln2": layers.build_norm(b, cfg.d_model, cfg.norm),
            "ffn": moe.build_ffn(b, cfg, cfg.d_ff,
                                 use_bias=cfg.norm == "layernorm"),
        }

    def _build_mtp(self, b) -> dict:
        cfg = self.cfg
        return {"proj": layers.build_dense(b, 2 * cfg.d_model, cfg.d_model,
                                           ("embed", "embed")),
                "norm_h": layers.build_norm(b, cfg.d_model, cfg.norm),
                "norm_e": layers.build_norm(b, cfg.d_model, cfg.norm),
                "layer": self._build_moe_layer(b)}

    def init(self, rng: jax.Array) -> dict:
        return self.build(ParamBuilder("init", rng, self.opt.param_dtype))

    def abstract(self) -> dict:
        return self.build(ParamBuilder("abstract", dtype=self.opt.param_dtype))

    def axes(self) -> dict:
        return self.build(ParamBuilder("axes", dtype=self.opt.param_dtype))

    # ------------------------------------------------------------------
    # Layer bodies
    # ------------------------------------------------------------------
    def _maybe_remat(self, f):
        if self.opt.remat == "full":
            return jax.checkpoint(f)
        if self.opt.remat == "dots":
            # save matmul outputs: no recompute of attention/FFN/dispatch
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable)
        return f

    def _run_stack(self, body, x, stacked):
        """Scan over stacked layer params, or unroll (dry-run mode).
        ``body(x, layer_params) -> (x, None)``."""
        if not self.opt.unroll_layers:
            return jax.lax.scan(body, x, stacked)[0]
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree.map(lambda l, i=i: l[i], stacked))
        return x

    def _run_stack_cache(self, body, x, stacked, cache):
        """Layer loop threading a per-layer cache; scan or unrolled."""
        if not self.opt.unroll_layers:
            return jax.lax.scan(body, x, (stacked, cache))
        n = jax.tree.leaves(stacked)[0].shape[0]
        outs = []
        for i in range(n):
            x, c = body(x, (jax.tree.map(lambda l, i=i: l[i], stacked),
                            jax.tree.map(lambda l, i=i: l[i], cache)))
            outs.append(c)
        return x, jax.tree.map(lambda *ls: jnp.stack(ls), *outs)

    def _run_stack_collect(self, body, x, stacked):
        """Layer loop collecting a per-layer output (prefill caches)."""
        if not self.opt.unroll_layers:
            return jax.lax.scan(body, x, stacked)
        n = jax.tree.leaves(stacked)[0].shape[0]
        outs = []
        for i in range(n):
            x, c = body(x, jax.tree.map(lambda l, i=i: l[i], stacked))
            outs.append(c)
        return x, jax.tree.map(lambda *ls: jnp.stack(ls), *outs)

    def _run_prefix_then_stack(self, body, x, params, cache):
        """Cache-threading layer loop with the MoE dense prefix: the
        unrolled prefix layers hold their own cache slices at the front
        of the stacked cache, the scanned main stack follows, and the
        prefix caches are re-stacked on the way out.  Shared by
        ``decode_step`` and ``mixed_step`` (all attention variants)."""
        prefix = params.get("dense_prefix", [])
        if not prefix:
            return self._run_stack_cache(body, x, params["layers"], cache)
        npref = len(prefix)
        pref_cache = jax.tree.map(lambda l: l[:npref], cache)
        main_cache = jax.tree.map(lambda l: l[npref:], cache)
        new_pref = []
        for i, lp in enumerate(prefix):
            ci = jax.tree.map(lambda l, i=i: l[i], pref_cache)
            x, c2 = body(x, (lp, ci))
            new_pref.append(c2)
        x, new_main = self._run_stack_cache(body, x, params["layers"],
                                            main_cache)
        stacked_pref = jax.tree.map(lambda *ls: jnp.stack(ls), *new_pref)
        return x, jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                               stacked_pref, new_main)

    def _dense_body(self, x, lp, positions, causal, window=None):
        cfg = self.cfg
        # re-pin the scan carry: GSPMD propagation through while loops
        # otherwise drops the batch sharding (see DESIGN.md §7).  Under a
        # sequence-parallel strategy "seq" resolves to the TP axis and the
        # residual stream stays token-sharded between blocks (Megatron-SP:
        # the TP all-reduce splits into reduce-scatter + bf16 all-gather).
        x = constrain(x, ("batch", "seq", None))
        h = layers.apply_norm(x, lp["ln1"], cfg.norm)
        if cfg.mla is not None:
            h = attn.mla_attention(h, lp["attn"], cfg, positions=positions)
        else:
            h = attn.gqa_attention(h, lp["attn"], cfg, positions=positions,
                                   causal=causal, window=window)
        x = x + h
        h = layers.apply_norm(x, lp["ln2"], cfg.norm)
        if "moe" in lp:
            h = moe.apply_moe(h, lp["moe"], cfg)
        else:
            h = moe.apply_ffn(h, lp["ffn"], cfg.activation)
        return x + h

    def _ssm_body(self, x, lp):
        x = constrain(x, ("batch", None, None))
        h = layers.apply_norm(x, lp["ln"], self.cfg.norm)
        return x + ssm.ssm_forward(h, lp["ssm"], self.cfg)

    def _hybrid_body(self, x, lp, kind, positions):
        cfg = self.cfg
        x = constrain(x, ("batch", None, None))
        h = layers.apply_norm(x, lp["ln1"], cfg.norm)
        if kind == "r":
            h = rglru.rglru_forward(h, lp["rglru"], cfg)
        else:
            h = attn.gqa_attention(h, lp["attn"], cfg, positions=positions,
                                   causal=True,
                                   window=cfg.hybrid.attention_window)
        x = x + h
        h = layers.apply_norm(x, lp["ln2"], cfg.norm)
        return x + moe.apply_ffn(h, lp["ffn"], cfg.activation)

    def _cross_body(self, x, lp, positions, enc_kv):
        cfg = self.cfg
        x = constrain(x, ("batch", None, None))
        h = layers.apply_norm(x, lp["ln1"], cfg.norm)
        h = attn.gqa_attention(h, lp["attn"], cfg, positions=positions,
                               causal=True)
        x = x + h
        h = layers.apply_norm(x, lp["ln_cross"], cfg.norm)
        x = x + self._cross_attend(h, lp["cross"], enc_kv)
        h = layers.apply_norm(x, lp["ln2"], cfg.norm)
        return x + moe.apply_ffn(h, lp["ffn"], cfg.activation)

    def _cross_attend(self, h, cp, enc_kv):
        """Cross-attention: queries from decoder, K/V precomputed from encoder."""
        cfg = self.cfg
        b_, s, _ = h.shape
        hd = cfg.resolved_head_dim
        q = layers.apply_dense(h, cp["wq"]).reshape(b_, s, cfg.num_heads, hd)
        k, v = enc_kv
        n_rep = cfg.num_heads // max(cfg.num_kv_heads, 1)
        k, v = attn.repeat_kv(k, n_rep), attn.repeat_kv(v, n_rep)
        o = attn.full_attention(q, k, v, causal=False)
        return layers.apply_dense(o.reshape(b_, s, cfg.num_heads * hd), cp["wo"])

    def _cross_kv(self, cp, enc_out):
        cfg = self.cfg
        b_, se, _ = enc_out.shape
        hd = cfg.resolved_head_dim
        k = layers.apply_dense(enc_out, cp["wk"]).reshape(b_, se, cfg.num_kv_heads, hd)
        v = layers.apply_dense(enc_out, cp["wv"]).reshape(b_, se, cfg.num_kv_heads, hd)
        return k, v

    # ------------------------------------------------------------------
    # Embedding / positions
    # ------------------------------------------------------------------
    def _embed_inputs(self, params, batch, q_offset: int = 0):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = layers.embed(tokens, params["embed"], self.opt.compute_dtype)
        b_, s = tokens.shape
        positions = jnp.arange(s, dtype=jnp.int32)[None, :] + q_offset
        if cfg.positional == "learned":
            idx = jnp.minimum(positions, cfg.max_position_embeddings - 1)
            x = x + params["pos_embed"]["table"].astype(x.dtype)[idx[0]][None]
        if cfg.frontend is not None and cfg.encdec is None and "frontend" in batch:
            # stub vision frontend: first num_tokens positions carry the
            # precomputed patch embeddings (audio frontends feed the encoder)
            fe = batch["frontend"].astype(x.dtype)
            n = fe.shape[1]
            mask = (jnp.arange(s) < n)[None, :, None]
            fe_pad = jnp.pad(fe, ((0, 0), (0, max(s - n, 0)), (0, 0)))[:, :s]
            x = jnp.where(mask, fe_pad, x)
        return constrain(x, ("batch", None, None)), positions

    def _unembed(self, params, x):
        x = layers.apply_norm(x, params["final_norm"], self.cfg.norm)
        table = params["embed"]["table"] if self.cfg.tie_embeddings \
            else params["lm_head"]["table"]
        logits = layers.unembed(x, {"table": table})
        return constrain(logits, ("batch", None, "vocab"))

    # ------------------------------------------------------------------
    # Forward (train / prefill)
    # ------------------------------------------------------------------
    @_with_backend
    def forward(self, params: dict, batch: dict) -> jax.Array:
        """Full-sequence forward -> logits [B, S, vocab] (f32)."""
        return self._unembed(params, self._backbone(params, batch))

    def _backbone(self, params: dict, batch: dict) -> jax.Array:
        """Embed + all layers -> pre-final-norm hidden states [B, S, d]."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        causal = _is_causal(cfg)

        if cfg.family == "ssm":
            body = self._maybe_remat(lambda h, lp: (self._ssm_body(h, lp), None))
            x = self._run_stack(body, x, params["layers"])
        elif cfg.family == "hybrid":
            for lp, kind in zip(params["layers"], self._hybrid_kinds()):
                f = self._maybe_remat(functools.partial(
                    self._hybrid_body, kind=kind, positions=positions))
                x = f(x, lp)
        elif cfg.family == "moe":
            for lp in params.get("dense_prefix", []):
                f = self._maybe_remat(functools.partial(
                    self._dense_body, positions=positions, causal=True))
                x = f(x, lp)
            body = self._maybe_remat(lambda h, lp: (
                self._dense_body(h, lp, positions, True), None))
            x = self._run_stack(body, x, params["layers"])
        elif cfg.encdec is not None:
            enc = self._encode(params, batch)
            def cross_body(h, lp):
                kv = self._cross_kv(lp["cross"], enc)
                return self._cross_body(h, lp, positions, kv), None
            x = self._run_stack(self._maybe_remat(cross_body), x,
                                params["layers"])
        else:
            window = cfg.hybrid.attention_window if cfg.hybrid else None
            body = self._maybe_remat(lambda h, lp: (
                self._dense_body(h, lp, positions, causal, window), None))
            x = self._run_stack(body, x, params["layers"])
        return x

    def _encode(self, params: dict, batch: dict) -> jax.Array:
        """Whisper encoder over stub frame embeddings [B, T_enc, d]."""
        cfg = self.cfg
        fe = batch["frontend"].astype(self.opt.compute_dtype)
        pos = jnp.arange(fe.shape[1], dtype=jnp.int32)[None, :]
        x = fe + params["enc_pos_embed"]["table"].astype(fe.dtype)[None]
        body = self._maybe_remat(lambda h, lp: (
            self._dense_body(h, lp, pos, causal=False), None))
        x = self._run_stack(body, x, params["enc_layers"])
        return layers.apply_norm(x, params["enc_final_norm"], cfg.norm)

    # ------------------------------------------------------------------
    # Loss (train step body)
    # ------------------------------------------------------------------
    @_with_backend
    def loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = self._backbone(params, batch)
        logits = self._unembed(params, x)
        targets = batch["targets"]
        xent = _xent(logits, targets)
        aux: dict[str, jax.Array] = {"xent": xent}
        total = xent
        if cfg.family == "moe" and self.opt.moe_aux_weight:
            # router balance loss on the embedding stream (cheap proxy input)
            e, _ = self._embed_inputs(params, batch)
            lb = moe.load_balance_loss(
                e, _first_layer(params["layers"], "moe")["router"], cfg.moe)
            aux["load_balance"] = lb
            total = total + self.opt.moe_aux_weight * lb
        if cfg.num_mtp_modules and "mtp" in params:
            mtp_loss = self._mtp_loss(params, batch, x)
            aux["mtp"] = mtp_loss
            total = total + self.opt.mtp_loss_weight * mtp_loss
        aux["total"] = total
        return total, aux

    def _mtp_loss(self, params: dict, batch: dict, x: jax.Array) -> jax.Array:
        """DeepSeek-V3 multi-token prediction (depth 1), reusing the main
        backbone hidden states ``x``: combine h_t with emb(t+1), run one
        extra layer, predict token t+2."""
        cfg = self.cfg
        targets = batch["targets"]
        positions = jnp.arange(targets.shape[1], dtype=jnp.int32)[None, :]
        mp = params["mtp"]
        e = layers.embed(targets, params["embed"], self.opt.compute_dtype)
        h = jnp.concatenate([
            layers.apply_norm(x, mp["norm_h"], cfg.norm),
            layers.apply_norm(e, mp["norm_e"], cfg.norm)], axis=-1)
        h = layers.apply_dense(h, mp["proj"])
        h = self._dense_body(h, mp["layer"], positions, True)
        logits = self._unembed(params, h)
        return _xent(logits, jnp.roll(targets, -1, axis=1))

    # ------------------------------------------------------------------
    # Decode (one new token with per-family cache)
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, abstract: bool = False,
                   paging: "PagingConfig | None" = None):
        """Decode cache in either layout and either storage codec.

        ``paging=None`` (dense): per-slot ``[batch, max_len, ...]`` rows —
        the training/test layout.  With a ``core.paging.PagingConfig``,
        returns the pooled block layout ``[num_blocks+1, block_size, ...]``
        shared by all slots (row 0 is the null block); ``batch``/``max_len``
        then only bound the serving engine's block tables, not the pool.

        With ``ModelOptions(kv_dtype="int8")`` the KV/latent values are
        int8 and per-row f32 scale arrays ride in the same pytree
        (``core.kv_quant``); supported for the attention-cache families
        only.
        """
        cfg = self.cfg
        codec = self.codec
        kd = codec.storage_dtype(jnp.bfloat16)
        if codec.quantized and cfg.family not in KV_QUANTIZABLE_FAMILIES:
            raise ValueError(
                f"kv_dtype='int8' is unsupported for family {cfg.family!r} "
                "(only KV/latent attention caches are quantized); use "
                "kv_dtype='compute'")
        if paging is not None:
            return self._init_paged_cache(paging, abstract)

        def kv(n_layers, s, n_kv, hd):
            shape = (n_layers, batch, s, n_kv, hd)
            kvals, ksc = codec.cache_arrays(shape, abstract=abstract)
            vvals, vsc = codec.cache_arrays(shape, abstract=abstract)
            return KVCache(kvals, vvals, ksc, vsc)

        if cfg.family == "ssm":
            st = ssm.ssm_init_state(cfg, batch, abstract)
            return jax.tree.map(
                lambda l: _stack_abstract(l, cfg.num_layers) if abstract
                else jnp.broadcast_to(l, (cfg.num_layers,) + l.shape).copy(), st)
        if cfg.mla is not None:
            m = cfg.mla
            cv, cs = codec.cache_arrays(
                (cfg.num_layers, batch, max_len, m.kv_lora_rank),
                abstract=abstract)
            rv, rs = codec.cache_arrays(
                (cfg.num_layers, batch, max_len, m.qk_rope_head_dim),
                abstract=abstract)
            return MLACache(cv, rv, cs, rs)
        if cfg.family == "hybrid":
            caches = []
            for kind in self._hybrid_kinds():
                if kind == "r":
                    caches.append(rglru.rglru_init_state(cfg, batch, abstract))
                else:
                    w = min(cfg.hybrid.attention_window, max_len)
                    shape = (batch, w, cfg.num_kv_heads, cfg.resolved_head_dim)
                    if abstract:
                        caches.append(KVCache(jax.ShapeDtypeStruct(shape, kd),
                                              jax.ShapeDtypeStruct(shape, kd)))
                    else:
                        caches.append(KVCache(jnp.zeros(shape, kd),
                                              jnp.zeros(shape, kd)))
            return caches
        if cfg.encdec is not None:
            se = cfg.encdec.encoder_seq_len
            return {"self": kv(cfg.num_layers, max_len, cfg.num_kv_heads,
                               cfg.resolved_head_dim),
                    "cross": kv(cfg.num_layers, se, cfg.num_kv_heads,
                                cfg.resolved_head_dim)}
        return kv(cfg.num_layers, max_len, cfg.num_kv_heads,
                  cfg.resolved_head_dim)

    def _init_paged_cache(self, paging, abstract: bool):
        cfg = self.cfg
        codec = self.codec
        if cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError(
                f"paged KV cache unsupported for family {cfg.family!r} "
                "(SSM / rolling-window / enc-dec state is not paged)")

        pb, bs = paging.pool_blocks, paging.block_size
        if cfg.mla is not None:
            m = cfg.mla
            cv, cs = codec.cache_arrays(
                (cfg.num_layers, pb, bs, m.kv_lora_rank), abstract=abstract)
            rv, rs = codec.cache_arrays(
                (cfg.num_layers, pb, bs, m.qk_rope_head_dim),
                abstract=abstract)
            return MLACache(cv, rv, cs, rs)
        shape = (cfg.num_layers, pb, bs, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
        kvals, ksc = codec.cache_arrays(shape, abstract=abstract)
        vvals, vsc = codec.cache_arrays(shape, abstract=abstract)
        return KVCache(kvals, vvals, ksc, vsc)

    @_with_backend
    # jit-region
    def prefill(self, params: dict, batch: dict, max_len: int):
        """Prompt -> (logits [B,S,V], decode cache ready at index S).

        The serving counterpart of ``forward``: identical math, but every
        layer also emits its decode-time state.
        """
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)

        def ffn_half(h, lp):
            # SP residual pinning only — prefill never had the scan-carry
            # sharding bug, and pinning batch here regressed propagation
            # (see EXPERIMENTS.md §Perf prefill iteration 1)
            h = constrain(h, (None, "seq", None))
            hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
            if "moe" in lp:
                return h + moe.apply_moe(hn, lp["moe"], cfg)
            return h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)

        if cfg.family == "ssm":
            def body(h, lp):
                hn = layers.apply_norm(h, lp["ln"], cfg.norm)
                o, st = ssm.ssm_prefill(hn, lp["ssm"], cfg)
                return h + o, st
            x, cache = self._run_stack_collect(body, x, params["layers"])
        elif cfg.family == "hybrid":
            cache = []
            for lp, kind in zip(params["layers"], self._hybrid_kinds()):
                hn = layers.apply_norm(x, lp["ln1"], cfg.norm)
                if kind == "r":
                    o, st = rglru.rglru_prefill(hn, lp["rglru"], cfg)
                else:
                    o, st = attn.gqa_prefill(
                        hn, lp["attn"], cfg, positions=positions,
                        max_len=max_len, window=cfg.hybrid.attention_window)
                x = ffn_half(x + o, lp)
                cache.append(st)
        elif cfg.encdec is not None:
            enc = self._encode(params, batch)
            def body(h, lp):
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                o, st = attn.gqa_prefill(hn, lp["attn"], cfg,
                                         positions=positions, max_len=max_len)
                h = h + o
                kc, vc = self._cross_kv(lp["cross"], enc)
                hn = layers.apply_norm(h, lp["ln_cross"], cfg.norm)
                h = h + self._cross_attend(hn, lp["cross"], (kc, vc))
                hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
                h = h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                cross = KVCache(kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16))
                return h, (st, cross)
            x, (self_c, cross_c) = self._run_stack_collect(
                body, x, params["layers"])
            cache = {"self": self_c, "cross": cross_c}
        else:
            def body(h, lp):
                h = constrain(h, (None, "seq", None))
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                if cfg.mla is not None:
                    o, st = attn.mla_prefill(hn, lp["attn"], cfg,
                                             positions=positions,
                                             max_len=max_len,
                                             codec=self.codec)
                else:
                    o, st = attn.gqa_prefill(hn, lp["attn"], cfg,
                                             positions=positions,
                                             max_len=max_len,
                                             codec=self.codec)
                return ffn_half(h + o, lp), st

            pref = []
            for lp in params.get("dense_prefix", []):
                x, st = body(x, lp)
                pref.append(st)
            x, main_cache = self._run_stack_collect(body, x, params["layers"])
            if pref:
                stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *pref)
                cache = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                                     stacked, main_cache)
            else:
                cache = main_cache
        return self._unembed(params, x), cache

    @_with_backend
    # jit-region
    def decode_step(self, params: dict, cache, tokens: jax.Array,
                    cache_index: jax.Array,
                    block_tables: jax.Array | None = None):
        """tokens: [B, 1] -> (logits [B, 1, vocab], new cache).

        ``cache_index``: scalar, or [B] per-slot indices (serving).
        ``block_tables``: [B, blocks_per_slot] int32 selects the paged
        cache layout (``cache`` must then be the pooled block layout from
        ``init_cache(..., paging=...)``); None keeps the dense layout."""
        cfg = self.cfg
        if block_tables is not None and cfg.family not in ("dense", "vlm",
                                                           "moe"):
            raise ValueError(
                f"paged decode unsupported for family {cfg.family!r}")
        idx_vec = attn.as_index_vector(cache_index, tokens.shape[0])
        x = layers.embed(tokens, params["embed"], self.opt.compute_dtype)
        if cfg.positional == "learned":
            idx = jnp.minimum(idx_vec, cfg.max_position_embeddings - 1)
            x = x + params["pos_embed"]["table"].astype(x.dtype)[idx][:, None]

        if cfg.family == "ssm":
            def body(h, inp):
                lp, st = inp
                hn = layers.apply_norm(h, lp["ln"], cfg.norm)
                out, st2 = ssm.ssm_decode(hn, lp["ssm"], cfg, st)
                return h + out, st2
            x, new_cache = self._run_stack_cache(body, x, params["layers"], cache)
        elif cfg.mla is not None:
            def body(h, inp):
                lp, c = inp
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                if block_tables is not None:
                    o, c2 = attn.mla_decode_paged(hn, lp["attn"], cfg, c,
                                                  cache_index, block_tables,
                                                  codec=self.codec)
                else:
                    o, c2 = attn.mla_decode(hn, lp["attn"], cfg, c,
                                            cache_index, codec=self.codec)
                h = h + o
                hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
                if "moe" in lp:
                    h = h + moe.apply_moe(hn, lp["moe"], cfg)
                else:
                    h = h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                return h, c2
            # dense prefix layers hold their own caches at the front
            x, new_cache = self._run_prefix_then_stack(body, x, params,
                                                       cache)
        elif cfg.family == "hybrid":
            new_cache = []
            for lp, kind, st in zip(params["layers"], self._hybrid_kinds(), cache):
                hn = layers.apply_norm(x, lp["ln1"], cfg.norm)
                if kind == "r":
                    o, st2 = rglru.rglru_decode(hn, lp["rglru"], cfg, st)
                else:
                    o, st2 = attn.gqa_decode(hn, lp["attn"], cfg, st, cache_index,
                                             window=cfg.hybrid.attention_window,
                                             grouped=self.opt.grouped_gqa)
                x = x + o
                hn = layers.apply_norm(x, lp["ln2"], cfg.norm)
                x = x + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                new_cache.append(st2)
        elif cfg.encdec is not None:
            def body(h, inp):
                lp, (c_self, c_cross) = inp
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                o, c2 = attn.gqa_decode(hn, lp["attn"], cfg, c_self, cache_index,
                                        grouped=self.opt.grouped_gqa)
                h = h + o
                hn = layers.apply_norm(h, lp["ln_cross"], cfg.norm)
                h = h + self._cross_attend(hn, lp["cross"], (c_cross.k, c_cross.v))
                hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
                h = h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                return h, (c2, c_cross)
            x, new_cache = self._run_stack_cache(
                body, x, params["layers"], (cache["self"], cache["cross"]))
            new_cache = {"self": new_cache[0], "cross": new_cache[1]}
        else:
            def body(h, inp):
                lp, c = inp
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                if block_tables is not None:
                    o, c2 = attn.gqa_decode_paged(
                        hn, lp["attn"], cfg, c, cache_index, block_tables,
                        grouped=self.opt.grouped_gqa,
                        impl=self.opt.paged_attn_impl, codec=self.codec)
                else:
                    o, c2 = attn.gqa_decode(hn, lp["attn"], cfg, c,
                                            cache_index,
                                            grouped=self.opt.grouped_gqa,
                                            codec=self.codec)
                h = h + o
                hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
                if "moe" in lp:
                    h = h + moe.apply_moe(hn, lp["moe"], cfg)
                else:
                    h = h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                return h, c2
            x, new_cache = self._run_prefix_then_stack(body, x, params,
                                                       cache)
        return self._unembed(params, x), new_cache

    @_with_backend
    # jit-region
    def mixed_step(self, params: dict, cache, tokens: jax.Array,
                   start: jax.Array, n_live: jax.Array,
                   block_tables: jax.Array | None = None,
                   prefill_lanes: jax.Array | None = None):
        """Chunked-prefill/decode mixed step: tokens [B, W] -> (logits
        [B, W, vocab], new cache).

        Lane ``l`` of slot ``b`` sits at cache position ``start[b] + l``;
        only the first ``n_live[b]`` lanes are real.  A decoding slot uses
        one lane (its next token), a prefilling slot up to a chunk of
        prompt tokens, an idle slot none — one compiled step serves any
        mixture, so prefill stops being a separate per-bucket dispatch.
        ``prefill_lanes`` ([B] bool) marks slots whose lanes are prompt
        tokens (only consulted by the vlm frontend stub).  Restricted to
        attention-cache families: recurrent / rolling-window / enc-dec
        prefill state is sequential and stays on the bucketed path.
        """
        cfg = self.cfg
        if cfg.family not in CHUNKABLE_FAMILIES:
            raise ValueError(
                f"mixed_step unsupported for family {cfg.family!r} "
                "(sequential prefill state); use the bucketed scheduler")
        b_, w = tokens.shape
        start = attn.as_index_vector(start, b_)
        positions = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
        x = layers.embed(tokens, params["embed"], self.opt.compute_dtype)
        if cfg.positional == "learned":
            idx = jnp.minimum(positions, cfg.max_position_embeddings - 1)
            x = x + params["pos_embed"]["table"].astype(x.dtype)[idx]
        if cfg.frontend is not None and prefill_lanes is not None:
            # parity with the stub vision frontend of prefill: prompt
            # positions < num_tokens carry the (zero-stub) patch
            # embeddings instead of token embeddings
            fm = prefill_lanes[:, None, None] \
                & (positions < cfg.frontend.num_tokens)[..., None]
            x = jnp.where(fm, jnp.zeros_like(x), x)

        if cfg.mla is not None:
            def body(h, inp):
                lp, c = inp
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                if block_tables is not None:
                    o, c2 = attn.mla_mixed_paged(hn, lp["attn"], cfg, c,
                                                 start, n_live, block_tables,
                                                 codec=self.codec)
                else:
                    o, c2 = attn.mla_mixed(hn, lp["attn"], cfg, c,
                                           start, n_live, codec=self.codec)
                h = h + o
                hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
                if "moe" in lp:
                    h = h + moe.apply_moe(hn, lp["moe"], cfg)
                else:
                    h = h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                return h, c2
        else:
            def body(h, inp):
                lp, c = inp
                hn = layers.apply_norm(h, lp["ln1"], cfg.norm)
                if block_tables is not None:
                    o, c2 = attn.gqa_mixed_paged(
                        hn, lp["attn"], cfg, c, start, n_live, block_tables,
                        grouped=self.opt.grouped_gqa,
                        impl=self.opt.paged_attn_impl, codec=self.codec)
                else:
                    o, c2 = attn.gqa_mixed(hn, lp["attn"], cfg, c,
                                           start, n_live,
                                           grouped=self.opt.grouped_gqa,
                                           codec=self.codec)
                h = h + o
                hn = layers.apply_norm(h, lp["ln2"], cfg.norm)
                if "moe" in lp:
                    h = h + moe.apply_moe(hn, lp["moe"], cfg)
                else:
                    h = h + moe.apply_ffn(hn, lp["ffn"], cfg.activation)
                return h, c2

        x, new_cache = self._run_prefix_then_stack(body, x, params, cache)
        return self._unembed(params, x), new_cache


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Cross-entropy that stays sharded over a vocab-partitioned logits
    tensor: the gold logit is picked with a fused iota-compare-reduce, not
    a gather (a gather across the sharded vocab axis would force GSPMD to
    all-gather the full [B, S, V] logits on every device)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    hit = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1) \
        == targets[..., None]
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def _first_layer(stacked: dict, key: str) -> dict:
    return jax.tree.map(lambda l: l[0], stacked[key])


def _stack_abstract(leaf, n: int):
    return jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)

