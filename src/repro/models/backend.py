"""Matmul backend switch: XLA dot vs the ADAPTOR Pallas tiled kernel.

Three modes, selected with context managers:

* default        — CPU-safe execution: bf16 operands are upcast to f32
  (the CPU DotThunk cannot execute some fused bf16 x bf16 -> f32 dots).
  Numerically this *over*-delivers on the TPU semantics (full f32 path).
* ``faithful()`` — bf16-in / f32-accumulate via ``preferred_element_type``,
  the exact TPU MXU contract.  Used by the multi-pod dry-run so the
  lowered HLO carries true bf16 operand bytes for the roofline analysis
  (it is never executed on CPU).
* ``use('pallas')`` — route through the ADAPTOR tiled Pallas kernel
  (validated in interpret mode on CPU; the deployment path on TPU).

This mirrors the paper's split between the HLS behavioural C model
(C simulation) and the synthesized RTL.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator

import jax
import jax.numpy as jnp

_state = threading.local()


def _impl() -> str:
    return getattr(_state, "impl", "xla")


def active_impl() -> str:
    """The matmul implementation in effect for the current (trace) scope."""
    return _impl()


def _faithful() -> bool:
    return getattr(_state, "faithful", False)


@contextlib.contextmanager
def use(impl: str) -> Iterator[None]:
    """Context manager selecting the matmul implementation: 'xla' | 'pallas'."""
    if impl not in ("xla", "pallas"):
        raise ValueError(f"unknown backend {impl!r}")
    old = _impl()
    _state.impl = impl
    try:
        yield
    finally:
        _state.impl = old


@contextlib.contextmanager
def faithful() -> Iterator[None]:
    """bf16-in/f32-accumulate HLO (TPU contract); lower-only on CPU."""
    old = _faithful()
    _state.faithful = True
    try:
        yield
    finally:
        _state.faithful = old


def matmul(x, w):
    """y[..., n] = sum_k x[..., k] w[k, n], bf16-in / f32-accumulate."""
    if _impl() == "pallas":
        from repro.kernels import ops

        return ops.tiled_matmul(x, w)
    if _faithful() or jax.default_backend() != "cpu":
        return jnp.matmul(x, w,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    # CPU-safe execution path: full f32 (DotThunk bf16 limitation)
    return jnp.matmul(x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
