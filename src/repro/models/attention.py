"""Attention variants: GQA (full / blockwise / local-window) and MLA.

The paper's QK_PM -> softmax -> SV_PM pipeline (§3.6) appears here in three
forms:

* ``full_attention``       — direct einsum chain, used for short sequences;
  this is the literal Algorithm 11/7/12 composition.
* ``blockwise_attention``  — query-block streamed attention with the score
  rows never exceeding one block: the TPU analogue of the paper's tiled
  BRAM reuse (scores stay "on chip" per tile).  Used for long sequences on
  the XLA path; the Pallas ``flash_attention`` kernel is the TPU-native
  fusion of the same pipeline.
* ``local_attention``      — banded window attention (RecurrentGemma).

MLA (DeepSeek-V3) keeps the paper's dense-matmul discipline: every
projection routes through ``layers.dense`` and is therefore tiled by the
same machinery.  Decode uses the *absorbed* formulation so the per-step
cost scales with the latent width, not the expanded head dims.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.core import masking
from repro.core.kv_quant import (FLOAT_CODEC, CacheCodec, cache_put,
                                 gather_view)
from repro.core.paging import NULL_BLOCK
from repro.distributed.sharding import constrain
from repro.kernels.runtime import interpret_default
from repro.models import layers
from repro.models.layers import apply_rope, build_dense, apply_dense

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# Sequences at or above this length use blockwise (streamed) attention on
# the XLA path; below it the direct einsum chain is cheaper to compile.
BLOCKWISE_THRESHOLD = 8_192
QUERY_BLOCK = 1_024


class KVCache(NamedTuple):
    """Decode-time K/V cache for one attention stack.

    Two layouts share this pytree (the cache-layout interface):

    * dense — ``[B, S_max, n_kv, hd]``: one preallocated row per slot.
    * paged — ``[num_blocks, block_size, n_kv, hd]``: a pooled cache of
      fixed-size token blocks; a slot's sequence is scattered across the
      pool and addressed through its block table (``core.paging``).

    Two storage codecs share it too (``core.kv_quant.CacheCodec``):
    under ``kv_dtype="int8"`` the ``k``/``v`` values are int8 and the
    ``k_scale``/``v_scale`` arrays (values shape minus the trailing
    head_dim — one f32 scale per (position, kv-head) row) ride beside
    them through the same scatters, gathers and block tables; in
    ``"compute"`` mode the scale fields are None and the pytree is
    structurally the historical (k, v) pair.
    """

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None = None
    v_scale: jax.Array | None = None


def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, kv, hd] -> [B, S, kv*n_rep, hd] (GQA head grouping)."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd)) \
        .reshape(b, s, kv * n_rep, hd)


def _causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """[q_len, kv_len] bool; q position i (global i+q_offset) sees kv <= it."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, q_offset=0,
                   kv_len_mask: jax.Array | None = None,
                   scale: float | None = None) -> jax.Array:
    """q: [B,Sq,h,hd], k/v: [B,Skv,kv,hd] (kv already repeated to h)."""
    b, sq, h, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        s = jnp.where(_causal_mask(sq, k.shape[1], q_offset)[None, None], s, NEG_INF)
    if kv_len_mask is not None:
        # [B, Skv] live-position mask (decode / padding), or a per-lane
        # [B, Sq, Skv] mask (the chunked mixed step's causal-vs-cache view)
        m = kv_len_mask[:, None, None, :] if kv_len_mask.ndim == 2 \
            else kv_len_mask[:, None, :, :]
        s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        query_block: int = QUERY_BLOCK,
                        scale: float | None = None) -> jax.Array:
    """Query-block streamed attention: peak score memory B*h*Qb*Skv.

    XLA-level flash attention — the same tiling Fig. 4 applies to weight
    matrices, applied to the score matrix.  Exact (not approximate).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    vd = v.shape[-1]  # MLA: value head dim differs from qk head dim
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    nb = -(-sq // query_block)
    pad = nb * query_block - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nb, query_block, h, hd).transpose(1, 0, 2, 3, 4)

    kv_pos = jnp.arange(skv)

    def one_block(carry, inp):
        qi, block_idx = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k).astype(jnp.float32) * scale
        if causal:
            q_pos = block_idx * query_block + jnp.arange(query_block)
            m = kv_pos[None, :] <= q_pos[:, None]
            s = jnp.where(m[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
        return carry, o

    _, ob = jax.lax.scan(one_block, None, (qb, jnp.arange(nb)))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(b, nb * query_block, h, vd)
    return out[:, :sq]


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, window: int, *,
                    scale: float | None = None) -> jax.Array:
    """Causal banded attention: position i attends to (i-window, i].

    Implemented block-wise (block = window): each query block attends to its
    own and the previous key block, so memory is B*h*S*2W, never S^2.
    """
    b, s, h, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    w = min(window, s)
    nb = -(-s // w)
    pad = nb * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = nb * w
    qb = q.reshape(b, nb, w, h, hd)
    kb = k.reshape(b, nb, w, h, hd)
    vb = v.reshape(b, nb, w, h, hd)
    # keys for block i: blocks (i-1, i); block -1 is zeros and fully masked.
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # [b, nb, 2w, h, hd]
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    sc = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, k2).astype(jnp.float32) * scale
    q_pos = jnp.arange(w)[:, None] + w                    # within the 2w frame
    kv_pos = jnp.arange(2 * w)[None, :]
    m = (kv_pos <= q_pos) & (kv_pos > q_pos - w)          # (i-w, i]
    first = (jnp.arange(nb) == 0)[:, None, None]          # block -1 is invalid
    m = m[None] & (~first | (kv_pos[None] >= w))
    sc = jnp.where(m[:, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    ob = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(v2.dtype), v2)
    return ob.reshape(b, sp, h, hd)[:, :s]


# ---------------------------------------------------------------------------
# GQA attention block (params + apply)
# ---------------------------------------------------------------------------
def build_gqa(b, cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": build_dense(b, d, h * hd, ("embed", "heads"), use_bias=cfg.qkv_bias),
        "wk": build_dense(b, d, kv * hd, ("embed", "kv_heads"), use_bias=cfg.qkv_bias),
        "wv": build_dense(b, d, kv * hd, ("embed", "kv_heads"), use_bias=cfg.qkv_bias),
        "wo": build_dense(b, h * hd, d, ("heads", "embed")),
    }


def gqa_qkv(x: jax.Array, p: dict, cfg: ArchConfig, positions: jax.Array,
            rope: bool = True) -> tuple[jax.Array, jax.Array, jax.Array]:
    b_, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = apply_dense(x, p["wq"]).reshape(b_, s, h, hd)
    k = apply_dense(x, p["wk"]).reshape(b_, s, kv, hd)
    v = apply_dense(x, p["wv"]).reshape(b_, s, kv, hd)
    if rope and cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def gqa_attention(x: jax.Array, p: dict, cfg: ArchConfig, *,
                  positions: jax.Array, causal: bool = True,
                  window: int | None = None) -> jax.Array:
    """Full-sequence (train / prefill) GQA attention."""
    b_, s, _ = x.shape
    q, k, v = gqa_qkv(x, p, cfg, positions)
    n_rep = cfg.num_heads // max(cfg.num_kv_heads, 1)
    k, v = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    if window is not None and s > window:
        o = local_attention(q, k, v, window)
    elif s >= BLOCKWISE_THRESHOLD:
        o = blockwise_attention(q, k, v, causal=causal)
    else:
        o = full_attention(q, k, v, causal=causal)
    o = o.reshape(b_, s, cfg.num_heads * cfg.resolved_head_dim)
    return apply_dense(o, p["wo"])


def gqa_prefill(x: jax.Array, p: dict, cfg: ArchConfig, *,
                positions: jax.Array, max_len: int,
                window: int | None = None,
                causal: bool = True,
                codec: CacheCodec | None = None
                ) -> tuple[jax.Array, KVCache]:
    """Full-sequence attention that also emits this layer's decode cache."""
    codec = codec or FLOAT_CODEC
    b_, s, _ = x.shape
    q, k, v = gqa_qkv(x, p, cfg, positions)
    n_rep = cfg.num_heads // max(cfg.num_kv_heads, 1)
    kf, vf = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    if window is not None and s > window:
        o = local_attention(q, kf, vf, window)
    elif s >= BLOCKWISE_THRESHOLD:
        o = blockwise_attention(q, kf, vf, causal=causal)
    else:
        o = full_attention(q, kf, vf, causal=causal)
    o = apply_dense(o.reshape(b_, s, cfg.num_heads * cfg.resolved_head_dim),
                    p["wo"])
    if window is not None:
        # rolling buffer: row (p % window) holds token p, for the last W
        # tokens (hybrid family — never quantized, see spec validation)
        if codec.quantized:
            raise ValueError("kv_dtype='int8' is unsupported for "
                             "rolling-window attention caches")
        w = min(window, max_len)
        start = max(s - w, 0)
        rows = (jnp.arange(start, start + w) % w) if s >= w else jnp.arange(w)
        src = k[:, start:start + w], v[:, start:start + w]
        ck = jnp.zeros((b_, w) + k.shape[2:], jnp.bfloat16)
        cv = jnp.zeros_like(ck)
        n_src = src[0].shape[1]
        ck = ck.at[:, rows[:n_src]].set(src[0].astype(jnp.bfloat16))
        cv = cv.at[:, rows[:n_src]].set(src[1].astype(jnp.bfloat16))
        return o, KVCache(ck, cv)
    pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
    kq, ks = codec.store(k, jnp.bfloat16)
    vq, vs = codec.store(v, jnp.bfloat16)
    if ks is None:
        return o, KVCache(jnp.pad(kq, pad), jnp.pad(vq, pad))
    return o, KVCache(jnp.pad(kq, pad), jnp.pad(vq, pad),
                      jnp.pad(ks, pad[:-1]), jnp.pad(vs, pad[:-1]))


def mla_prefill(x: jax.Array, p: dict, cfg: ArchConfig, *,
                positions: jax.Array, max_len: int,
                codec: CacheCodec | None = None
                ) -> tuple[jax.Array, MLACache]:
    """MLA prefill: attention output + this layer's latent cache."""
    codec = codec or FLOAT_CODEC
    m = cfg.mla
    b_, s, _ = x.shape
    o = mla_attention(x, p, cfg, positions=positions)
    c_kv, k_rope = _mla_latent(x, p, m, positions, cfg.rope_theta)
    pad = ((0, 0), (0, max_len - s), (0, 0))
    cq, cs = codec.store(c_kv, jnp.bfloat16)
    rq, rs = codec.store(k_rope, jnp.bfloat16)
    if cs is None:
        return o, MLACache(jnp.pad(cq, pad), jnp.pad(rq, pad))
    return o, MLACache(jnp.pad(cq, pad), jnp.pad(rq, pad),
                       jnp.pad(cs, pad[:-1]), jnp.pad(rs, pad[:-1]))


def as_index_vector(cache_index: jax.Array, batch: int) -> jax.Array:
    """Scalar or [B] cache index -> [B] int32 (per-slot decode support)."""
    idx = jnp.asarray(cache_index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (batch,))
    return idx


def _gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, live: jax.Array,
                cfg: ArchConfig, grouped: bool) -> jax.Array:
    """Decode/chunk score/value contraction over a [B, S, kv, hd] view.

    ``live`` is [B, S] (one query lane per slot) or [B, W, S] (the mixed
    step's per-lane causal-vs-cache masks).  Shared by the dense and
    paged layouts: both reduce to the same masked attention once the
    cache has been (gathered into) sequence-major form, which is what
    keeps the two layouts bit-identical.
    """
    b_, nq = q.shape[:2]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    n_rep = h // max(kv, 1)
    if grouped:
        # GQA-grouped contraction: the KV cache is used directly, never
        # materialized at h heads (repeat_kv costs ~2x cache bytes/layer)
        lv = live[:, None, :] if live.ndim == 2 else live      # [B, W, S]
        qg = q.reshape(b_, nq, kv, n_rep, hd)
        s = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
        s = s / math.sqrt(hd)
        s = jnp.where(lv[:, None, None, :, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkrqs,bskd->bqkrd", pr.astype(v.dtype), v)
        return o.reshape(b_, nq, h * hd)
    kf, vf = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    o = full_attention(q, kf, vf, causal=False, kv_len_mask=live)
    return o.reshape(b_, nq, h * hd)


def gqa_decode(x: jax.Array, p: dict, cfg: ArchConfig, cache: KVCache,
               cache_index: jax.Array, *,
               window: int | None = None,
               grouped: bool = False,
               codec: CacheCodec | None = None) -> tuple[jax.Array, KVCache]:
    """One-token decode against a [B, S_max, kv, hd] cache.

    ``cache_index`` is the number of tokens already in the cache — a
    scalar, or a [B] vector for per-slot serving (continuous batching).
    For windowed layers the cache is a rolling buffer of size window.
    ``grouped``: GQA-grouped score contraction (no repeat_kv copy).
    ``codec``: the cache codec; int8 quantizes the new token's K/V row on
    write and fuses the dequant into the attend.
    """
    codec = codec or FLOAT_CODEC
    b_, one, _ = x.shape
    idx_vec = as_index_vector(cache_index, b_)
    positions = idx_vec[:, None]
    q, k_new, v_new = gqa_qkv(x, p, cfg, positions)
    s_max = cache.k.shape[1]
    slot = idx_vec % s_max if window is not None else idx_vec
    rows = jnp.arange(b_)
    kq, ks = codec.store(k_new[:, 0], cache.k.dtype)
    vq, vs = codec.store(v_new[:, 0], cache.v.dtype)
    k, k_sc = cache_put(cache.k, cache.k_scale, (rows, slot), kq, ks)
    v, v_sc = cache_put(cache.v, cache.v_scale, (rows, slot), vq, vs)
    idx = jnp.arange(s_max)
    if window is not None:  # rolling-buffer validity, per slot
        live = (idx[None, :] <= slot[:, None]) | (idx_vec[:, None] >= s_max)
    else:
        live = idx[None, :] <= idx_vec[:, None]
    o = _gqa_attend(q, codec.load(k, k_sc, x.dtype),
                    codec.load(v, v_sc, x.dtype), live, cfg, grouped)
    return apply_dense(o, p["wo"]), KVCache(k, v, k_sc, v_sc)


def paged_write_slot(idx_vec: jax.Array, block_tables: jax.Array,
                     block_size: int) -> tuple[jax.Array, jax.Array]:
    """(physical block, in-block offset) for each slot's next cache write.

    ``idx_vec`` is [B] (one write per slot) or [B, W] (the mixed step's
    chunk lanes).  An index past the addressable range (cache full, slot
    finished but not yet harvested, dead chunk lane) is routed to the
    null block, so the fused step stays safe with zero host intervention.
    """
    t_max = block_tables.shape[1] * block_size
    safe = jnp.minimum(idx_vec, t_max - 1)
    if idx_vec.ndim == 1:
        blk = jnp.take_along_axis(block_tables, (safe // block_size)[:, None],
                                  axis=1)[:, 0]
    else:
        blk = jnp.take_along_axis(block_tables, safe // block_size, axis=1)
    blk = jnp.where(idx_vec < t_max, blk, NULL_BLOCK)
    return blk, safe % block_size


def gqa_decode_paged(x: jax.Array, p: dict, cfg: ArchConfig, cache: KVCache,
                     cache_index: jax.Array, block_tables: jax.Array, *,
                     grouped: bool = False,
                     impl: str = "gather",
                     codec: CacheCodec | None = None
                     ) -> tuple[jax.Array, KVCache]:
    """One-token decode against the pooled [NB, bs, kv, hd] cache.

    ``block_tables``: [B, blocks_per_slot] int32 — logical block i of a
    slot lives in pool row ``block_tables[slot, i]`` (0 = null block).
    ``impl``: "gather" (XLA gather + the dense contraction, bit-identical
    to the dense layout) or "pallas" (the fused paged-decode kernel).
    With an int8 codec the per-(block entry, kv-head) scales ride the
    same block tables: gathered beside the values on the XLA path, walked
    by the same scalar-prefetched index maps inside the Pallas kernel.
    """
    codec = codec or FLOAT_CODEC
    b_, one, _ = x.shape
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    bs = cache.k.shape[1]
    idx_vec = as_index_vector(cache_index, b_)
    q, k_new, v_new = gqa_qkv(x, p, cfg, idx_vec[:, None])
    blk, off = paged_write_slot(idx_vec, block_tables, bs)
    kq, ks = codec.store(k_new[:, 0], cache.k.dtype)
    vq, vs = codec.store(v_new[:, 0], cache.v.dtype)
    k, k_sc = cache_put(cache.k, cache.k_scale, (blk, off), kq, ks)
    v, v_sc = cache_put(cache.v, cache.v_scale, (blk, off), vq, vs)
    t_max = block_tables.shape[1] * bs
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_decode_attention
        lengths = jnp.minimum(idx_vec + 1, t_max)
        o = paged_decode_attention(
            q[:, 0], k, v, block_tables, lengths,
            k_scale=k_sc, v_scale=v_sc,
            interpret=interpret_default())
        o = o.reshape(b_, one, cfg.num_heads * hd)
    else:
        kg = gather_view(codec, k, k_sc, block_tables,
                          (b_, t_max, kv, hd), x.dtype)
        vg = gather_view(codec, v, v_sc, block_tables,
                          (b_, t_max, kv, hd), x.dtype)
        live = jnp.arange(t_max)[None, :] <= idx_vec[:, None]
        o = _gqa_attend(q, kg, vg, live, cfg, grouped)
    return apply_dense(o, p["wo"]), KVCache(k, v, k_sc, v_sc)


# ---------------------------------------------------------------------------
# Mixed chunk/decode step — chunked prefill fused with decode
# ---------------------------------------------------------------------------
def gqa_mixed(x: jax.Array, p: dict, cfg: ArchConfig, cache: KVCache,
              start: jax.Array, n_live: jax.Array, *,
              grouped: bool = False,
              codec: CacheCodec | None = None) -> tuple[jax.Array, KVCache]:
    """W-lane chunk/decode attention against the dense [B, S_max] cache.

    ``x`` is [B, W, d]: lane ``l`` of slot ``b`` sits at cache position
    ``start[b] + l``; only the first ``n_live[b]`` lanes are real (a
    decoding slot uses one, a prefilling slot up to a chunk, an idle slot
    none).  Chunk K/V are written *before* the attend, so one
    causal-vs-cache mask covers intra-chunk causality and the prior
    cache — the math reduces exactly to ``gqa_decode`` at W == 1, and
    replaying a prompt chunk-by-chunk reproduces ``gqa_prefill``'s
    logits bit-for-bit below ``BLOCKWISE_THRESHOLD`` (above it bucketed
    prefill switches to the streaming softmax, whose accumulation order
    this unfused path does not mirror).
    """
    codec = codec or FLOAT_CODEC
    b_, w, _ = x.shape
    positions = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    q, k_new, v_new = gqa_qkv(x, p, cfg, positions)
    s_max = cache.k.shape[1]
    # dead lanes scatter out of bounds; jax drops those updates, so no
    # lane ever collides with a live write
    pos = jnp.where(masking.lane_mask(w, n_live), positions, s_max)
    rows = jnp.arange(b_)[:, None]
    kq, ks = codec.store(k_new, cache.k.dtype)
    vq, vs = codec.store(v_new, cache.v.dtype)
    k, k_sc = cache_put(cache.k, cache.k_scale, (rows, pos), kq, ks)
    v, v_sc = cache_put(cache.v, cache.v_scale, (rows, pos), vq, vs)
    live = masking.chunk_causal_mask(s_max, start, w)
    o = _gqa_attend(q, codec.load(k, k_sc, x.dtype),
                    codec.load(v, v_sc, x.dtype), live, cfg, grouped)
    return apply_dense(o, p["wo"]), KVCache(k, v, k_sc, v_sc)


def gqa_mixed_paged(x: jax.Array, p: dict, cfg: ArchConfig, cache: KVCache,
                    start: jax.Array, n_live: jax.Array,
                    block_tables: jax.Array, *, grouped: bool = False,
                    impl: str = "gather",
                    interpret: bool | None = None,
                    codec: CacheCodec | None = None
                    ) -> tuple[jax.Array, KVCache]:
    """W-lane chunk/decode attention against the pooled block cache.

    ``impl="gather"`` materializes the block-table view and reuses the
    dense contraction (bit-identical to ``gqa_mixed``); ``"pallas"``
    streams pool blocks through the fused chunked-prefill kernel (the
    int8 codec's scales ride its scalar-prefetched block-table walk).
    """
    codec = codec or FLOAT_CODEC
    b_, w, _ = x.shape
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    bs = cache.k.shape[1]
    positions = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    q, k_new, v_new = gqa_qkv(x, p, cfg, positions)
    t_max = block_tables.shape[1] * bs
    # dead lanes -> index t_max -> the null block absorbs them
    idx_w = jnp.where(masking.lane_mask(w, n_live), positions, t_max)
    blk, off = paged_write_slot(idx_w, block_tables, bs)
    kq, ks = codec.store(k_new, cache.k.dtype)
    vq, vs = codec.store(v_new, cache.v.dtype)
    k, k_sc = cache_put(cache.k, cache.k_scale, (blk, off), kq, ks)
    v, v_sc = cache_put(cache.v, cache.v_scale, (blk, off), vq, vs)
    if impl == "pallas":
        from repro.kernels.chunked_prefill import chunked_prefill_attention
        if interpret is None:
            interpret = interpret_default()
        o = chunked_prefill_attention(q, k, v, block_tables, start,
                                      k_scale=k_sc, v_scale=v_sc,
                                      interpret=interpret)
        o = o.reshape(b_, w, cfg.num_heads * hd)
    else:
        kg = gather_view(codec, k, k_sc, block_tables,
                          (b_, t_max, kv, hd), x.dtype)
        vg = gather_view(codec, v, v_sc, block_tables,
                          (b_, t_max, kv, hd), x.dtype)
        live = masking.chunk_causal_mask(t_max, start, w)
        o = _gqa_attend(q, kg, vg, live, cfg, grouped)
    return apply_dense(o, p["wo"]), KVCache(k, v, k_sc, v_sc)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------
class MLACache(NamedTuple):
    """Latent cache: the compressed kv + shared rope key (paper-faithful
    MLA).  Under the int8 codec the values are int8 and one f32 scale per
    cached position rides in ``c_scale``/``r_scale`` (None in compute
    mode — see ``KVCache``)."""

    c_kv: jax.Array    # [B, S_max, kv_lora]
    k_rope: jax.Array  # [B, S_max, rope_dim]
    c_scale: jax.Array | None = None
    r_scale: jax.Array | None = None


def build_mla(b, cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    return {
        "q_down": build_dense(b, d, m.q_lora_rank, ("embed", "q_lora")),
        "q_norm": {"scale": b.param((m.q_lora_rank,), ("q_lora",), init="ones")},
        "q_up": build_dense(b, m.q_lora_rank, h * m.qk_head_dim, ("q_lora", "heads")),
        "kv_down": build_dense(b, d, m.kv_lora_rank + m.qk_rope_head_dim,
                               ("embed", "kv_lora")),
        "kv_norm": {"scale": b.param((m.kv_lora_rank,), ("kv_lora",), init="ones")},
        "k_up": build_dense(b, m.kv_lora_rank, h * m.qk_nope_head_dim,
                            ("kv_lora", "heads")),
        "v_up": build_dense(b, m.kv_lora_rank, h * m.v_head_dim,
                            ("kv_lora", "heads")),
        "wo": build_dense(b, h * m.v_head_dim, d, ("heads", "embed")),
    }


def _mla_q(x, p, m: MLAConfig, h: int, positions, theta):
    b_, s, _ = x.shape
    cq = layers.rmsnorm(apply_dense(x, p["q_down"]), p["q_norm"]["scale"])
    q = apply_dense(cq, p["q_up"]).reshape(b_, s, h, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, theta)
    return q_nope, q_rope


def _mla_latent(x, p, m: MLAConfig, positions, theta):
    b_, s, _ = x.shape
    ckv_full = apply_dense(x, p["kv_down"])
    c_kv = layers.rmsnorm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"]["scale"])
    k_rope = ckv_full[..., m.kv_lora_rank:].reshape(b_, s, 1, m.qk_rope_head_dim)
    k_rope = apply_rope(k_rope, positions, theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(x: jax.Array, p: dict, cfg: ArchConfig, *,
                  positions: jax.Array) -> jax.Array:
    """Train/prefill MLA: expand latents to per-head K/V (naive path)."""
    m, h = cfg.mla, cfg.num_heads
    b_, s, _ = x.shape
    q_nope, q_rope = _mla_q(x, p, m, h, positions, cfg.rope_theta)
    c_kv, k_rope = _mla_latent(x, p, m, positions, cfg.rope_theta)
    k_nope = apply_dense(c_kv, p["k_up"]).reshape(b_, s, h, m.qk_nope_head_dim)
    v = apply_dense(c_kv, p["v_up"]).reshape(b_, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None], (b_, s, h, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_head_dim)
    if s >= BLOCKWISE_THRESHOLD:
        o = blockwise_attention(q, k, v, causal=True, scale=scale)
    else:
        o = full_attention(q, k, v, causal=True, scale=scale)
    return apply_dense(o.reshape(b_, s, h * m.v_head_dim), p["wo"])


def _mla_attend(x: jax.Array, p: dict, cfg: ArchConfig, q_nope: jax.Array,
                q_rope: jax.Array, c_kv: jax.Array, k_rope: jax.Array,
                live: jax.Array) -> jax.Array:
    """Absorbed-matmul contraction over a sequence-major latent view
    (c_kv [B, S, rank], k_rope [B, S, rope_dim]) — shared by both cache
    layouts, which is what keeps dense and paged decode bit-identical."""
    m, h = cfg.mla, cfg.num_heads
    b_, one = q_nope.shape[:2]
    wk = p["k_up"]["kernel"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    # absorb k_up into the query: q_lat [B,1,h,kv_lora] (f32: one token only)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))
    s_lat = jnp.einsum("bqhl,bkl->bhqk", q_lat, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scores = (s_lat + s_rope) / math.sqrt(m.qk_head_dim)
    scores = jnp.where(live, scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    # attend in latent space, then expand once per step via v_up
    o_lat = jnp.einsum("bhqk,bkl->bqhl", pr.astype(c_kv.dtype), c_kv)
    wv = jnp.transpose(p["v_up"]["kernel"].reshape(m.kv_lora_rank, h, m.v_head_dim),
                       (1, 0, 2)).astype(x.dtype)
    o = jnp.einsum("bqhl,hld->bqhd", o_lat, wv)
    return apply_dense(o.reshape(b_, one, h * m.v_head_dim), p["wo"])


def mla_decode(x: jax.Array, p: dict, cfg: ArchConfig, cache: MLACache,
               cache_index: jax.Array,
               codec: CacheCodec | None = None) -> tuple[jax.Array, MLACache]:
    """Absorbed-matmul MLA decode: score and value contraction happen in the
    latent space, so per-step FLOPs/bytes scale with kv_lora_rank."""
    codec = codec or FLOAT_CODEC
    m, h = cfg.mla, cfg.num_heads
    b_, one, _ = x.shape
    idx_vec = as_index_vector(cache_index, b_)
    positions = idx_vec[:, None]
    q_nope, q_rope = _mla_q(x, p, m, h, positions, cfg.rope_theta)
    c_new, kr_new = _mla_latent(x, p, m, positions, cfg.rope_theta)
    rows = jnp.arange(b_)
    cq, cs = codec.store(c_new[:, 0], cache.c_kv.dtype)
    rq, rs = codec.store(kr_new[:, 0], cache.k_rope.dtype)
    c_kv, c_sc = cache_put(cache.c_kv, cache.c_scale, (rows, idx_vec),
                            cq, cs)
    k_rope, r_sc = cache_put(cache.k_rope, cache.r_scale, (rows, idx_vec),
                              rq, rs)
    s_max = c_kv.shape[1]
    live = (jnp.arange(s_max)[None] <= idx_vec[:, None])[:, None, None, :]
    out = _mla_attend(x, p, cfg, q_nope, q_rope,
                      codec.load(c_kv, c_sc, x.dtype),
                      codec.load(k_rope, r_sc, x.dtype), live)
    return out, MLACache(c_kv, k_rope, c_sc, r_sc)


def mla_decode_paged(x: jax.Array, p: dict, cfg: ArchConfig, cache: MLACache,
                     cache_index: jax.Array, block_tables: jax.Array,
                     codec: CacheCodec | None = None
                     ) -> tuple[jax.Array, MLACache]:
    """MLA decode against pooled latent blocks ([NB, bs, rank] c_kv and
    [NB, bs, rope_dim] k_rope addressed through the same block tables)."""
    codec = codec or FLOAT_CODEC
    m, h = cfg.mla, cfg.num_heads
    b_, one, _ = x.shape
    bs = cache.c_kv.shape[1]
    idx_vec = as_index_vector(cache_index, b_)
    positions = idx_vec[:, None]
    q_nope, q_rope = _mla_q(x, p, m, h, positions, cfg.rope_theta)
    c_new, kr_new = _mla_latent(x, p, m, positions, cfg.rope_theta)
    blk, off = paged_write_slot(idx_vec, block_tables, bs)
    cq, cs = codec.store(c_new[:, 0], cache.c_kv.dtype)
    rq, rs = codec.store(kr_new[:, 0], cache.k_rope.dtype)
    c_kv, c_sc = cache_put(cache.c_kv, cache.c_scale, (blk, off), cq, cs)
    k_rope, r_sc = cache_put(cache.k_rope, cache.r_scale, (blk, off),
                              rq, rs)
    t_max = block_tables.shape[1] * bs
    ckv_g = gather_view(codec, c_kv, c_sc, block_tables,
                         (b_, t_max, m.kv_lora_rank), x.dtype)
    kr_g = gather_view(codec, k_rope, r_sc, block_tables,
                        (b_, t_max, m.qk_rope_head_dim), x.dtype)
    live = (jnp.arange(t_max)[None] <= idx_vec[:, None])[:, None, None, :]
    out = _mla_attend(x, p, cfg, q_nope, q_rope, ckv_g, kr_g, live)
    return out, MLACache(c_kv, k_rope, c_sc, r_sc)


def mla_mixed(x: jax.Array, p: dict, cfg: ArchConfig, cache: MLACache,
              start: jax.Array, n_live: jax.Array,
              codec: CacheCodec | None = None
              ) -> tuple[jax.Array, MLACache]:
    """W-lane chunk/decode MLA against the dense latent cache (absorbed
    contraction; see ``gqa_mixed`` for the lane protocol)."""
    codec = codec or FLOAT_CODEC
    m, h = cfg.mla, cfg.num_heads
    b_, w, _ = x.shape
    positions = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(x, p, m, h, positions, cfg.rope_theta)
    c_new, kr_new = _mla_latent(x, p, m, positions, cfg.rope_theta)
    s_max = cache.c_kv.shape[1]
    pos = jnp.where(masking.lane_mask(w, n_live), positions, s_max)
    rows = jnp.arange(b_)[:, None]
    cq, cs = codec.store(c_new, cache.c_kv.dtype)
    rq, rs = codec.store(kr_new, cache.k_rope.dtype)
    c_kv, c_sc = cache_put(cache.c_kv, cache.c_scale, (rows, pos), cq, cs)
    k_rope, r_sc = cache_put(cache.k_rope, cache.r_scale, (rows, pos),
                              rq, rs)
    live = masking.chunk_causal_mask(s_max, start, w)[:, None]  # [B,1,W,S]
    out = _mla_attend(x, p, cfg, q_nope, q_rope,
                      codec.load(c_kv, c_sc, x.dtype),
                      codec.load(k_rope, r_sc, x.dtype), live)
    return out, MLACache(c_kv, k_rope, c_sc, r_sc)


def mla_mixed_paged(x: jax.Array, p: dict, cfg: ArchConfig, cache: MLACache,
                    start: jax.Array, n_live: jax.Array,
                    block_tables: jax.Array,
                    codec: CacheCodec | None = None
                    ) -> tuple[jax.Array, MLACache]:
    """W-lane chunk/decode MLA against the pooled latent block cache."""
    codec = codec or FLOAT_CODEC
    m, h = cfg.mla, cfg.num_heads
    b_, w, _ = x.shape
    bs = cache.c_kv.shape[1]
    positions = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(x, p, m, h, positions, cfg.rope_theta)
    c_new, kr_new = _mla_latent(x, p, m, positions, cfg.rope_theta)
    t_max = block_tables.shape[1] * bs
    idx_w = jnp.where(masking.lane_mask(w, n_live), positions, t_max)
    blk, off = paged_write_slot(idx_w, block_tables, bs)
    cq, cs = codec.store(c_new, cache.c_kv.dtype)
    rq, rs = codec.store(kr_new, cache.k_rope.dtype)
    c_kv, c_sc = cache_put(cache.c_kv, cache.c_scale, (blk, off), cq, cs)
    k_rope, r_sc = cache_put(cache.k_rope, cache.r_scale, (blk, off),
                              rq, rs)
    ckv_g = gather_view(codec, c_kv, c_sc, block_tables,
                         (b_, t_max, m.kv_lora_rank), x.dtype)
    kr_g = gather_view(codec, k_rope, r_sc, block_tables,
                        (b_, t_max, m.qk_rope_head_dim), x.dtype)
    live = masking.chunk_causal_mask(t_max, start, w)[:, None]
    out = _mla_attend(x, p, cfg, q_nope, q_rope, ckv_g, kr_g, live)
    return out, MLACache(c_kv, k_rope, c_sc, r_sc)
