"""Mamba-1 selective SSM block (falcon-mamba-7b).

The ADAPTOR technique targets dense matmuls; here the in/x/dt/out
projections route through ``layers.dense`` (tiled on TPU), while the
selective recurrence itself has no paper analogue (documented in
DESIGN.md §Arch-applicability).  The recurrence is a ``lax.scan`` over
time with an O(d_inner * d_state) carry — constant memory in sequence
length, which is what makes the ``long_500k`` cell runnable.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import build_dense, apply_dense


class SSMState(NamedTuple):
    """Decode-time recurrent state."""

    conv: jax.Array  # [B, K-1, d_inner] trailing conv window
    h: jax.Array     # [B, d_inner, d_state] SSM state (f32)


def dims(cfg: ArchConfig) -> tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank, s.state_dim


def build_ssm(b, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, dt_rank, n = dims(cfg)
    return {
        "in_proj": build_dense(b, d, 2 * d_inner, ("embed", "dinner")),
        "conv_w": b.param((s.conv_kernel, d_inner), (None, "dinner"),
                          init="normal", scale=1.0 / math.sqrt(s.conv_kernel)),
        "conv_b": b.param((d_inner,), ("dinner",), init="zeros"),
        "x_proj": build_dense(b, d_inner, dt_rank + 2 * n, ("dinner", None)),
        "dt_proj": build_dense(b, dt_rank, d_inner, (None, "dinner"),
                               use_bias=True),
        "a_log": b.param((d_inner, n), ("dinner", "state"), init="ones"),
        "d_skip": b.param((d_inner,), ("dinner",), init="ones"),
        "out_proj": build_dense(b, d_inner, d, ("dinner", "embed")),
    }


def _split_proj(xz: jax.Array, d_inner: int) -> tuple[jax.Array, jax.Array]:
    return xz[..., :d_inner], xz[..., d_inner:]


def _ssm_inputs(x_conv: jax.Array, p: dict, cfg: ArchConfig):
    """x_conv: [..., d_inner] -> (dt, B, C) selective parameters."""
    d_inner, dt_rank, n = dims(cfg)
    proj = apply_dense(x_conv, p["x_proj"])
    dt = jax.nn.softplus(apply_dense(proj[..., :dt_rank], p["dt_proj"])
                         .astype(jnp.float32))                     # [..., d_inner]
    b_mat = proj[..., dt_rank: dt_rank + n].astype(jnp.float32)    # [..., n]
    c_mat = proj[..., dt_rank + n:].astype(jnp.float32)            # [..., n]
    return dt, b_mat, c_mat


def _discretize(dt, b_mat, x, a_log):
    """ZOH-style discretization: returns (A_bar, Bx) both [..., d_inner, n]."""
    a = -jnp.exp(a_log.astype(jnp.float32))                        # [d_inner, n]
    a_bar = jnp.exp(dt[..., None] * a)                             # [..., d_inner, n]
    bx = dt[..., None] * b_mat[..., None, :] * x[..., None].astype(jnp.float32)
    return a_bar, bx


def ssm_forward(x: jax.Array, p: dict, cfg: ArchConfig) -> jax.Array:
    """Full-sequence mamba block.  x: [B, S, d] -> [B, S, d]."""
    s_cfg = cfg.ssm
    b_, s, d = x.shape
    d_inner, _, n = dims(cfg)
    xz = apply_dense(x, p["in_proj"])
    xi, z = _split_proj(xz, d_inner)
    # causal depthwise conv along time
    pad = s_cfg.conv_kernel - 1
    xp = jnp.pad(xi, ((0, 0), (pad, 0), (0, 0)))
    windows = jnp.stack([xp[:, i: i + s] for i in range(s_cfg.conv_kernel)], axis=-1)
    # window index k holds x[t-(K-1)+k]; conv weight j applies to x[t-j]
    x_conv = jnp.einsum("bsdk,kd->bsd", windows, p["conv_w"].astype(x.dtype)[::-1])
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(x.dtype))
    dt, b_mat, c_mat = _ssm_inputs(x_conv, p, cfg)
    a_bar, bx = _discretize(dt, b_mat, x_conv, p["a_log"])

    def step(h, inp):
        a_t, bx_t, c_t = inp                  # [B, d_inner, n], ..., [B, n]
        h = a_t * h + bx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((b_, d_inner, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (a_bar.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3),
         c_mat.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2)                 # [B, S, d_inner]
    y = y + x_conv.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_dense(y, p["out_proj"])


def ssm_prefill(x: jax.Array, p: dict, cfg: ArchConfig
                ) -> tuple[jax.Array, SSMState]:
    """Full-sequence forward that also returns the decode state."""
    s_cfg = cfg.ssm
    b_, s, d = x.shape
    d_inner, _, n = dims(cfg)
    xz = apply_dense(x, p["in_proj"])
    xi, z = _split_proj(xz, d_inner)
    pad = s_cfg.conv_kernel - 1
    xp = jnp.pad(xi, ((0, 0), (pad, 0), (0, 0)))
    windows = jnp.stack([xp[:, i: i + s] for i in range(s_cfg.conv_kernel)], axis=-1)
    x_conv = jnp.einsum("bsdk,kd->bsd", windows, p["conv_w"].astype(x.dtype)[::-1])
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(x.dtype))
    dt, b_mat, c_mat = _ssm_inputs(x_conv, p, cfg)
    a_bar, bx = _discretize(dt, b_mat, x_conv, p["a_log"])

    def step(h, inp):
        a_t, bx_t, c_t = inp
        h = a_t * h + bx_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    h0 = jnp.zeros((b_, d_inner, n), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0, (a_bar.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3),
                   c_mat.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2)
    y = y + x_conv.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = apply_dense(y, p["out_proj"])
    conv_tail = xp[:, -pad:] if pad else xi[:, :0]
    return out, SSMState(conv_tail.astype(jnp.bfloat16), h_final)


def ssm_init_state(cfg: ArchConfig, batch: int, abstract: bool = False):
    s_cfg = cfg.ssm
    d_inner, _, n = dims(cfg)
    conv_shape = (batch, s_cfg.conv_kernel - 1, d_inner)
    h_shape = (batch, d_inner, n)
    if abstract:
        return SSMState(jax.ShapeDtypeStruct(conv_shape, jnp.bfloat16),
                        jax.ShapeDtypeStruct(h_shape, jnp.float32))
    return SSMState(jnp.zeros(conv_shape, jnp.bfloat16),
                    jnp.zeros(h_shape, jnp.float32))


def ssm_decode(x: jax.Array, p: dict, cfg: ArchConfig,
               state: SSMState) -> tuple[jax.Array, SSMState]:
    """One-token decode.  x: [B, 1, d]."""
    b_, one, d = x.shape
    d_inner, _, n = dims(cfg)
    xz = apply_dense(x[:, 0], p["in_proj"])
    xi, z = _split_proj(xz, d_inner)
    window = jnp.concatenate([state.conv.astype(xi.dtype), xi[:, None]], axis=1)
    x_conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype)[::-1])
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(x.dtype))
    dt, b_mat, c_mat = _ssm_inputs(x_conv, p, cfg)
    a_bar, bx = _discretize(dt, b_mat, x_conv, p["a_log"])
    h = a_bar * state.h + bx
    y = jnp.einsum("bdn,bn->bd", h, c_mat)
    y = y + x_conv.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = apply_dense(y, p["out_proj"])[:, None]
    return out, SSMState(window[:, 1:].astype(state.conv.dtype), h)
