"""Shared neural layers: norms, activations, RoPE, projections.

Pure-functional JAX; every matmul routes through ``dense`` so the ADAPTOR
tiled-kernel path (``repro.kernels``) and the XLA path are interchangeable via
``repro.models.backend``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import backend


# --------------------------------------------------------------------------
# Normalization (paper §3.5 — the LN unit)
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + 0.0) * weight.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x: jax.Array, p: dict, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def build_norm(b, d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": b.param((d,), ("embed",), init="ones")}
    return {"scale": b.param((d,), ("embed",), init="ones"),
            "bias": b.param((d,), ("embed",), init="zeros")}


# --------------------------------------------------------------------------
# Activations (paper §3.4 — activation unit; Eq. 5-7)
# --------------------------------------------------------------------------
def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


# --------------------------------------------------------------------------
# Dense projection — single entry point for all matmuls
# --------------------------------------------------------------------------
def dense(x: jax.Array, w, bias: jax.Array | None = None) -> jax.Array:
    """y = x @ w (+ bias).  Routed through the active matmul backend so the
    ADAPTOR Pallas tiled kernel can replace XLA dot on TPU.  ``w`` may be
    an int8 ``QTensor`` (the paper's C6 serving path): the weight is read
    from HBM at 1 byte/elem and dequantized on the fly (fused on TPU)."""
    from repro.core.quant import QTensor

    if isinstance(w, QTensor):
        if backend.active_impl() == "pallas" and w.values.ndim == 2:
            # deployment path: dynamic activation quant + the C6 int8
            # Pallas kernel — the weight never leaves int8 on the wire
            from repro.kernels import ops

            y = ops.quantized_dense(x, w)
            if bias is not None:
                y = y + bias.astype(y.dtype)
            return y
        w = w.values.astype(x.dtype) * w.scale.astype(x.dtype)
    y = backend.matmul(x, w)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def build_dense(b, d_in: int, d_out: int, axes: tuple[str | None, str | None],
                use_bias: bool = False, name_axes_bias: str | None = None) -> dict:
    p = {"kernel": b.param((d_in, d_out), axes)}
    if use_bias:
        p["bias"] = b.param((d_out,), (name_axes_bias if name_axes_bias else axes[1],),
                            init="zeros")
    return p


def apply_dense(x: jax.Array, p: dict) -> jax.Array:
    from repro.core.quant import QTensor

    k = p["kernel"]
    if not isinstance(k, QTensor):
        k = k.astype(x.dtype)
    return dense(x, k, p.get("bias"))


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)  # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # [..., S, D/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------
def build_embedding(b, vocab: int, d: int) -> dict:
    return {"table": b.param((vocab, d), ("vocab", "embed"), scale=0.02)}


def _maybe_dequant(table, dtype):
    from repro.core.quant import QTensor

    if isinstance(table, QTensor):
        return table.values.astype(dtype) * table.scale.astype(dtype)
    return table.astype(dtype)


def embed(tokens: jax.Array, p: dict, dtype=jnp.bfloat16) -> jax.Array:
    from repro.core.quant import QTensor

    t = p["table"]
    if isinstance(t, QTensor):  # per-row int8: gather rows + row scales
        return t.values[tokens].astype(dtype) * t.scale[tokens].astype(dtype)
    return t.astype(dtype)[tokens]


def unembed(x: jax.Array, p: dict) -> jax.Array:
    """Logits = x @ table^T, in f32 for a stable softmax/xent."""
    table = _maybe_dequant(p["table"], jnp.float32)
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table)
