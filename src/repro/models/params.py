"""Parameter construction with logical sharding axes.

``ParamBuilder`` runs the *same* structural code in three modes:

* ``init``     — real arrays (used by smoke tests / examples on CPU),
* ``abstract`` — ``jax.ShapeDtypeStruct`` leaves (used by the multi-pod
  dry-run: no allocation ever happens for full-size configs),
* ``axes``     — ``jax.sharding.PartitionSpec`` leaves holding *logical* axis
  names; ``repro.distributed.sharding`` translates them to mesh axes.

This mirrors ADAPTOR's separation between the synthesized hardware shape
(abstract structure + tiling) and the bits that flow through it at runtime.
"""
from __future__ import annotations

import contextlib
import math
from typing import Iterator

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class ParamBuilder:
    MODES = ("init", "abstract", "axes")

    def __init__(self, mode: str = "init", rng: jax.Array | None = None,
                 dtype=jnp.float32):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, got {mode!r}")
        if mode == "init" and rng is None:
            raise ValueError("init mode requires an rng key")
        self.mode = mode
        self.rng = rng
        self.dtype = dtype
        self._counter = 0
        self._prefix_shape: tuple[int, ...] = ()
        self._prefix_axes: tuple[str | None, ...] = ()

    @contextlib.contextmanager
    def stacked(self, n: int, axis_name: str | None = "layers") -> Iterator[None]:
        """Prepend a stacked-layer dimension to every param created inside."""
        old_shape, old_axes = self._prefix_shape, self._prefix_axes
        self._prefix_shape = old_shape + (n,)
        self._prefix_axes = old_axes + (axis_name,)
        try:
            yield
        finally:
            self._prefix_shape, self._prefix_axes = old_shape, old_axes

    def param(self, shape: tuple[int, ...], axes: tuple[str | None, ...],
              init: str = "normal", scale: float | None = None, dtype=None):
        if len(shape) != len(axes):
            raise ValueError(f"shape {shape} / axes {axes} rank mismatch")
        shape = self._prefix_shape + tuple(shape)
        axes = self._prefix_axes + tuple(axes)
        dtype = dtype or self.dtype
        if self.mode == "axes":
            return P(*axes)
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        key = jax.random.fold_in(self.rng, self._counter)
        self._counter += 1
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            if scale is None:
                # fan-in scaled, matching standard transformer init
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            return (scale * jax.random.normal(key, shape)).astype(dtype)
        if init == "uniform":
            scale = 1.0 if scale is None else scale
            return (scale * jax.random.uniform(key, shape, minval=-1.0)).astype(dtype)
        raise ValueError(f"unknown init {init!r}")


def build_in_all_modes(build_fn, cfg, rng=None, dtype=jnp.float32):
    """Convenience: returns (params, abstract, axes) for one builder fn."""
    rng = jax.random.PRNGKey(0) if rng is None else rng
    params = build_fn(ParamBuilder("init", rng, dtype), cfg)
    abstract = build_fn(ParamBuilder("abstract", dtype=dtype), cfg)
    axes = build_fn(ParamBuilder("axes", dtype=dtype), cfg)
    return params, abstract, axes
