"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The recurrent block: two input branches (value + gate), a short temporal
conv, the Real-Gated Linear Recurrent Unit, and an output projection.
Gates are block-diagonal linears (one block per head) per the Griffin
paper.  All projections route through ``layers.dense`` (ADAPTOR-tiled on
TPU); the recurrence is a ``lax.scan`` with an O(width) carry.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import build_dense, apply_dense

# Griffin fixes c = 8 in a_t = a^(c * softplus(param) * r_t)
_C = 8.0
_CONV_K = 4


class LRUState(NamedTuple):
    conv: jax.Array  # [B, K-1, width]
    h: jax.Array     # [B, width] recurrent state (f32)


def width(cfg: ArchConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def _heads(cfg: ArchConfig) -> int:
    return max(cfg.num_heads, 1)


def build_rglru(b, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    w = width(cfg)
    nh = _heads(cfg)
    blk = w // nh
    return {
        "in_x": build_dense(b, d, w, ("embed", "lru")),
        "in_gate": build_dense(b, d, w, ("embed", "lru")),
        "conv_w": b.param((_CONV_K, w), (None, "lru"),
                          init="normal", scale=1.0 / math.sqrt(_CONV_K)),
        "conv_b": b.param((w,), ("lru",), init="zeros"),
        # block-diagonal gates: [heads, blk, blk]
        "gate_in_w": b.param((nh, blk, blk), ("heads", None, "lru")),
        "gate_in_b": b.param((w,), ("lru",), init="zeros"),
        "gate_a_w": b.param((nh, blk, blk), ("heads", None, "lru")),
        "gate_a_b": b.param((w,), ("lru",), init="zeros"),
        "a_param": b.param((w,), ("lru",), init="uniform", scale=1.0),
        "out": build_dense(b, w, d, ("lru", "embed")),
    }


def _block_diag(x: jax.Array, w_blocks: jax.Array, bias: jax.Array) -> jax.Array:
    """x: [..., w] through block-diagonal weight [nh, blk, blk]."""
    nh, blk, _ = w_blocks.shape
    xs = x.reshape(x.shape[:-1] + (nh, blk))
    y = jnp.einsum("...hi,hij->...hj", xs, w_blocks.astype(x.dtype))
    return y.reshape(x.shape) + bias.astype(x.dtype)


def _gates(x_conv: jax.Array, p: dict):
    """Returns (a_t, gated_input) for the recurrence, in f32."""
    r = jax.nn.sigmoid(_block_diag(x_conv, p["gate_a_w"], p["gate_a_b"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(x_conv, p["gate_in_w"], p["gate_in_b"])
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # input normalization sqrt(1 - a^2) keeps the state variance bounded
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    gated = i * x_conv.astype(jnp.float32) * mult
    return a, gated


def _conv_full(xi: jax.Array, p: dict) -> jax.Array:
    b_, s, w = xi.shape
    xp = jnp.pad(xi, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    windows = jnp.stack([xp[:, i: i + s] for i in range(_CONV_K)], axis=-1)
    return jnp.einsum("bswk,kw->bsw", windows,
                      p["conv_w"].astype(xi.dtype)[::-1]) \
        + p["conv_b"].astype(xi.dtype)


def rglru_forward(x: jax.Array, p: dict, cfg: ArchConfig) -> jax.Array:
    """Full-sequence recurrent block.  x: [B, S, d]."""
    b_, s, d = x.shape
    xi = apply_dense(x, p["in_x"])
    gate = jax.nn.gelu(apply_dense(x, p["in_gate"]), approximate=True)
    x_conv = _conv_full(xi, p)
    a, gated = _gates(x_conv, p)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    h0 = jnp.zeros((b_, x_conv.shape[-1]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    y = hs.transpose(1, 0, 2).astype(x.dtype) * gate
    return apply_dense(y, p["out"])


def rglru_prefill(x: jax.Array, p: dict, cfg: ArchConfig
                  ) -> tuple[jax.Array, LRUState]:
    """Full-sequence forward that also returns the decode state."""
    b_, s, d = x.shape
    xi = apply_dense(x, p["in_x"])
    gate = jax.nn.gelu(apply_dense(x, p["in_gate"]), approximate=True)
    x_conv = _conv_full(xi, p)
    a, gated = _gates(x_conv, p)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    h0 = jnp.zeros((b_, x_conv.shape[-1]), jnp.float32)
    h_final, hs = jax.lax.scan(step, h0,
                               (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    y = hs.transpose(1, 0, 2).astype(x.dtype) * gate
    out = apply_dense(y, p["out"])
    pad = _CONV_K - 1
    xp = jnp.pad(xi, ((0, 0), (pad, 0), (0, 0)))
    return out, LRUState(xp[:, -pad:].astype(jnp.bfloat16), h_final)


def rglru_init_state(cfg: ArchConfig, batch: int, abstract: bool = False):
    w = width(cfg)
    conv_shape = (batch, _CONV_K - 1, w)
    h_shape = (batch, w)
    if abstract:
        return LRUState(jax.ShapeDtypeStruct(conv_shape, jnp.bfloat16),
                        jax.ShapeDtypeStruct(h_shape, jnp.float32))
    return LRUState(jnp.zeros(conv_shape, jnp.bfloat16),
                    jnp.zeros(h_shape, jnp.float32))


def rglru_decode(x: jax.Array, p: dict, cfg: ArchConfig,
                 state: LRUState) -> tuple[jax.Array, LRUState]:
    """One-token decode.  x: [B, 1, d]."""
    b_, one, d = x.shape
    xi = apply_dense(x[:, 0], p["in_x"])
    gate = jax.nn.gelu(apply_dense(x[:, 0], p["in_gate"]), approximate=True)
    window = jnp.concatenate([state.conv.astype(xi.dtype), xi[:, None]], axis=1)
    x_conv = jnp.einsum("bkw,kw->bw", window, p["conv_w"].astype(x.dtype)[::-1]) \
        + p["conv_b"].astype(x.dtype)
    a, gated = _gates(x_conv, p)
    h = a * state.h + gated
    y = h.astype(x.dtype) * gate
    out = apply_dense(y, p["out"])[:, None]
    return out, LRUState(window[:, 1:].astype(state.conv.dtype), h)
