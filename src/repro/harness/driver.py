"""Replay a trace against a configured :class:`ServingEngine`.

The driver owns the outer serve loop every benchmark used to hand-roll:
submit requests when the engine's logical clock reaches their
``arrival_step``, call ``engine.step()`` otherwise, and collect the
lifecycle events the engine publishes.  It works unchanged across all
engine configurations — bucketed or chunked scheduler, dense or paged
cache, single model or fleet — because it only touches the public
surface (``submit`` / ``step`` / ``events`` / ``stats``).

Clock semantics: arrivals are relative to the engine's step count at
replay start, so a warm engine (already-compiled programs, nonzero
``decode_steps``) replays a trace identically to a cold one.  The
engine's clock only advances while it has work; if it drains completely
before the next arrival, the gap is collapsed — the next arrival batch
is submitted immediately.  Idle wall time is never simulated, which is
exactly what makes step metrics reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.harness.metrics import SLO, HarnessMetrics, reduce_events
from repro.harness.trace import Trace
from repro.serving.events import EngineEvent, EventLog


@dataclass(frozen=True)
class ReplayResult:
    """Everything one replay produced."""

    trace: Trace
    metrics: HarnessMetrics
    events: list[EngineEvent]
    finished: list                       # engine Request objects
    uid_to_rid: dict[int, int] = field(default_factory=dict)

    @property
    def rid_metrics(self) -> dict:
        """Per-request step metrics keyed by *trace* rid (uids are
        assigned per engine and differ across replays)."""
        return {self.uid_to_rid[uid]: m
                for uid, m in self.metrics.per_request.items()
                if uid in self.uid_to_rid}


def replay(engine, trace: Trace, *, slo: SLO | None = None,
           max_steps: int = 50_000) -> ReplayResult:
    """Drive ``engine`` through ``trace`` and reduce the event stream.

    ``max_steps`` bounds fused dispatches (a stuck replay raises rather
    than spinning).  The engine must be loaded (and in fleet mode, every
    model id the trace references must be added) before calling.
    """
    log = EventLog()
    engine.events.subscribe(log)
    # stable sort: equal arrival steps keep trace order, so uid
    # assignment (and therefore the whole replay) is deterministic
    reqs = sorted(trace.requests, key=lambda r: r.arrival_step)
    uid_to_rid: dict[int, int] = {}
    finished = []
    try:
        step0 = engine.stats["decode_steps"]
        i, n, steps = 0, len(reqs), 0

        def _submit_due(until: int) -> None:
            nonlocal i
            while i < n and reqs[i].arrival_step <= until:
                uid = engine.submit(list(reqs[i].prompt),
                                    max_new_tokens=reqs[i].max_new_tokens,
                                    model=reqs[i].model)
                uid_to_rid[uid] = reqs[i].rid
                i += 1

        while True:
            _submit_due(engine.stats["decode_steps"] - step0)
            if not engine.queue and all(r is None for r in engine.slot_req):
                if i >= n:
                    break               # drained and no arrivals left
                # engine fully idle before the next arrival: collapse the
                # idle gap (submit the whole next arrival batch now)
                _submit_due(reqs[i].arrival_step)
                continue
            if steps >= max_steps:
                raise RuntimeError(
                    f"replay of trace {trace.name!r} exceeded max_steps="
                    f"{max_steps} with {n - i} unsubmitted and "
                    f"{len(engine.queue)} queued requests")
            finished += engine.step()
            steps += 1
    finally:
        engine.events.unsubscribe(log)
    return ReplayResult(trace=trace, metrics=reduce_events(log.events, slo),
                        events=log.events, finished=finished,
                        uid_to_rid=uid_to_rid)
