"""Seeded synthetic request traces + a small versioned on-disk format.

A trace is the workload half of a benchmark: an ordered sequence of
requests, each with an arrival time in *engine steps* (fused dispatches,
the engine's logical clock — wall-clock arrivals would make every replay
machine-dependent).  Generators are seeded ``numpy.random.RandomState``
(whose streams are frozen by numpy's compatibility guarantee), so the
same ``(generator, seed)`` always yields byte-identical traces — and the
on-disk format serializes canonically (sorted keys, fixed separators) so
"byte-identical" survives a save/load round trip too.

Arrival semantics during replay: the driver submits a request once the
engine's step clock reaches ``arrival_step``.  If the engine goes
completely idle before then, the remaining arrivals are submitted as the
engine reaches them with the queue empty — idle wall time is not
simulated (steps only advance when the engine dispatches work).

Generators cover the scenario families the serving stack is built for:

* :func:`poisson_trace`       — memoryless arrivals at a target rate.
* :func:`bursty_trace`        — arrival bursts separated by quiet gaps
  (the adversarial case for admission + preemption).
* :func:`shared_prefix_trace` — system-prompt-style traffic where most
  requests extend one of a few shared prefixes (prefix-cache workloads).
* :func:`fleet_trace`         — multi-model request streams for the
  multi-topology fabric.
* :func:`scripted_trace`      — hand-written request tuples for tests.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

TRACE_SCHEMA = 1


@dataclass(frozen=True)
class TraceRequest:
    """One request of a trace.  ``rid`` is its stable identity within the
    trace (engine uids differ per replay; results are keyed by rid)."""

    rid: int
    arrival_step: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    model: int = 0            # fleet member (multi-topology mode)

    def __post_init__(self) -> None:
        if self.arrival_step < 0:
            raise ValueError(f"request {self.rid}: arrival_step "
                             f"{self.arrival_step} < 0")
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens "
                             f"{self.max_new_tokens} < 1")


@dataclass(frozen=True)
class Trace:
    """An ordered, replayable request sequence."""

    name: str
    seed: int
    requests: tuple[TraceRequest, ...]
    meta: dict = field(default_factory=dict)   # generator parameters

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def max_prompt_len(self) -> int:
        return max(len(r.prompt) for r in self.requests)

    @property
    def mean_prompt_len(self) -> float:
        return sum(len(r.prompt) for r in self.requests) / len(self.requests)

    @property
    def mean_new_tokens(self) -> float:
        return sum(r.max_new_tokens for r in self.requests) / len(self.requests)

    @property
    def models(self) -> tuple[int, ...]:
        return tuple(sorted({r.model for r in self.requests}))


# ---------------------------------------------------------------------------
# On-disk format
# ---------------------------------------------------------------------------
def dumps_trace(trace: Trace) -> str:
    """Canonical serialization: sorted keys, fixed separators, trailing
    newline — byte-identical for equal traces, whatever dict order the
    generator produced."""
    obj = {
        "schema": TRACE_SCHEMA,
        "name": trace.name,
        "seed": trace.seed,
        "meta": trace.meta,
        "requests": [[r.rid, r.arrival_step, r.max_new_tokens, r.model,
                      list(r.prompt)] for r in trace.requests],
    }
    return json.dumps(obj, sort_keys=True, separators=(",", ":")) + "\n"


def loads_trace(text: str) -> Trace:
    try:
        obj = json.loads(text)
    except ValueError as e:
        raise ValueError(f"trace file is not valid JSON: {e}") from e
    if not isinstance(obj, dict) or obj.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"trace schema {obj.get('schema') if isinstance(obj, dict) else obj!r} "
            f"is not the supported version {TRACE_SCHEMA}")
    reqs = tuple(TraceRequest(rid=r[0], arrival_step=r[1],
                              max_new_tokens=r[2], model=r[3],
                              prompt=tuple(r[4]))
                 for r in obj["requests"])
    return Trace(name=obj["name"], seed=obj["seed"], requests=reqs,
                 meta=obj.get("meta", {}))


def save_trace(trace: Trace, path: str | Path) -> None:
    Path(path).write_text(dumps_trace(trace))


def load_trace(path: str | Path) -> Trace:
    return loads_trace(Path(path).read_text())


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------
def _tokens(rng: np.random.RandomState, n: int, vocab: int) -> tuple[int, ...]:
    """Token ids in [1, vocab] — 0 is avoided (pad/garbage by convention
    in the engine buffers), and vocab=50 fits every reduced() arch."""
    return tuple(1 + int(t) for t in rng.randint(0, vocab, size=n))


def _mixed_len(rng: np.random.RandomState, max_len: int,
               short_frac: float) -> int:
    """Mixed prompt lengths: mostly short chat-style prompts with a long
    tail of document-style ones (the distribution chunked prefill and
    paged admission are designed around)."""
    if rng.random_sample() < short_frac:
        return int(rng.randint(4, max(max_len // 8, 5)))
    return int(rng.randint(max_len // 4, max(3 * max_len // 4, max_len // 4 + 1)))


def _budget(rng: np.random.RandomState, max_new: int) -> int:
    return int(rng.randint(max(2, max_new // 2), max_new + 1))


def poisson_trace(n: int, *, rate: float, max_len: int = 128,
                  max_new: int = 8, short_frac: float = 0.7,
                  vocab: int = 50, seed: int = 0,
                  name: str = "poisson") -> Trace:
    """Memoryless arrivals at ``rate`` requests per engine step."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.RandomState(seed)
    t, reqs = 0.0, []
    for rid in range(n):
        t += rng.exponential(1.0 / rate)
        plen = min(_mixed_len(rng, max_len, short_frac), max_len - max_new)
        reqs.append(TraceRequest(rid, int(t), _tokens(rng, plen, vocab),
                                 _budget(rng, max_new)))
    return Trace(name, seed, tuple(reqs),
                 meta={"kind": "poisson", "rate": rate, "max_len": max_len,
                       "max_new": max_new, "short_frac": short_frac})


def bursty_trace(n: int, *, burst_size: int, gap_steps: int,
                 max_len: int = 128, max_new: int = 8,
                 short_frac: float = 0.7, vocab: int = 50, seed: int = 0,
                 name: str = "bursty") -> Trace:
    """Bursts of ``burst_size`` simultaneous arrivals every ``gap_steps``
    engine steps — the admission-control stress case: each burst exceeds
    what a naive configuration can seat, so queueing (and with paging,
    preemption pressure) is part of the workload, not an accident."""
    if burst_size < 1 or gap_steps < 1:
        raise ValueError("burst_size and gap_steps must be >= 1, got "
                         f"{burst_size} and {gap_steps}")
    rng = np.random.RandomState(seed)
    reqs = []
    for rid in range(n):
        burst = rid // burst_size
        plen = min(_mixed_len(rng, max_len, short_frac), max_len - max_new)
        reqs.append(TraceRequest(rid, burst * gap_steps,
                                 _tokens(rng, plen, vocab),
                                 _budget(rng, max_new)))
    return Trace(name, seed, tuple(reqs),
                 meta={"kind": "bursty", "burst_size": burst_size,
                       "gap_steps": gap_steps, "max_len": max_len,
                       "max_new": max_new, "short_frac": short_frac})


def shared_prefix_trace(n: int, *, n_families: int, prefix_len: int,
                        max_len: int = 128, max_new: int = 8,
                        shared_frac: float = 0.8, vocab: int = 50,
                        seed: int = 0, arrival_every: int = 1,
                        name: str = "shared-prefix") -> Trace:
    """System-prompt traffic: ``shared_frac`` of requests extend one of
    ``n_families`` fixed prefixes with a unique suffix; the rest are
    fully unique prompts.  Family prefixes are deterministic in the seed,
    so two engines replaying the trace see identical sharing structure."""
    if not 0 <= shared_frac <= 1:
        raise ValueError(f"shared_frac must be in [0, 1], got {shared_frac}")
    if prefix_len + max_new >= max_len:
        raise ValueError(
            f"prefix_len={prefix_len} + max_new={max_new} must leave room "
            f"under max_len={max_len}")
    rng = np.random.RandomState(seed)
    families = [_tokens(rng, prefix_len, vocab) for _ in range(n_families)]
    reqs = []
    for rid in range(n):
        budget = _budget(rng, max_new)
        if rng.random_sample() < shared_frac:
            base = families[int(rng.randint(0, n_families))]
            room = max_len - prefix_len - budget
            sfx = int(rng.randint(1, max(room // 2, 2)))
            prompt = base + _tokens(rng, sfx, vocab)
        else:
            plen = min(_mixed_len(rng, max_len, 0.8), max_len - budget)
            prompt = _tokens(rng, plen, vocab)
        reqs.append(TraceRequest(rid, rid // max(arrival_every, 1),
                                 prompt, budget))
    return Trace(name, seed, tuple(reqs),
                 meta={"kind": "shared-prefix", "n_families": n_families,
                       "prefix_len": prefix_len, "shared_frac": shared_frac,
                       "max_len": max_len, "max_new": max_new})


def fleet_trace(n: int, *, n_models: int, max_len: int = 64,
                max_new: int = 6, vocab: int = 50, seed: int = 0,
                burst_size: int = 4, gap_steps: int = 4,
                name: str = "fleet") -> Trace:
    """Multi-model request stream: bursty arrivals round-robined (with
    seeded jitter) across ``n_models`` fleet members."""
    if n_models < 1:
        raise ValueError(f"n_models must be >= 1, got {n_models}")
    rng = np.random.RandomState(seed)
    reqs = []
    for rid in range(n):
        plen = min(_mixed_len(rng, max_len, 0.8), max_len - max_new)
        model = int(rng.randint(0, n_models)) if rng.random_sample() < 0.5 \
            else rid % n_models
        reqs.append(TraceRequest(rid, (rid // burst_size) * gap_steps,
                                 _tokens(rng, plen, vocab),
                                 _budget(rng, max_new), model=model))
    return Trace(name, seed, tuple(reqs),
                 meta={"kind": "fleet", "n_models": n_models,
                       "max_len": max_len, "max_new": max_new})


def scripted_trace(rows, *, name: str = "scripted", seed: int = 0) -> Trace:
    """Hand-written trace: rows of ``(arrival_step, prompt, max_new)`` or
    ``(arrival_step, prompt, max_new, model)`` — the toy-trace entry
    point for tests and examples."""
    reqs = []
    for rid, row in enumerate(rows):
        arrival, prompt, max_new = row[0], row[1], row[2]
        model = row[3] if len(row) > 3 else 0
        reqs.append(TraceRequest(rid, arrival, tuple(prompt), max_new,
                                 model=model))
    return Trace(name, seed, tuple(reqs), meta={"kind": "scripted"})
