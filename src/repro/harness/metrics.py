"""Reduce engine lifecycle events to serving SLO metrics.

Every metric exists in two currencies, kept strictly separated:

* **steps** — the engine's logical clock (fused dispatches).  Step
  arithmetic is bit-reproducible across runs and machines, so the CI
  reproducibility smoke and all benchmark gates use the step view
  (:meth:`HarnessMetrics.deterministic`).
* **seconds** — ``time.perf_counter()`` wall stamps.  Honest for
  human-facing numbers, useless for gating.

Definitions (all hand-computable from an event list, and tested that
way in ``tests/test_harness.py``):

* **TTFT (steps)** — ``first_token.step - submit.step``: dispatches
  between entering the queue and the first generated token existing.
* **TTFT (seconds)** — first ``progress`` with ``count >= 1`` minus
  ``submit``.  ``first_token``'s own wall stamp is dispatch-side
  (async dispatch returns before the device finishes), so the wall
  view waits for the first *completion-honest* observation instead.
* **ITL** — for each consecutive ``progress`` pair of one request with
  counts ``c0 < c1`` at steps ``s0 < s1``, append ``c1 - c0`` samples
  of ``(s1 - s0) / (c1 - c0)`` steps per token (wall analogue from the
  stamps).  A count *decrease* is a preemption reset: re-baseline,
  no samples.
* **Percentiles** — nearest-rank: ``sorted(xs)[ceil(q/100 * n) - 1]``.
  No interpolation, so toy-trace expectations are exact.
* **Peak concurrency** — running sum over the event stream
  (``admit`` +1, ``finish``/``preempt`` -1), maxed.
* **Mean accepted draft length** — speculative decoding only: total
  accepted draft tokens over total speculative fused steps, both read
  from the cumulative ``accepted`` / ``spec_steps`` counters on
  ``progress`` events (a decrease in ``accepted`` is a preemption
  reset: the previous epoch's totals are banked and the counter
  re-baselines, mirroring the ITL rule).  ``None`` when no step
  speculated.  Tokens/step for a speculating slot is then
  ``1 + mean_accepted_len``.
* **SLO / goodput** — a request meets the :class:`SLO` iff it finished,
  its TTFT (steps) is within ``slo.ttft_steps``, and its worst
  per-token ITL (steps) is within ``slo.itl_steps`` (each bound
  optional).  ``slo_attainment`` is the met fraction of submitted
  requests; goodput counts only SLO-met finishes, per 1k steps and
  per wall second.  With no SLO, "met" degrades to "finished".
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass

from repro.serving.events import EngineEvent

_WALL_FIELDS = ("wall_s", "ttft_s_p50", "ttft_s_p99", "itl_s_p50",
                "itl_s_p99", "goodput_req_s", "tokens_per_s")


@dataclass(frozen=True)
class SLO:
    """Service-level objective in engine steps.  ``None`` bounds are
    unconstrained."""

    ttft_steps: int | None = None
    itl_steps: float | None = None


@dataclass(frozen=True)
class HarnessMetrics:
    """Reduced view of one replay.  Step-based fields (everything not in
    ``_WALL_FIELDS``) are bit-reproducible for a fixed trace + spec."""

    n_requests: int
    n_finished: int
    n_preemptions: int
    peak_concurrency: int
    prefix_hits: int
    prefix_hit_tokens: int
    steps: int                      # event-stream step span
    total_new_tokens: int
    tokens_per_step: float
    spec_accepted_tokens: int       # accepted draft tokens (speculation)
    spec_steps: int                 # fused steps that speculated
    mean_accepted_len: float | None  # accepted/steps; None without spec
    ttft_steps_p50: float | None
    ttft_steps_p99: float | None
    itl_steps_p50: float | None
    itl_steps_p99: float | None
    n_slo_met: int
    slo_attainment: float
    goodput_req_per_1k_steps: float
    per_request: dict               # uid -> step-based summary
    # wall-clock view (machine-dependent; excluded from deterministic())
    wall_s: float
    ttft_s_p50: float | None
    ttft_s_p99: float | None
    itl_s_p50: float | None
    itl_s_p99: float | None
    goodput_req_s: float
    tokens_per_s: float

    def deterministic(self) -> dict:
        """The step-based view only — byte-comparable across runs."""
        d = asdict(self)
        for k in _WALL_FIELDS:
            del d[k]
        return d

    def deterministic_json(self) -> str:
        """Canonical serialization of :meth:`deterministic` — two replays
        of the same trace on the same spec must produce identical bytes."""
        return json.dumps(self.deterministic(), sort_keys=True,
                          separators=(",", ":")) + "\n"


def percentile(xs, q: float):
    """Nearest-rank percentile; ``None`` on an empty sample."""
    if not xs:
        return None
    ys = sorted(xs)
    return ys[max(math.ceil(q / 100.0 * len(ys)), 1) - 1]


class _ReqState:
    """Per-request accumulator while scanning the event stream."""

    __slots__ = ("submit_step", "submit_t", "ft_step", "ttft_s", "finished",
                 "n_generated", "itl_steps", "itl_s", "base",
                 "spec_acc", "spec_steps", "spec_base")

    def __init__(self) -> None:
        self.submit_step = None
        self.submit_t = None
        self.ft_step = None
        self.ttft_s = None
        self.finished = False
        self.n_generated = 0
        self.itl_steps: list[float] = []
        self.itl_s: list[float] = []
        self.base = None          # (count, step, t) ITL baseline
        self.spec_acc = 0         # accepted tokens banked across preemptions
        self.spec_steps = 0       # speculative steps banked likewise
        self.spec_base = None     # (accepted, spec_steps) cumulative epoch

    def on_progress(self, e: EngineEvent) -> None:
        a = e.data.get("accepted")
        if a is not None:
            ss = e.data.get("spec_steps", 0)
            if self.spec_base is not None and a < self.spec_base[0]:
                # preemption reset: bank the epoch, re-baseline
                self.spec_acc += self.spec_base[0]
                self.spec_steps += self.spec_base[1]
            self.spec_base = (a, ss)
        c = e.data["count"]
        if c >= 1 and self.ttft_s is None and self.submit_t is not None:
            self.ttft_s = e.t - self.submit_t
        if self.base is None:
            if c >= 1:
                self.base = (c, e.step, e.t)
            return
        c0, s0, t0 = self.base
        if c < c0:                # preemption reset: re-baseline, no samples
            self.base = (c, e.step, e.t) if c >= 1 else None
            return
        if c > c0:
            n = c - c0
            self.itl_steps.extend([(e.step - s0) / n] * n)
            self.itl_s.extend([(e.t - t0) / n] * n)
            self.base = (c, e.step, e.t)

    def ttft_steps(self):
        if self.ft_step is None or self.submit_step is None:
            return None
        return self.ft_step - self.submit_step

    def spec_totals(self) -> tuple[int, int]:
        """(accepted draft tokens, speculative steps) including the
        still-open epoch."""
        acc, steps = self.spec_acc, self.spec_steps
        if self.spec_base is not None:
            acc += self.spec_base[0]
            steps += self.spec_base[1]
        return acc, steps

    def meets(self, slo: SLO | None) -> bool:
        if not self.finished:
            return False
        if slo is None:
            return True
        ttft = self.ttft_steps()
        if slo.ttft_steps is not None and (ttft is None
                                           or ttft > slo.ttft_steps):
            return False
        if slo.itl_steps is not None and self.itl_steps \
                and max(self.itl_steps) > slo.itl_steps:
            return False
        return True


def reduce_events(events: list[EngineEvent],
                  slo: SLO | None = None) -> HarnessMetrics:
    """Scan an event stream (in emission order) into :class:`HarnessMetrics`."""
    if not events:
        raise ValueError("reduce_events needs a non-empty event stream")
    reqs: dict[int, _ReqState] = {}
    live = peak = 0
    n_preempt = prefix_hits = prefix_hit_tokens = 0
    for e in events:
        r = reqs.setdefault(e.uid, _ReqState())
        if e.kind == "submit":
            if r.submit_step is None:
                r.submit_step, r.submit_t = e.step, e.t
        elif e.kind == "admit":
            live += 1
            peak = max(peak, live)
            cached = e.data.get("cached_tokens", 0)
            if cached:
                prefix_hits += 1
                prefix_hit_tokens += cached
        elif e.kind == "first_token":
            if r.ft_step is None:
                r.ft_step = e.step
        elif e.kind == "progress":
            r.on_progress(e)
        elif e.kind == "finish":
            live -= 1
            r.finished = True
            r.n_generated = e.data.get("n_generated", 0)
        elif e.kind == "preempt":
            live -= 1
            n_preempt += 1

    steps = max(e.step for e in events) - min(e.step for e in events)
    wall_s = max(e.t for e in events) - min(e.t for e in events)
    ttfts = [r.ttft_steps() for r in reqs.values()
             if r.ttft_steps() is not None]
    ttfts_s = [r.ttft_s for r in reqs.values() if r.ttft_s is not None]
    itls = [x for r in reqs.values() for x in r.itl_steps]
    itls_s = [x for r in reqs.values() for x in r.itl_s]
    n_finished = sum(r.finished for r in reqs.values())
    n_met = sum(r.meets(slo) for r in reqs.values())
    total_new = sum(r.n_generated for r in reqs.values())
    spec_acc = sum(r.spec_totals()[0] for r in reqs.values())
    spec_steps = sum(r.spec_totals()[1] for r in reqs.values())
    per_request = {
        uid: {"ttft_steps": r.ttft_steps(), "finished": r.finished,
              "n_generated": r.n_generated,
              "n_itl_samples": len(r.itl_steps),
              "max_itl_steps": max(r.itl_steps) if r.itl_steps else None,
              "slo_met": r.meets(slo)}
        for uid, r in sorted(reqs.items())}
    return HarnessMetrics(
        n_requests=len(reqs),
        n_finished=n_finished,
        n_preemptions=n_preempt,
        peak_concurrency=peak,
        prefix_hits=prefix_hits,
        prefix_hit_tokens=prefix_hit_tokens,
        steps=steps,
        total_new_tokens=total_new,
        tokens_per_step=total_new / max(steps, 1),
        spec_accepted_tokens=spec_acc,
        spec_steps=spec_steps,
        mean_accepted_len=(spec_acc / spec_steps) if spec_steps else None,
        ttft_steps_p50=percentile(ttfts, 50),
        ttft_steps_p99=percentile(ttfts, 99),
        itl_steps_p50=percentile(itls, 50),
        itl_steps_p99=percentile(itls, 99),
        n_slo_met=n_met,
        slo_attainment=n_met / len(reqs),
        goodput_req_per_1k_steps=1000.0 * n_met / max(steps, 1),
        per_request=per_request,
        wall_s=wall_s,
        ttft_s_p50=percentile(ttfts_s, 50),
        ttft_s_p99=percentile(ttfts_s, 99),
        itl_s_p50=percentile(itls_s, 50),
        itl_s_p99=percentile(itls_s, 99),
        goodput_req_s=n_met / wall_s if wall_s > 0 else 0.0,
        tokens_per_s=total_new / wall_s if wall_s > 0 else 0.0,
    )
