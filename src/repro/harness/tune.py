"""Analytical autotuner: rank runtime configurations before running any.

This is the reproduction of ADAPTOR's resource allocator (§5): the
paper sizes tile counts and BRAM partitions from a closed-form model of
the target platform; here the same ``core.analytical`` roofline model
sizes the serving runtime's free knobs — cache layout (dense vs paged),
pool geometry (``block_size`` / ``num_blocks`` / ``max_batch``),
scheduler (``chunk_size`` / ``token_budget``), prefix caching — under a
cache-memory budget, for a described workload.

The tuner is *pre-execution* arithmetic: it never builds an engine.  Its
objective is deliberately coarse — a queueing sketch on top of
``analytical_step_seconds`` — because ranking, not absolute seconds, is
what matters (the calibration test in ``tests/test_analytical.py`` pins
exactly that: the model's config ranking matches measured fused-step
times).  The harness then *measures* the chosen spec against the naive
default (``benchmarks/load_harness.py``), closing the loop the paper
closes with its AXI timers.

Front doors::

    spec = RuntimeSpec.tuned(arch, device_profile=DeviceProfile(...),
                             workload=WorkloadProfile.from_trace(trace))
    result = tune(arch, device=..., workload=...)   # ranked candidates
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.analytical import (V5E, TPUSpec, analytical_step_seconds,
                                   kv_bytes_per_token, weight_bytes)
from repro.core.spec import (CHUNKABLE_FAMILIES, ExecutionSpec, MemorySpec,
                             MeshSpec, RuntimeSpec, SchedulerSpec,
                             SpeculationSpec)

# Enumerated knob grids.  Small on purpose: the analytical model makes
# each point ~free, but the benchmark that *verifies* the winner is not.
_BLOCK_SIZES = (8, 16, 32)
_CHUNK_SIZES = (16, 32, 64)
_BUDGET_MULT = (2, 4, 8)
_SPEC_KS = (2, 4)            # draft depths searched (k=0 = no speculation)
_MAX_BATCH_CAP = 64          # host-side per-slot bookkeeping ceiling


def expected_accepted(k: int, a: float) -> float:
    """Expected tokens per speculative step at per-token acceptance ``a``:
    ``E(k, a) = sum_{i=0..k} a^i = (1 - a^{k+1}) / (1 - a)`` — the
    accepted prefix run plus the always-emitted bonus/correction token.
    Monotone in both arguments, ``1`` at ``a = 0`` (speculation never
    emits fewer tokens than plain decode), ``k + 1`` at ``a -> 1``."""
    a = min(max(a, 0.0), 0.999)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


@dataclass(frozen=True)
class DeviceProfile:
    """The target platform, plus how much of its HBM the KV cache may
    use.  ``cache_budget_bytes`` pins the budget directly (the
    equal-memory comparisons in benchmarks do this); ``None`` derives it
    as ``cache_fraction`` of HBM left after weights.

    ``n_devices`` is the mesh surface: the tuner enumerates every
    ``(tp, dp)`` divisor pair of it as a candidate axis (``n_chips``
    keeps its historical single-replica meaning and pins the 1-device
    ranking).  ``interconnect_gbps`` overrides the chip's ICI bandwidth
    for the TP all-reduce term — the knob that makes a host-mesh dev box
    (slow interconnect) rank TP lower than a real pod would."""

    tpu: TPUSpec = V5E
    n_chips: int = 1
    cache_fraction: float = 0.4
    cache_budget_bytes: int | None = None
    n_devices: int = 1
    interconnect_gbps: float | None = None

    @property
    def effective_tpu(self) -> TPUSpec:
        if self.interconnect_gbps is None:
            return self.tpu
        return dataclasses.replace(self.tpu,
                                   ici_bw=self.interconnect_gbps * 1e9)

    def meshes(self) -> tuple[MeshSpec, ...]:
        """Every (tp, dp) divisor pair of ``n_devices``, tp ascending —
        (1, 1) only for the historical single-device profile."""
        return tuple(MeshSpec(tp=tp, dp=self.n_devices // tp)
                     for tp in range(1, self.n_devices + 1)
                     if self.n_devices % tp == 0)

    def budget(self, arch: ArchConfig, dtype_bytes: int = 2) -> int:
        if self.cache_budget_bytes is not None:
            return self.cache_budget_bytes
        free = max(self.n_chips, self.n_devices) * self.tpu.hbm_bytes \
            - weight_bytes(arch, dtype_bytes)
        return max(int(self.cache_fraction * free), 0)


@dataclass(frozen=True)
class WorkloadProfile:
    """What the traffic looks like — the trace distilled to the moments
    the tuner's queueing sketch needs."""

    mean_prompt_len: float = 64.0
    max_prompt_len: int = 128
    mean_new_tokens: float = 8.0
    burst_size: int = 8              # peak simultaneous arrivals
    shared_prefix_frac: float = 0.0  # fraction of requests sharing a prefix
    shared_prefix_len: int = 0       # tokens of that shared prefix
    # expected per-token probability the target accepts a draft proposal
    # (workload-dependent: ~1 for greedy self-drafting, lower the further
    # the draft sits from the target); 0 keeps speculation out of the
    # candidate space
    draft_acceptance: float = 0.0

    @staticmethod
    def from_trace(trace) -> "WorkloadProfile":
        arrivals: dict[int, int] = {}
        for r in trace.requests:
            arrivals[r.arrival_step] = arrivals.get(r.arrival_step, 0) + 1
        meta = trace.meta
        return WorkloadProfile(
            mean_prompt_len=trace.mean_prompt_len,
            max_prompt_len=trace.max_prompt_len,
            mean_new_tokens=trace.mean_new_tokens,
            burst_size=max(arrivals.values()),
            shared_prefix_frac=meta.get("shared_frac", 0.0),
            shared_prefix_len=meta.get("prefix_len", 0))

    @property
    def effective_prompt_len(self) -> float:
        """Mean prompt tokens that must actually be prefilled once a
        prefix cache absorbs the shared span."""
        saved = self.shared_prefix_frac * min(self.shared_prefix_len,
                                              self.mean_prompt_len)
        return max(self.mean_prompt_len - saved, 1.0)


@dataclass(frozen=True)
class Candidate:
    """One scored configuration point."""

    spec: RuntimeSpec
    score: float                 # requests per predicted second (higher wins)
    predicted_latency_s: float
    predicted_ttft_s: float
    predicted_itl_s: float
    cache_bytes: int
    max_batch: int

    def summary(self) -> dict:
        m, s = self.spec.memory, self.spec.scheduler
        return {"tp": self.spec.mesh.tp, "dp": self.spec.mesh.dp,
                "cache_layout": m.cache_layout, "max_batch": m.max_batch,
                "block_size": m.block_size if m.cache_layout == "paged" else None,
                "num_blocks": m.resolved_num_blocks if m.cache_layout == "paged" else None,
                "kv_dtype": m.kv_dtype, "prefix_cache": m.prefix_cache,
                "policy": s.policy, "chunk_size": s.chunk_size,
                "token_budget": s.resolved_token_budget,
                "spec_k": self.spec.speculation.k
                if self.spec.speculation is not None else 0,
                "score": self.score, "cache_bytes": self.cache_bytes,
                "predicted_ttft_s": self.predicted_ttft_s,
                "predicted_itl_s": self.predicted_itl_s}


@dataclass(frozen=True)
class TuneResult:
    """The winner plus the full ranking (transparency for benchmarks)."""

    spec: RuntimeSpec
    best: Candidate
    ranked: tuple[Candidate, ...]    # best first
    budget_bytes: int


def _per_token_bytes(arch: ArchConfig, kv_dtype: str, maxima) -> int:
    """Cache bytes per token: the arch's own geometry, or — under a
    fleet ``maxima`` — the maxima-shaped rows the shared pool actually
    allocates (``DecodeFabric.kv_bytes_per_token``: a small member in a
    big fabric still pays maxima-sized cache)."""
    if maxima is not None:
        hd = maxima.head_dim_max
        per_row = hd + 4 if kv_dtype == "int8" else 2 * hd
        return 2 * maxima.layers_enc_max * maxima.heads_max * per_row
    return kv_bytes_per_token(arch, kv_dtype)


def cache_bytes(spec: RuntimeSpec) -> int:
    """KV-cache bytes a spec provisions (the equal-memory yardstick).
    A speculative spec also pays for the draft's private dense cache —
    equal-memory comparisons must charge speculation its real rent."""
    per_tok = _per_token_bytes(spec.arch, spec.memory.kv_dtype, spec.maxima)
    m = spec.memory
    total = m.resolved_num_blocks * m.block_size * per_tok \
        if m.cache_layout == "paged" else m.max_batch * m.max_len * per_tok
    if spec.speculation is not None:
        total += m.max_batch * m.max_len * kv_bytes_per_token(
            spec.speculation.draft_model, "compute")
    return total


def _predict(arch: ArchConfig, cand: RuntimeSpec, device: DeviceProfile,
             workload: WorkloadProfile, dtype_bytes: int) -> tuple[float, float, float]:
    """(ttft_s, itl_s, latency_s) queueing sketch for one candidate.

    Coarse by design: decode cost from the roofline at the candidate's
    batch, prefill cost from the roofline at its per-step grant, queue
    effects from how many of a burst fit.  Monotone in the knobs that
    matter (bigger batch amortizes weight reads; bigger grants finish
    prompts in fewer steps but each step costs more; prefix caching
    shrinks the prompt work) — which is all a *ranking* objective needs.
    """
    tpu = device.effective_tpu
    tp = cand.mesh.tp
    # one TP replica spans tp chips; the legacy n_chips profile knob
    # keeps meaning "chips per replica" for 1-device rankings
    chips = tp if tp > 1 else device.n_chips
    B = cand.memory.max_batch
    eff_prompt = workload.effective_prompt_len if cand.memory.prefix_cache \
        else workload.mean_prompt_len
    kv_depth = int(eff_prompt + workload.mean_new_tokens)
    t_decode = analytical_step_seconds(
        arch, ShapeSpec("tune_decode", kv_depth, B, "decode"),
        chips, tpu, dtype_bytes, tp=tp).t_total
    concurrent = max(1, min(B, workload.burst_size))
    if cand.scheduler.policy == "chunked":
        grant = min(cand.scheduler.resolved_token_budget,
                    max(int(eff_prompt), cand.scheduler.chunk_size))
        t_pre = analytical_step_seconds(
            arch, ShapeSpec("tune_chunk", grant, 1, "prefill"),
            chips, tpu, dtype_bytes, tp=tp).t_total
        t_mixed = t_decode + t_pre
        share = cand.scheduler.resolved_token_budget / concurrent
        ttft_steps = eff_prompt / max(share, 1.0)
        ttft = ttft_steps * t_mixed
        prefill_steps = concurrent * eff_prompt \
            / cand.scheduler.resolved_token_budget
        frac = prefill_steps / max(prefill_steps + workload.mean_new_tokens,
                                   1.0)
        t_dec_eff = t_decode
        if cand.speculation is not None:
            # speculative steady state: one fused step pays k one-lane
            # draft decodes plus the target's k+1-lane verify (the verify
            # is roofline-equivalent to a decode step — both stream the
            # same weights and KV, the extra query lanes are ~free) and
            # yields E(k, a) tokens
            sp = cand.speculation
            t_draft = analytical_step_seconds(
                sp.draft_model, ShapeSpec("tune_draft", kv_depth, B,
                                          "decode"),
                chips, tpu, dtype_bytes, tp=tp).t_total
            t_dec_eff = (sp.k * t_draft + t_decode) / expected_accepted(
                sp.k, workload.draft_acceptance)
        itl = frac * t_mixed + (1.0 - frac) * t_dec_eff
    else:
        # bucketed: one B=1 prefill dispatch per request, decode stalls
        # behind it, and a burst larger than the batch waits whole turns
        t_pre = analytical_step_seconds(
            arch, ShapeSpec("tune_prefill", max(int(eff_prompt), 1), 1,
                            "prefill"), chips, tpu, dtype_bytes, tp=tp).t_total
        waves = math.ceil(concurrent / B)
        ttft = waves * t_pre
        itl = t_decode + concurrent * t_pre / max(
            workload.mean_new_tokens * B, 1.0)
    latency = ttft + workload.mean_new_tokens * itl
    return ttft, itl, latency


def _candidates(arch: ArchConfig, device: DeviceProfile,
                workload: WorkloadProfile, max_len: int, budget: int,
                execution: ExecutionSpec, kv_dtypes: tuple[str, ...],
                maxima, mesh: MeshSpec = MeshSpec(),
                draft: ArchConfig | None = None) -> list[RuntimeSpec]:
    chunkable = arch.family in CHUNKABLE_FAMILIES
    pageable = arch.family in ("dense", "vlm", "moe")
    live_tokens = workload.effective_prompt_len + workload.mean_new_tokens
    # speculation variants ride every chunked point (k=0 is the point
    # itself); the spec's own validation prunes infeasible geometry
    # (horizon > chunk, vocab mismatch, non-chunkable draft)
    speculations: tuple[SpeculationSpec | None, ...] = (None,)
    if draft is not None and workload.draft_acceptance > 0.0:
        speculations += tuple(SpeculationSpec(draft_model=draft, k=kk)
                              for kk in _SPEC_KS)

    out: list[RuntimeSpec] = []

    def add(memory: MemorySpec, scheduler: SchedulerSpec) -> None:
        specs = speculations if scheduler.policy == "chunked" else (None,)
        for sp in specs:
            try:
                out.append(RuntimeSpec(arch=arch, maxima=maxima,
                                       execution=execution, memory=memory,
                                       scheduler=scheduler, mesh=mesh,
                                       speculation=sp))
            except ValueError:
                pass  # geometry the spec itself rejects is not a candidate

    for kv_dtype in kv_dtypes:
        per_tok = _per_token_bytes(arch, kv_dtype, maxima)
        # dense: every slot pre-pays max_len tokens
        dense_b = min(budget // (max_len * per_tok), _MAX_BATCH_CAP)
        if dense_b >= 1:
            mem = MemorySpec(cache_layout="dense", max_batch=int(dense_b),
                             max_len=max_len, kv_dtype=kv_dtype)
            add(mem, SchedulerSpec(policy="bucketed"))
            if chunkable:
                for chunk in _CHUNK_SIZES:
                    if chunk > max_len:
                        continue
                    for mult in _BUDGET_MULT:
                        add(mem, SchedulerSpec(policy="chunked",
                                               chunk_size=chunk,
                                               token_budget=mult * chunk))
        if not (pageable and chunkable):
            continue
        # paged: the pool holds live tokens, not worst-case rectangles
        pool_tokens = budget // per_tok
        for bs in _BLOCK_SIZES:
            if max_len % bs:
                continue
            num_blocks = pool_tokens // bs
            if num_blocks * bs < max_len:
                continue        # could never admit one full request
            # per-request block rounding means live_tokens understates
            # true occupancy; round up to whole blocks before dividing
            per_req = math.ceil(live_tokens / bs) * bs
            paged_b = int(min(pool_tokens // per_req, _MAX_BATCH_CAP))
            if paged_b < 1:
                continue
            num_blocks = min(num_blocks,
                             paged_b * math.ceil(max_len / bs) * 2)
            if num_blocks * bs * per_tok > budget:
                num_blocks = budget // (bs * per_tok)
            prefixes = (False, True) if workload.shared_prefix_frac > 0.0 \
                else (False,)
            for prefix in prefixes:
                mem = MemorySpec(cache_layout="paged", max_batch=paged_b,
                                 max_len=max_len, block_size=bs,
                                 num_blocks=int(num_blocks),
                                 kv_dtype=kv_dtype, prefix_cache=bool(prefix))
                for chunk in _CHUNK_SIZES:
                    if chunk % bs or chunk > max_len:
                        continue
                    for mult in _BUDGET_MULT:
                        add(mem, SchedulerSpec(policy="chunked",
                                               chunk_size=chunk,
                                               token_budget=mult * chunk))
    return out


def tune(arch: ArchConfig, device: DeviceProfile | None = None,
         workload: WorkloadProfile | None = None, *,
         max_len: int | None = None, execution: ExecutionSpec | None = None,
         allow_int8_kv: bool = False, maxima=None,
         draft: ArchConfig | None = None) -> TuneResult:
    """Rank candidate runtime configurations for ``arch`` and return the
    predicted-best under the device's cache-memory budget.

    ``allow_int8_kv`` gates the int8 cache codec into the search: it is
    numerics-changing (quantize-on-write), so the tuner only trades
    capacity against it when explicitly allowed.  ``execution`` (kernel
    backend, weight quant, dtypes) is passed through unsearched — kernel
    routing is benchmarked separately and is workload-independent.

    ``draft`` adds speculative-decoding points (``spec_k`` in
    ``_SPEC_KS``) to the chunked candidates; their decode term is scaled
    by the analytical acceptance model ``expected_accepted(k,
    workload.draft_acceptance)``, so a workload that reports low draft
    agreement prices speculation out on its own.
    """
    device = device or DeviceProfile()
    workload = workload or WorkloadProfile()
    execution = execution or ExecutionSpec()
    if max_len is None:
        need = workload.max_prompt_len + int(workload.mean_new_tokens) * 2
        max_len = max(64, 1 << (need - 1).bit_length())
    dtype_bytes = 1 if execution.quant == "int8" else 2
    budget = device.budget(arch, dtype_bytes)
    kv_dtypes = ("compute", "int8") if (
        allow_int8_kv and arch.family in ("dense", "vlm", "moe")) \
        else ("compute",)
    cands: list[RuntimeSpec] = []
    for mesh in device.meshes():
        # the whole-fleet budget splits evenly across DP replicas; each
        # candidate's geometry is *per replica* (what one engine sees)
        cands += _candidates(arch, device, workload, max_len,
                             budget // mesh.dp, execution, kv_dtypes,
                             maxima, mesh=mesh, draft=draft)
    if not cands:
        raise ValueError(
            f"no feasible configuration for {arch.family!r} arch under a "
            f"{budget}-byte cache budget at max_len={max_len}: even one "
            "slot does not fit; raise the budget or shrink max_len")
    scored = []
    for spec in cands:
        ttft, itl, latency = _predict(arch, spec, device, workload,
                                      dtype_bytes)
        # dp replicas drain dp queues at once: fleet throughput scales,
        # per-request latency does not
        scored.append(Candidate(
            spec=spec, score=spec.mesh.dp * spec.memory.max_batch / latency,
            predicted_latency_s=latency, predicted_ttft_s=ttft,
            predicted_itl_s=itl, cache_bytes=spec.mesh.dp * cache_bytes(spec),
            max_batch=spec.mesh.dp * spec.memory.max_batch))
    # deterministic ranking: score desc, then the smaller provisioned
    # pool wins ties, then the summary repr as a total order
    scored.sort(key=lambda c: (-c.score, c.cache_bytes, repr(c.summary())))
    return TuneResult(spec=scored[0].spec, best=scored[0],
                      ranked=tuple(scored), budget_bytes=budget)


def naive_default(arch: ArchConfig, tuned: RuntimeSpec) -> RuntimeSpec:
    """The hand-picked baseline at *equal memory*: dense layout with the
    stock ``MemorySpec`` batch, shrunk or grown along ``max_batch`` until
    its cache pays the same bytes as ``tuned``'s pool (so any goodput
    win is allocation, not extra HBM)."""
    per_tok = _per_token_bytes(arch, "compute", tuned.maxima)
    m = tuned.memory
    b = max(cache_bytes(tuned) // (m.max_len * per_tok), 1)
    return RuntimeSpec(
        arch=arch, maxima=tuned.maxima, execution=tuned.execution,
        memory=MemorySpec(cache_layout="dense", max_batch=int(b),
                          max_len=m.max_len),
        scheduler=SchedulerSpec(policy="auto"))
