"""Trace-driven load harness + analytical autotuner.

Closes ADAPTOR's resource-allocation loop for the serving stack:

* ``harness.trace``   — seeded synthetic request traces (Poisson, bursty,
  shared-prefix, multi-model fleet) with a versioned on-disk format, so
  every benchmark replays the exact same request sequence.
* ``harness.driver``  — replay any trace against a configured
  ``ServingEngine`` via the engine's structured lifecycle events.
* ``harness.metrics`` — reduce lifecycle events to SLO metrics: TTFT and
  ITL p50/p99, goodput under an SLO, peak concurrency, preemption and
  prefix-hit counts.  Step-based metrics are bit-reproducible.
* ``harness.tune``    — rank candidate ``RuntimeSpec`` points with the
  ``core.analytical`` roofline model under a memory budget
  (``RuntimeSpec.tuned(arch, device_profile)`` is the front door).
"""
from repro.harness.driver import ReplayResult, replay
from repro.harness.metrics import SLO, HarnessMetrics, reduce_events
from repro.harness.trace import (Trace, TraceRequest, bursty_trace,
                                 fleet_trace, load_trace, poisson_trace,
                                 save_trace, scripted_trace,
                                 shared_prefix_trace)
from repro.harness.tune import DeviceProfile, WorkloadProfile, tune

__all__ = [
    "SLO", "DeviceProfile", "HarnessMetrics", "ReplayResult", "Trace",
    "TraceRequest", "WorkloadProfile", "bursty_trace", "fleet_trace",
    "load_trace", "poisson_trace", "reduce_events", "replay", "save_trace",
    "scripted_trace", "shared_prefix_trace", "tune",
]
