"""Data-parallel serving replicas behind one admission queue.

Tensor parallelism lives *inside* one :class:`ServingEngine` (its
``spec.mesh.tp`` devices run the one fused step under GSPMD); data
parallelism lives *outside*, here: ``dp`` independent engine replicas,
each pinned to its own ``tp``-device mesh slice with its own paged pool
and prefix-cache namespace, behind a single host-side admission surface.
Nothing is sharded across replicas — a request's whole lifetime happens
on the replica that admitted it, which is what keeps every stream
bit-identical to the single-device engine (same program, same lane
arithmetic, just fewer neighbours per pool).

The cluster is a drop-in for ``ServingEngine`` wherever only the public
serving surface is touched — ``submit`` / ``step`` / ``queue`` /
``slot_req`` / ``events`` / ``stats`` — which is exactly the contract
``harness.driver.replay`` documents.  One trace replays against the
replica set unchanged, with every replica's :class:`EngineEvent` stream
relayed onto the cluster bus under cluster-level uids and the cluster's
logical clock (rounds of replica steps), so ``reduce_events`` works on
the merged log as-is.

Placement is by *free capacity*: each submit seats on the replica with
the most free pool blocks net of demand already queued there (dense
layout: free slots net of queue length).  Ties break to the lowest
replica index, and the router reads only host-side state, so placement
— and therefore the whole replay — is deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.paging import blocks_for_tokens
from repro.core.spec import MeshSpec, RuntimeSpec
from repro.serving.engine import Request, ServingEngine
from repro.serving.events import EngineEvent, EventBus


class EngineCluster:
    """``spec.mesh.dp`` ServingEngine replicas, one admission queue."""

    def __init__(self, spec: RuntimeSpec, *, devices=None, rng=None):
        import jax

        mesh = spec.mesh
        if mesh.dp < 1:
            raise ValueError(f"mesh.dp must be >= 1, got {mesh.dp}")
        need = mesh.n_devices
        devs = list(devices) if devices is not None else jax.devices()[:need]
        if len(devs) < need:
            raise ValueError(
                f"mesh tp={mesh.tp} x dp={mesh.dp} needs {need} devices but "
                f"only {len(devs)} are visible; call "
                "launch.mesh.ensure_host_devices(n) before importing jax "
                "(or pass devices=)")
        self.spec = spec
        replica_spec = dataclasses.replace(
            spec, mesh=MeshSpec(tp=mesh.tp, dp=1))
        self.replicas: list[ServingEngine] = [
            ServingEngine(replica_spec, rng=rng,
                          devices=devs[i * mesh.tp:(i + 1) * mesh.tp])
            for i in range(mesh.dp)
        ]
        self.events = EventBus()
        self.stats: dict[str, int] = {"decode_steps": 0}
        self._uid = 0
        # per-replica {replica uid -> cluster uid}; entries live from
        # submit to finish (spanning preempt/re-admit cycles)
        self._maps: list[dict[int, int]] = [{} for _ in self.replicas]
        for i, eng in enumerate(self.replicas):
            eng.events.subscribe(self._relay(i))

    # ------------------------------------------------------------------
    def _relay(self, idx: int):
        """Republish one replica's events under cluster uids + clock."""

        def cb(e: EngineEvent) -> None:
            if not self.events.active:
                return
            uid = self._maps[idx].get(e.uid)
            if uid is None:        # event for a request we didn't route
                return
            self.events.publish(EngineEvent(
                e.kind, uid, self.stats["decode_steps"], e.t, e.data))

        return cb

    def load(self, params) -> None:
        """Install the same weights on every replica."""
        for eng in self.replicas:
            eng.load(params)

    # ------------------------------------------------------------------
    def _place(self, prompt_len: int) -> int:
        """Replica index with the most free capacity net of queued
        demand; ties to the lowest index (deterministic routing)."""
        best, best_score = 0, None
        for i, eng in enumerate(self.replicas):
            if eng.paging is not None:
                bs = eng.paging.block_size
                demand = sum(
                    blocks_for_tokens(len(r.prompt) + len(r.prefix), bs)
                    for r in eng.queue)
                score = eng.allocator.num_free - demand
            else:
                free = sum(r is None for r in eng.slot_req)
                score = free - len(eng.queue)
            if best_score is None or score > best_score:
                best, best_score = i, score
        return best

    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               eos_id=None, sampling=None, model: int = 0) -> int:
        idx = self._place(len(prompt))
        eng = self.replicas[idx]
        # pre-register the uid mapping: the replica emits its "submit"
        # event *inside* submit(), and the relay needs the translation
        # already in place.  Every submit-side validation raises before
        # the replica increments its uid, so the prediction is exact;
        # roll back on raise.
        ruid = eng._uid + 1
        self._uid += 1
        self._maps[idx][ruid] = self._uid
        try:
            got = eng.submit(prompt, max_new_tokens=max_new_tokens,
                             eos_id=eos_id, sampling=sampling, model=model)
        except Exception:
            del self._maps[idx][ruid]
            self._uid -= 1
            raise
        assert got == ruid, "replica uid drifted from prediction"
        return self._uid

    # ------------------------------------------------------------------
    def _busy(self, eng: ServingEngine) -> bool:
        return bool(eng.queue) or any(r is not None for r in eng.slot_req)

    def step(self) -> list[Request]:
        """One cluster round: every replica with work advances one fused
        step.  Returns requests finished this round, uids rewritten to
        cluster uids."""
        done: list[Request] = []
        stepped = False
        for i, eng in enumerate(self.replicas):
            if not self._busy(eng):
                continue
            stepped = True
            for req in eng.step():
                req.uid = self._maps[i].pop(req.uid)
                done.append(req)
        if stepped:
            self.stats["decode_steps"] += 1
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        steps = 0
        while any(self._busy(eng) for eng in self.replicas):
            if steps >= max_steps:
                raise RuntimeError(
                    f"cluster did not drain within max_steps={max_steps}")
            done += self.step()
            steps += 1
        return done

    # ------------------------------------------------------------------
    # replay-surface views (harness.driver touches only these)
    # ------------------------------------------------------------------
    @property
    def queue(self) -> list[Request]:
        return [r for eng in self.replicas for r in eng.queue]

    @property
    def slot_req(self) -> list[Request | None]:
        return [r for eng in self.replicas for r in eng.slot_req]

    @property
    def compilations(self) -> list[dict[str, int]]:
        """Per-replica compile counts (the census asserts decode == 1 on
        every replica)."""
        return [dict(eng.compilations) for eng in self.replicas]

    def replica_stats(self) -> list[dict[str, Any]]:
        return [dict(eng.stats) for eng in self.replicas]
