"""Structured per-request lifecycle events emitted by the serving engine.

The engine used to grow an ad-hoc ``stats`` dict whenever a benchmark
needed a new counter; anything finer-grained (when did request 17 get
its first token?) meant another bespoke polling loop around
``engine.step()`` with its own ``device_get``.  This module is the
replacement: the engine publishes one :class:`EngineEvent` per request
lifecycle transition through an :class:`EventBus`, and consumers (the
load harness, benchmarks, tests) subscribe instead of polling.

Lifecycle of one request::

    submit ──> admit ──> first_token ──> progress* ──> finish
                  └──────────── preempt ──> admit ...(re-entry)

* ``submit``       — the request entered the engine queue.
  data: ``prompt_len``, ``max_new_tokens``, ``model``.
* ``admit``        — the request was seated in a slot.
  data: ``slot``, ``cached_tokens`` (prefix-cache hit span, 0 otherwise).
* ``first_token``  — the request's first token exists on device.  Under
  the bucketed scheduler this coincides with ``admit`` (the prefill
  dispatch samples it); under the chunked scheduler it is the fused step
  whose chunk grant completes the prompt.
* ``progress``     — one per occupied slot per harvest sync, carrying
  the slot's generated-token ``count``.  Emitted *after* the harvest's
  bulk ``device_get``, so its wall-clock stamp is completion-honest
  (the dispatch-side stamps on ``first_token`` are not — use the first
  ``progress`` with ``count >= 1`` for wall-clock TTFT).  With
  speculative decoding the event also carries the slot's cumulative
  ``accepted`` (draft tokens the target verified) and ``spec_steps``
  (fused steps the slot spec-decoded in) — both in the deterministic
  step currency, reduced by ``harness.metrics`` into the
  mean-accepted-draft-length metric.
* ``finish``       — the request completed and was harvested.
  data: ``n_generated``.
* ``preempt``      — the slot was recompute-preempted; the request
  re-enters admission later.  data: ``banked`` (tokens carried over).

Every event carries the engine's logical clock (``step`` = fused
dispatches so far) and a ``time.perf_counter()`` wall stamp.  Step
arithmetic is bit-reproducible across runs; wall stamps are not — the
harness keeps the two strictly separated for exactly that reason.

The bus costs one attribute check per would-be event when nobody
subscribed, so the engine's normal (harness-free) operation is
unchanged; the ``stats`` counters stay as the cheap always-on summary.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

EVENT_KINDS = ("submit", "admit", "first_token", "progress", "finish",
               "preempt")


@dataclass(frozen=True)
class EngineEvent:
    """One lifecycle transition of one request."""

    kind: str                 # one of EVENT_KINDS
    uid: int                  # engine request uid
    step: int                 # engine logical clock (fused dispatches)
    t: float                  # wall stamp (time.perf_counter())
    data: dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; expected "
                             f"one of {EVENT_KINDS}")


class EventBus:
    """Tiny synchronous pub/sub: subscribers are called in order, on the
    engine's host thread, at emission time."""

    def __init__(self) -> None:
        self._subs: list[Callable[[EngineEvent], None]] = []

    @property
    def active(self) -> bool:
        """True when at least one subscriber would see an event — the
        engine skips event construction entirely otherwise."""
        return bool(self._subs)

    def subscribe(self, cb: Callable[[EngineEvent], None]) -> None:
        self._subs.append(cb)

    def unsubscribe(self, cb: Callable[[EngineEvent], None]) -> None:
        self._subs.remove(cb)

    def publish(self, event: EngineEvent) -> None:
        for cb in self._subs:
            cb(event)


class EventLog:
    """The standard subscriber: an append-only list with per-uid views."""

    def __init__(self) -> None:
        self.events: list[EngineEvent] = []

    def __call__(self, event: EngineEvent) -> None:
        self.events.append(event)

    def __len__(self) -> int:
        return len(self.events)

    def of_kind(self, kind: str) -> list[EngineEvent]:
        return [e for e in self.events if e.kind == kind]

    def of_uid(self, uid: int) -> list[EngineEvent]:
        return [e for e in self.events if e.uid == uid]


def now() -> float:
    return time.perf_counter()
