"""Batched serving engine with device-resident continuous batching.

Compile-once discipline (the paper's Alg. 18 applied to serving):

* **chunked scheduler** (default wherever the family supports it) — ONE
  fused mixed step, compiled exactly once, does everything: prompts are
  split into fixed ``chunk_size`` chunks and up to ``token_budget``
  prompt tokens ride *inside the same jitted step* that decodes active
  slots (a Sarathi-style mixed batch).  Every slot advances by up to W =
  chunk_size query lanes per dispatch — a decoding slot uses one lane, a
  prefilling slot a chunk of its prompt (gathered on device from
  ``SlotState.prompt_buf``), an idle slot none.  Prefill compilations
  drop from O(#buckets x modes) to O(1) and a long prompt never stalls
  the decoding slots sharing its batch.  The cache and ``SlotState`` are
  donated to the step (``donate_argnums``), so XLA updates the KV pool
  in place instead of copying it every token.
* **bucketed scheduler** (legacy; families with sequential prefill
  state) — ``prefill_fn`` compiled per prompt-length *bucket* (powers of
  two up to max_len): a new request is padded up to its bucket,
  prefilled at B=1, and its cache is scattered into the shared batched
  cache; ``decode_fn`` is the one-lane fused step.  Idle slots compute
  masked garbage (idle PEs) that never reaches a live output.

Host↔device discipline (the paper's "no host intervention beyond the
topology registers"): **all** per-slot state lives in device arrays
(``SlotState``).  The host only *dispatches* the fused step and harvests
finished requests with one bulk ``device_get`` of the (done, count)
vectors per sync — O(1) transfers per step regardless of ``max_batch``.
Finished token buffers are pulled with one more bulk get, sliced to the
longest finished stream (never ``max_len`` columns).

Cache layouts (the paper's tiling discipline applied to KV memory):

* ``cache_layout="dense"`` — per-slot ``[max_batch, max_len]`` rows; a
  request of length 40 pays for ``max_len``, so concurrency is bounded
  by the worst case.
* ``cache_layout="paged"`` — a pooled ``[num_blocks, block_size, ...]``
  cache (``core.paging``): a request is **admitted when the blocks for
  its prompt are free**, blocks are appended as decode crosses block
  boundaries (pre-reserved per sync window, so the fused step still
  needs zero host intervention) and returned to the free list at
  harvest.  When the pool runs dry mid-flight the most recently admitted
  slot is preempted (its tokens are banked and the request re-queued for
  recompute-resume), so the oldest request always completes.

Configuration surface: the engine is built from one frozen
``core.spec.RuntimeSpec`` — ``ServingEngine(spec)``.  Every knob the
constructor used to take piecemeal (``matmul_backend``, ``cache_layout``,
``block_size``, ``num_blocks``) now lives in ``spec.execution`` /
``spec.memory``; the old ``ServingEngine(model, kwarg=...)`` spellings
keep working for one release behind ``DeprecationWarning`` shims.

Multi-topology serving (the paper's §3.12 payoff): ``ServingEngine(spec,
maxima=...)`` compiles the register-driven ``serving.fabric`` at the
maxima instead of one fixed architecture.  ``add_model(params, arch)``
packs any dense-family model into the device-resident weight table, each
slot carries its model's topology registers inside ``SlotState``, and the
one fused decode step serves a mixed fleet — continuous batching *across
models*, zero retraces.

Fully-quantized serving: ``spec.execution.quant="int8"`` quantizes the
weights (including the fleet's weight table — int8 values + f32 scales
per member) and ``spec.memory.kv_dtype="int8"`` swaps the KV cache for
the ``core.kv_quant`` codec (quantize-on-write int8 with per-row scales,
~2x concurrent capacity at equal HBM) in every mode — dense, paged,
chunked, fleet.  See README "Fully-quantized serving".
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.core.paging import (NULL_BLOCK, BlockAllocator, FragmentationStats,
                               PrefixCache, blocks_for_tokens)
from repro.core.jitutil import strict_jit
from repro.core.kv_quant import fork_block
from repro.core.spec import (CHUNKABLE_FAMILIES, ExecutionSpec, MemorySpec,
                             RuntimeSpec)
from repro.kernels.runtime import interpret_default
from repro.models import backend
from repro.models.model import Model
from repro.serving.events import EngineEvent, EventBus
from repro.serving.events import now as _now
from repro.serving.fabric import N_REGS, DecodeFabric
from repro.serving.sampling import (SamplingParams, fold_in_keys,
                                    sample_per_slot, speculative_accept,
                                    split_keys)

# The always-on summary counters.  These are *derived* telemetry kept for
# backward compatibility (tests and benchmarks read them); anything
# per-request or per-step now flows through the structured event surface
# (``serving.events`` / ``engine.events``) instead of growing this dict.
_STAT_KEYS = ("decode_steps", "device_gets", "harvest_elems", "preemptions",
              "prefill_tokens", "max_step_prefill_tokens", "prefix_hits",
              "prefix_hit_tokens", "cow_forks", "prefix_evictions",
              "spec_steps", "spec_accepted")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    sampling: SamplingParams | None = None   # None -> engine default
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int | None = None
    # tokens generated before a preemption; on re-admission they extend
    # the prompt (recompute-resume) and still count against the budget
    prefix: list[int] = dataclasses.field(default_factory=list)
    # fleet member serving this request (multi-topology mode; 0 otherwise)
    model: int = 0


class SlotState(NamedTuple):
    """All per-slot decode state, resident on device (one pytree)."""

    last: jax.Array    # [B, 1] i32  token fed to the next decode step
    index: jax.Array   # [B]    i32  cache write position
    active: jax.Array  # [B]    bool slot is live (prefilling or decoding)
    done: jax.Array    # [B]    bool finished, not yet harvested/reused
    budget: jax.Array  # [B]    i32  max_new_tokens (incl. prefill token)
    count: jax.Array   # [B]    i32  tokens generated so far
    eos: jax.Array     # [B]    i32  eos id, -1 = none
    temp: jax.Array    # [B]    f32  sampling temperature (0 = greedy)
    top_k: jax.Array   # [B]    i32  top-k cutoff (0 = disabled)
    top_p: jax.Array   # [B]    f32  nucleus threshold (1 = disabled)
    buf: jax.Array     # [B, max_len] i32 generated tokens
    # [B, 2] u32 per-slot PRNG key lanes, split once per fused step: each
    # slot's sampling stream is a pure function of its own lane, so a
    # harness replay is byte-identical regardless of batch composition
    rng: jax.Array
    topo: jax.Array    # [B, N_REGS] i32 per-slot topology registers
    # chunked-prefill progress (the token-budget scheduler's device side)
    prompt_buf: jax.Array  # [B, max_len] i32 prompt tokens, chunk source
    prompt_len: jax.Array  # [B] i32 total prompt length
    pf_pos: jax.Array      # [B] i32 prompt tokens already written to cache
    # speculative-decoding accounting (zeros when speculation is off)
    acc: jax.Array         # [B] i32 accepted draft tokens, cumulative
    spec_steps: jax.Array  # [B] i32 fused steps this slot spec-decoded in


class _Compilations(dict):
    """Compile-count mapping that is also callable: both the historical
    ``engine.compilations["decode"]`` property spelling and the newer
    ``engine.compilations()["prefill"]`` read the same accounting."""

    def __call__(self) -> "_Compilations":
        return self


def _buckets(max_len: int, smallest: int = 32) -> list[int]:
    out, b = [], smallest
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


def _resolve_spec(spec, maxima, max_batch, max_len, matmul_backend,
                  cache_layout, block_size, num_blocks):
    """Normalize the constructor surface onto one ``RuntimeSpec``.

    Returns ``(spec, model)``; ``model`` is the caller's ``Model``
    instance when the legacy model-first spelling was used (kept so the
    inherit path can reuse it without re-tracing).  The legacy per-knob
    kwargs are deprecation shims: they still work, warn once, and are
    folded into the spec so everything downstream reads one surface.
    """
    legacy = {k: v for k, v in (("matmul_backend", matmul_backend),
                                ("cache_layout", cache_layout),
                                ("block_size", block_size),
                                ("num_blocks", num_blocks)) if v is not None}
    if legacy:
        warnings.warn(
            "ServingEngine(" + ", ".join(f"{k}=..." for k in sorted(legacy))
            + ") is deprecated; configure these through core.spec."
              "RuntimeSpec — execution=ExecutionSpec(matmul_backend=...), "
              "memory=MemorySpec(cache_layout=..., block_size=..., "
              "num_blocks=...) — and pass the spec to ServingEngine",
            DeprecationWarning, stacklevel=3)
    if isinstance(spec, Model):
        model = spec
        opt = model.opt
        ex = ExecutionSpec(
            matmul_backend=legacy.get("matmul_backend", opt.matmul_backend),
            paged_attn_impl=opt.paged_attn_impl,
            param_dtype=opt.param_dtype,
            compute_dtype=opt.compute_dtype,
            grouped_gqa=opt.grouped_gqa)
        mem = MemorySpec(
            cache_layout=legacy.get("cache_layout", "dense"),
            max_batch=8 if max_batch is None else max_batch,
            max_len=512 if max_len is None else max_len,
            block_size=legacy.get("block_size", 16),
            num_blocks=legacy.get("num_blocks"))
        return RuntimeSpec(arch=model.cfg, maxima=maxima, execution=ex,
                           memory=mem), model
    if not isinstance(spec, RuntimeSpec):
        raise TypeError(
            "ServingEngine expects a core.spec.RuntimeSpec (or a legacy "
            f"Model), got {type(spec).__name__}")
    ex, mem = spec.execution, spec.memory
    if "matmul_backend" in legacy:
        ex = dataclasses.replace(ex, matmul_backend=legacy["matmul_backend"])
    mem_kw = {k: v for k, v in legacy.items()
              if k in ("cache_layout", "block_size", "num_blocks")}
    if max_batch is not None:
        mem_kw["max_batch"] = max_batch
    if max_len is not None:
        mem_kw["max_len"] = max_len
    if mem_kw:
        mem = dataclasses.replace(mem, **mem_kw)
    if maxima is None:
        maxima = spec.maxima
    if ex is not spec.execution or mem is not spec.memory \
            or maxima is not spec.maxima:
        spec = dataclasses.replace(spec, execution=ex, memory=mem,
                                   maxima=maxima)
    return spec, None


class ServingEngine:
    def __init__(self, spec: RuntimeSpec | Model, *,
                 maxima=None, max_models: int = 4,
                 sampling: SamplingParams = SamplingParams(),
                 rng: jax.Array | None = None,
                 devices=None,
                 max_batch: int | None = None,
                 max_len: int | None = None,
                 matmul_backend: str | None = None,
                 cache_layout: str | None = None,
                 block_size: int | None = None,
                 num_blocks: int | None = None):
        spec, model = _resolve_spec(spec, maxima, max_batch, max_len,
                                    matmul_backend, cache_layout,
                                    block_size, num_blocks)
        cfg = spec.arch
        if cfg.family == "encoder":
            raise ValueError("encoder-only archs have no decode step")
        self.spec = spec
        self.cfg: ArchConfig = cfg
        self.max_batch = spec.memory.max_batch
        self.max_len = spec.memory.max_len
        self.sampling = sampling
        self.buckets = _buckets(self.max_len)
        self.matmul_backend = spec.execution.matmul_backend
        # Pallas kernels need interpret mode off-TPU; evaluated once here
        # instead of on every fused dispatch
        self._interpret = interpret_default()

        # ---- scheduler: chunked (token-budget) or bucketed ---------------
        sched = spec.scheduler
        chunkable = (spec.maxima is not None
                     or cfg.family in CHUNKABLE_FAMILIES) \
            and not sched.chunk_violations(spec.memory)
        if sched.policy == "auto":
            self.scheduler = "chunked" if chunkable else "bucketed"
        else:
            # an unsatisfiable explicit "chunked" was rejected by
            # RuntimeSpec.validate at construction
            self.scheduler = sched.policy
        self.chunk_size = min(sched.chunk_size, self.max_len)
        self.token_budget = sched.resolved_token_budget

        # ---- speculation: a draft model rides the fused step -------------
        # The draft decodes from its OWN private dense cache inside the
        # same jitted program (propose k tokens, one masked lane each),
        # then the target verifies all k+1 positions as a chunk-shaped
        # attend.  ``spec_horizon`` = k+1 is the positions a decoding slot
        # may consume per fused step — block budgeting scales by it.
        sp = spec.speculation
        self.speculation = sp
        self.spec_horizon = 1 if sp is None else sp.horizon
        self.draft_model: Model | None = None
        self.draft_params: Any = None
        self.draft_cache: Any = None
        if sp is not None:
            if self.scheduler != "chunked":
                raise ValueError(
                    "speculation requires the chunked scheduler, but policy "
                    "'auto' resolved to 'bucketed' for this spec; fix the "
                    "chunk geometry so chunked is satisfiable")
            if sp.horizon > self.chunk_size:
                raise ValueError(
                    f"SpeculationSpec.k={sp.k} needs {sp.horizon} verify "
                    f"lanes but the engine's chunk width is "
                    f"{self.chunk_size}; raise SchedulerSpec.chunk_size")
            from repro.models.model import ModelOptions
            # the draft's cache is always dense + compute-dtype: it is
            # small, rolls back by index rewind alone, and never pages
            self.draft_model = Model(
                sp.draft_model,
                dataclasses.replace(
                    ModelOptions.from_execution(spec.execution),
                    kv_dtype="compute"))

        # ---- tensor-parallel mesh (spec.mesh.tp devices per fused step) --
        # MeshSpec(tp=1) without an explicit device list is the historical
        # single-device engine: no mesh object, identical lowering.  With
        # tp > 1 (or an explicit ``devices=`` placement, how EngineCluster
        # pins each DP replica to its own device slice) the engine builds a
        # (data=1, model=tp) mesh: params shard via the logical-axis rules,
        # the cache's kv-head axis shards via ``kv_cache_shardings``, and
        # SlotState / block tables replicate.  spec.validate() already
        # rejected tp > 1 with fleet mode / Pallas kernels / bucketed.
        tp = spec.mesh.tp
        if spec.mesh.dp > 1 and devices is None:
            raise ValueError(
                f"spec.mesh.dp={spec.mesh.dp}: data parallelism is replica-"
                "level — construct serving.cluster.EngineCluster(spec) (one "
                "ServingEngine is a single replica; EngineCluster passes "
                "each replica its device slice via devices=)")
        self._mesh = self._strategy = self._cache_shardings = None
        self._device = None
        if tp > 1 or devices is not None:
            if tp > 1 and self.scheduler != "chunked":
                raise ValueError(
                    "mesh.tp > 1 requires the chunked scheduler, but policy "
                    "'auto' resolved to 'bucketed' for this spec (the "
                    "bucketed path stages B=1 prefill caches off-mesh); fix "
                    "the chunk geometry so chunked is satisfiable")
            devs = list(devices) if devices is not None \
                else jax.devices()[:tp]
            if len(devs) < tp:
                raise ValueError(
                    f"mesh.tp={tp} needs {tp} devices but only {len(devs)} "
                    "are visible; on CPU force virtual host devices before "
                    "jax initializes (launch.mesh.ensure_host_devices(n) / "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=n)")
            if tp > 1:
                self._mesh = shd.tp_mesh(devs[:tp])
                self._strategy = shd.strategy_for_mesh(self._mesh)
            else:
                # tp=1 replica pinned to one device: no GSPMD at all.  A
                # 1x1 mesh would work but poisons the jit cache — device_put
                # commits NamedShardings while the step's outputs come back
                # SingleDeviceSharding, and the sharding mismatch recompiles
                # the step on its second call (sharding is part of the C++
                # jit cache key).  Committed single-device placement gives
                # one stable key and disjoint replica residency for free.
                self._device = devs[0]

        # ---- compute path: one fixed model, or the register fabric -------
        if spec.maxima is not None:
            # multi-topology mode: one compiled step at the maxima serves a
            # fleet of models selected by per-slot registers (add_model)
            if spec.execution.matmul_backend != "xla":
                raise ValueError(
                    f"matmul_backend={spec.execution.matmul_backend!r} is "
                    "not yet supported in multi-topology mode: the fabric's "
                    "per-slot weight gathers do not route through the "
                    "tiled-kernel backend (use the default 'xla'; for "
                    "quantized fleet serving use "
                    "ExecutionSpec(quant='int8') — the fabric packs an "
                    "int8 weight table itself — see README "
                    "'Fully-quantized serving')")
            self.fabric: DecodeFabric | None = DecodeFabric(
                spec.maxima, max_models, cfg,
                compute_dtype=spec.execution.compute_dtype,
                param_dtype=spec.execution.param_dtype,
                quant=spec.execution.quant,
                quant_min_size=spec.execution.quant_min_size,
                kv_dtype=spec.memory.kv_dtype)
            self.fabric.check_member(cfg)
            self.model: Model | None = None
            self._traced_model: Model | None = None
            self.fleet: list[ArchConfig | None] = [None] * max_models
            self._fleet_rows: list[list[int] | None] = [None] * max_models
        else:
            self.fabric = None
            # single source of truth: the backend every trace uses is
            # spec.execution.matmul_backend.  A caller's Model instance is
            # kept when it already agrees; with a legacy override the
            # traced model is rebuilt around the spec's backend but keeps
            # its other build options (remat/unroll are training-side
            # knobs the spec does not model — the shim must not reset
            # them)
            if model is None:
                self.model = Model.from_spec(spec)
            elif model.opt.matmul_backend == self.matmul_backend \
                    and model.opt.kv_dtype == spec.memory.kv_dtype:
                self.model = model
            else:
                self.model = Model(cfg, dataclasses.replace(
                    model.opt, matmul_backend=self.matmul_backend,
                    kv_dtype=spec.memory.kv_dtype))
            self._traced_model = self.model

        # ---- cache layout -------------------------------------------------
        self.paging = spec.memory.paging()
        max_batch, max_len = self.max_batch, self.max_len
        if self.paging is not None:
            bs = self.paging.block_size
            if self.buckets[0] % bs:
                raise ValueError(
                    f"block_size={bs} must divide the smallest prefill "
                    f"bucket {self.buckets[0]}")
            self.allocator = BlockAllocator(self.paging)
            self.blocks_per_slot = max_len // bs
            self._tables = [[NULL_BLOCK] * self.blocks_per_slot
                            for _ in range(max_batch)]
            self._slot_blocks: list[list[int]] = [[] for _ in range(max_batch)]
            self._tables_dirty = True
            self.block_tables: jax.Array | None = jnp.zeros(
                (max_batch, self.blocks_per_slot), jnp.int32)
        else:
            self.allocator = None
            self.block_tables = None

        # ---- prefix cache (paged + chunked only) -------------------------
        self.prefix_cache: PrefixCache | None = None
        if spec.memory.prefix_cache:
            if self.scheduler != "chunked":
                raise ValueError(
                    "prefix_cache=True requires the chunked scheduler, but "
                    "policy 'auto' resolved to 'bucketed' for this spec "
                    "(a cache-hit request resumes prefill mid-prompt, which "
                    "only the fused chunked step supports); fix the chunk "
                    "geometry so the chunked scheduler is satisfiable")
            self.prefix_cache = PrefixCache(self.allocator)
        # one-shot per occupancy: a slot's prompt blocks are registered in
        # the trie once its prefill completes
        self._reg_done = [False] * max_batch
        # host mirrors for block budgeting (exact at sync points; between
        # syncs ``_idx_ub`` is a per-step upper bound on the device index)
        self._plen = [0] * max_batch
        self._budget = [0] * max_batch
        self._idx_ub = [0] * max_batch
        self._admit_seq = [0] * max_batch
        self._seq = 0
        # chunked-prefill progress mirror: exact, because the host grants
        # every chunk itself — no device read needed
        self._pf = [0] * max_batch

        self.params: Any = None
        self.cache: Any = None
        if self.fabric is not None:
            # the fabric's synthesis-time buffers exist before any model is
            # loaded — add_model only writes device data into them
            self.params = self.fabric.init_table()
            self.cache = self.fabric.init_cache(max_batch, max_len,
                                                paging=self.paging)
            if self._placement is not None:
                # DP replica placement: the fabric's table and cache live
                # whole on this replica's device (slice); add_model's
                # scatters and the fused step keep that placement because
                # every other operand follows the committed arrays
                self.params = jax.device_put(self.params, self._placement)
                self.cache = jax.device_put(self.cache, self._placement)
        self.state: SlotState = self._init_state(
            rng if rng is not None else jax.random.PRNGKey(0))
        if self._placement is not None:
            self.state = jax.device_put(self.state, self._placement)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self._uid = 0
        # host↔device traffic accounting (asserted O(1)/step by the tests);
        # harvest_elems counts i32 elements pulled for finished buffers —
        # bounded by the finished streams' lengths, not max_len
        self.stats = dict.fromkeys(_STAT_KEYS, 0)
        # structured lifecycle events (serving.events): subscribers see
        # submit/admit/first_token/progress/finish/preempt per request.
        # Publishing is skipped entirely while nobody subscribes.
        self.events = EventBus()
        # uids whose first token was already announced — a re-admission
        # after preemption must not emit first_token twice
        self._ft_emitted: set[int] = set()

        # the cache and SlotState are donated: XLA aliases the KV pool and
        # the slot buffers in place of copying them on every fused step.
        # strict_jit raises (REPRO_STRICT=1) if XLA ever demotes that
        # aliasing to a copy instead of warning into the void.
        self._decode = strict_jit(self._decode_impl, donate_argnums=(1, 2))
        self._step = strict_jit(self._mixed_impl, donate_argnums=(1, 2))
        self._prefill = {}        # bucket -> jitted fn (bucketed path)
        self._insert = jax.jit(self._insert_impl, static_argnums=(3,))
        self._insert_paged = jax.jit(self._insert_paged_impl,
                                     static_argnums=(3,))
        self._admit_slot = jax.jit(self._admit_slot_impl)
        self._admit_chunk = jax.jit(self._admit_chunk_impl)
        self._evict_slot = jax.jit(self._evict_slot_impl)
        # copy-on-write fork: duplicate one pool block (values + scales)
        # before a cache-hit request writes past the divergence point.
        # src/dst are traced scalars — one compilation, cache donated.
        self._cow = strict_jit(self._cow_impl, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _init_state(self, rng: jax.Array) -> SlotState:
        B = self.max_batch
        return SlotState(
            last=jnp.zeros((B, 1), jnp.int32),
            index=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool),
            done=jnp.zeros((B,), bool),
            budget=jnp.zeros((B,), jnp.int32),
            count=jnp.zeros((B,), jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
            temp=jnp.zeros((B,), jnp.float32),
            top_k=jnp.zeros((B,), jnp.int32),
            top_p=jnp.ones((B,), jnp.float32),
            buf=jnp.zeros((B, self.max_len), jnp.int32),
            rng=jax.random.split(rng, B),
            topo=jnp.zeros((B, N_REGS), jnp.int32),
            prompt_buf=jnp.zeros((B, self.max_len), jnp.int32),
            prompt_len=jnp.zeros((B,), jnp.int32),
            pf_pos=jnp.zeros((B,), jnp.int32),
            acc=jnp.zeros((B,), jnp.int32),
            spec_steps=jnp.zeros((B,), jnp.int32))

    def _emit(self, kind: str, uid: int, **data) -> None:
        """Publish one lifecycle event (no-op without subscribers).  The
        event's logical clock is the fused-dispatch count, so event
        arithmetic is bit-reproducible; the wall stamp is not."""
        if self.events.active:
            self.events.publish(EngineEvent(
                kind, uid, self.stats["decode_steps"], _now(), data))

    def _emit_first_token(self, uid: int) -> None:
        """``first_token`` exactly once per uid — a request re-admitted
        after preemption already announced its first token."""
        if self.events.active and uid not in self._ft_emitted:
            self._ft_emitted.add(uid)
            self.events.publish(EngineEvent(
                "first_token", uid, self.stats["decode_steps"], _now(), {}))

    def load(self, params, draft=None) -> None:
        """Install weights (quantized here when ``spec.execution.quant``
        asks for it).  Multi-topology mode: equivalent to
        ``add_model(params)`` for the engine's own architecture.
        ``draft`` installs the speculation draft's weights in the same
        call (sugar for :meth:`load_draft`)."""
        if draft is not None and self.speculation is None:
            raise ValueError(
                "load(draft=...) requires spec.speculation — construct the "
                "RuntimeSpec with speculation=SpeculationSpec(...)")
        if self.fabric is not None:
            self.add_model(params)
        else:
            if self.spec.execution.quant == "int8":
                from repro.core.serve_quant import quantize_params
                params = quantize_params(
                    params, min_size=self.spec.execution.quant_min_size)
            self.params = params
            self.cache = self.model.init_cache(self.max_batch, self.max_len,
                                               paging=self.paging)
            if self._mesh is not None or self._device is not None:
                self._shard_arrays()
        if draft is not None:
            self.load_draft(draft)

    def load_draft(self, params) -> None:
        """Install the speculation draft's weights and its private dense
        KV cache.  The draft never pages and never quantizes its cache —
        it is small by design, and rejected-suffix rollback on a dense
        cache is a pure index rewind (stale rows are masked by the causal
        window and overwritten on the next propose pass).  On a TP mesh
        the draft replicates whole — its work is k one-lane decodes."""
        if self.speculation is None:
            raise ValueError(
                "load_draft requires spec.speculation — construct the "
                "RuntimeSpec with speculation=SpeculationSpec(...)")
        if self.spec.execution.quant == "int8":
            from repro.core.serve_quant import quantize_params
            params = quantize_params(
                params, min_size=self.spec.execution.quant_min_size)
        self.draft_params = params
        self.draft_cache = self.draft_model.init_cache(self.max_batch,
                                                       self.max_len)
        if self._placement is not None:
            self.draft_params = jax.device_put(self.draft_params,
                                               self._placement)
            self.draft_cache = jax.device_put(self.draft_cache,
                                              self._placement)

    @property
    def _placement(self):
        """device_put target for whole (replicated) arrays: the mesh's
        replicated sharding, the pinned replica device, or None for the
        historical uncommitted single-device engine."""
        if self._mesh is not None:
            return shd.replicated(self._mesh)
        return self._device

    def _shard_arrays(self) -> None:
        """Lower ``spec.mesh`` through ``distributed.sharding``: params
        via the logical-axis rules the models already annotate
        (``model.axes()``), the cache via its kv-head axis, block tables
        replicated.  Committed placements matter beyond locality — the
        donated fused step must see inputs already laid out like its
        outputs, or strict_jit's donation contract trips."""
        if self._mesh is None:
            self.params = jax.device_put(self.params, self._device)
            self.cache = jax.device_put(self.cache, self._device)
            if self.block_tables is not None:
                self.block_tables = jax.device_put(self.block_tables,
                                                   self._device)
            return
        mesh, strategy = self._mesh, self._strategy
        axes, abstract = self.model.axes(), self.model.abstract()
        if self.spec.execution.quant == "int8":
            from repro.core.serve_quant import (quantize_abstract,
                                                quantize_axes)
            ms = self.spec.execution.quant_min_size
            axes = quantize_axes(axes, abstract, min_size=ms)
            abstract = quantize_abstract(abstract, min_size=ms)
        self.params = jax.device_put(
            self.params,
            shd.tree_param_shardings(mesh, axes, abstract, strategy))
        self._cache_shardings = shd.kv_cache_shardings(mesh, self.cache,
                                                       strategy)
        self.cache = jax.device_put(self.cache, self._cache_shardings)
        if self.block_tables is not None:
            self.block_tables = jax.device_put(self.block_tables,
                                               shd.replicated(mesh))

    def _pin_outputs(self, cache, state: SlotState):
        """In-graph output shardings for the donated (cache, state) pair:
        identical to the input shardings, so XLA's buffer donation holds
        under GSPMD.  No-op off-mesh (the jaxpr of the single-device
        engine is unchanged — the census fingerprints pin that)."""
        if self._mesh is None:
            return cache, state
        wsc = jax.lax.with_sharding_constraint
        if self._cache_shardings is not None:
            cache = jax.tree.map(wsc, cache, self._cache_shardings)
        rep = shd.replicated(self._mesh)
        state = jax.tree.map(lambda x: wsc(x, rep), state)
        return cache, state

    def _mesh_scope(self):
        """Activation-constraint scope for traced bodies: inside it the
        models' ``constrain(...)`` hints resolve against this engine's
        mesh (no-ops off-mesh)."""
        if self._mesh is None:
            return contextlib.nullcontext()
        return shd.active(self._mesh, self._strategy)

    def add_model(self, params, arch: ArchConfig | None = None) -> int:
        """Pack one fleet member's weights into the fabric's model table
        and return its model id (pass to ``submit(..., model=id)``).

        A device scatter, never a retrace: the table rows are synthesis-
        time buffers, loading a model is the paper's weight-write step.
        """
        if self.fabric is None:
            raise ValueError(
                "add_model requires multi-topology mode — construct the "
                "engine with ServingEngine(spec, maxima=...)")
        if isinstance(arch, RuntimeSpec):
            arch = arch.arch
        arch = arch or self.cfg
        mid = next((i for i, a in enumerate(self.fleet) if a is None), None)
        if mid is None:
            raise ValueError(
                f"model table full ({self.fabric.max_models} rows); "
                "construct the engine with a larger max_models")
        row = self.fabric.pack_member(arch, params)
        self.params = self.fabric.insert_model(self.params, row, mid)
        self.fleet[mid] = arch
        self._fleet_rows[mid] = self.fabric.topo_row(arch, mid)
        return mid

    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               eos_id: int | None = None,
               sampling: SamplingParams | None = None,
               model: int = 0) -> int:
        # reject at the door: raising later, mid-drain, would abort
        # run_to_completion with live requests still in flight.  The guard
        # mirrors the decode finish condition (index >= max_len): every
        # admitted request can use the full cache, so a max_len prompt is
        # fine when its one token comes from the prefill sample.
        if not prompt:
            raise ValueError("empty prompt: the engine needs at least one "
                             "token to condition on")
        if len(prompt) > self.max_len:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"max_len={self.max_len}")
        if len(prompt) == self.max_len and max_new_tokens > 1:
            raise ValueError(
                f"prompt length {len(prompt)} leaves no cache position for "
                f"decode (max_len={self.max_len}); max_new_tokens must be 1")
        if self.paging is not None:
            need = blocks_for_tokens(len(prompt), self.paging.block_size)
            if need > self.paging.num_blocks:
                # an unadmittable request would sit in the queue forever
                raise ValueError(
                    f"prompt needs {need} blocks but the pool has only "
                    f"{self.paging.num_blocks}; increase num_blocks")
        if self.fabric is not None:
            if not 0 <= model < len(self.fleet) or self.fleet[model] is None:
                loaded = [i for i, a in enumerate(self.fleet) if a is not None]
                raise ValueError(f"model id {model} is not loaded "
                                 f"(loaded ids: {loaded}); call add_model")
            vocab = self.fleet[model].vocab_size
            if prompt and not all(0 <= t < vocab for t in prompt):
                raise ValueError(
                    f"prompt contains token ids outside model {model}'s "
                    f"vocab [0, {vocab})")
        elif model != 0:
            raise ValueError("submit(model=...) requires multi-topology "
                             "mode (ServingEngine(spec, maxima=...))")
        else:
            vocab = self.cfg.vocab_size
            if not all(0 <= t < vocab for t in prompt):
                # out-of-range ids are not just garbage-in: XLA clamps the
                # OOB embedding gather, and a *sharded* table clamps to a
                # different row than an unsharded one — the same submit
                # would stream different tokens on different meshes
                raise ValueError(
                    f"prompt contains token ids outside vocab [0, {vocab})")
        self._uid += 1
        self.queue.append(Request(self._uid, list(prompt), max_new_tokens,
                                  eos_id, sampling, model=model))
        self._emit("submit", self._uid, prompt_len=len(prompt),
                   max_new_tokens=max_new_tokens, model=model)
        return self._uid

    # ------------------------------------------------------------------
    # jitted impls (traced under the configured matmul backend)
    # ------------------------------------------------------------------
    def _prefill_impl(self, bucket: int, params, tokens, extras):
        with backend.use(self.matmul_backend):
            batch = {"tokens": tokens, **extras}
            # paged: the B=1 cache is only a staging buffer for the block
            # scatter, so bucket-sized is enough (and cheaper than max_len)
            cache_len = bucket if self.paging is not None else self.max_len
            logits, cache = self._traced_model.prefill(params, batch,
                                                       max_len=cache_len)
            return logits, cache

    def _prefill_fabric_impl(self, bucket: int, params, tokens, topo):
        """Fabric prefill: the member's topology registers are device data,
        so every fleet model shares this bucket's one compilation."""
        with backend.use(self.matmul_backend):
            cache_len = bucket if self.paging is not None else self.max_len
            return self.fabric.prefill(params, topo, tokens, cache_len)

    def _insert_impl(self, global_cache, one_cache, slot, _bucket):
        def put(g, o):
            if g.ndim == o.ndim and g.shape[0] == o.shape[0] and g.ndim >= 2 \
                    and g.shape[1] == self.max_batch:
                return g.at[:, slot].set(o[:, 0])      # [L, B, ...] stacked
            return g.at[slot].set(o[0])                # [B, ...] per-layer
        return jax.tree.map(put, global_cache, one_cache)

    def _insert_paged_impl(self, pool, one_cache, table_row, bucket: int):
        """Scatter a B=1 prefill cache into the block pool.

        Chunks past the prompt's allocated blocks carry padding garbage;
        their table entries are the null block, which absorbs them."""
        bs = self.paging.block_size
        nchunks = bucket // bs
        ids = table_row[:nchunks]

        def put(g, o):
            chunks = o.reshape(o.shape[0], nchunks, bs, *o.shape[3:])
            return g.at[:, ids].set(chunks)
        return jax.tree.map(put, pool, one_cache)

    def _admit_slot_impl(self, state: SlotState, last_logits, slot, plen,
                         budget, eos, temp, top_k, top_p,
                         topo) -> SlotState:
        """Seat one prefilled request: sample its first token and reset
        every per-slot field — all on device, no host round trip.
        ``topo`` writes the slot's topology registers (zeros when the
        engine serves a single fixed architecture)."""
        ks = jax.random.split(state.rng[slot])
        first = sample_per_slot(last_logits, ks[1:], temp[None], top_k[None],
                                top_p[None])[0]
        # spent: a 1-token budget is consumed by the prefill sample, an
        # eos prefill sample ends the request, and a max_len prompt has
        # no cache position left to decode into
        fin = (budget <= 1) | ((eos >= 0) & (first == eos)) \
            | (plen >= self.max_len)
        return SlotState(
            last=state.last.at[slot, 0].set(first),
            index=state.index.at[slot].set(plen),
            active=state.active.at[slot].set(~fin),
            done=state.done.at[slot].set(fin),
            budget=state.budget.at[slot].set(budget),
            count=state.count.at[slot].set(1),
            eos=state.eos.at[slot].set(eos),
            temp=state.temp.at[slot].set(temp),
            top_k=state.top_k.at[slot].set(top_k),
            top_p=state.top_p.at[slot].set(top_p),
            buf=state.buf.at[slot].set(0).at[slot, 0].set(first),
            rng=state.rng.at[slot].set(ks[0]),
            topo=state.topo.at[slot].set(topo),
            prompt_buf=state.prompt_buf,
            prompt_len=state.prompt_len.at[slot].set(plen),
            pf_pos=state.pf_pos.at[slot].set(plen),  # bucketed: prefilled
            acc=state.acc.at[slot].set(0),
            spec_steps=state.spec_steps.at[slot].set(0))

    def _admit_chunk_impl(self, state: SlotState, slot, toks, plen, budget,
                          eos, temp, top_k, top_p, topo,
                          start) -> SlotState:
        """Seat one request for chunked prefill: write its prompt into the
        device-resident chunk source and reset every per-slot field — the
        prompt is *not* run here; the fused mixed step consumes it chunk
        by chunk under the token budget.  ``start`` (a traced scalar, so
        no retrace) is the prefix-cache hit length: positions below it
        are already resident in the slot's mapped blocks, so prefill
        resumes mid-prompt exactly as it does after a chunk boundary —
        0 without a hit."""
        return SlotState(
            last=state.last.at[slot, 0].set(0),
            index=state.index.at[slot].set(start),
            active=state.active.at[slot].set(True),
            done=state.done.at[slot].set(False),
            budget=state.budget.at[slot].set(budget),
            count=state.count.at[slot].set(0),
            eos=state.eos.at[slot].set(eos),
            temp=state.temp.at[slot].set(temp),
            top_k=state.top_k.at[slot].set(top_k),
            top_p=state.top_p.at[slot].set(top_p),
            buf=state.buf.at[slot].set(0),
            rng=state.rng,
            topo=state.topo.at[slot].set(topo),
            prompt_buf=state.prompt_buf.at[slot].set(toks),
            prompt_len=state.prompt_len.at[slot].set(plen),
            pf_pos=state.pf_pos.at[slot].set(start),
            acc=state.acc.at[slot].set(0),
            spec_steps=state.spec_steps.at[slot].set(0))

    def _cow_impl(self, cache, src, dst):
        """Fork pool block ``src`` into ``dst`` across every cache leaf
        (values and int8 scale rows alike — ``kv_quant.fork_block``).
        Donated, so the pool's mesh sharding is re-pinned on the way out."""
        cache = fork_block(cache, src, dst)
        if self._mesh is not None and self._cache_shardings is not None:
            cache = jax.tree.map(jax.lax.with_sharding_constraint, cache,
                                 self._cache_shardings)
        return cache

    def _evict_slot_impl(self, state: SlotState, slot) -> SlotState:
        """Preemption: park a slot as idle (its tokens were banked on the
        host; the request re-enters through the normal admission path)."""
        return state._replace(
            active=state.active.at[slot].set(False),
            done=state.done.at[slot].set(False),
            count=state.count.at[slot].set(0),
            index=state.index.at[slot].set(0),
            prompt_len=state.prompt_len.at[slot].set(0),
            pf_pos=state.pf_pos.at[slot].set(0),
            acc=state.acc.at[slot].set(0),
            spec_steps=state.spec_steps.at[slot].set(0))

    def _decode_impl(self, params, cache, state: SlotState, block_tables):
        """The fused device step: decode -> sample -> scatter token ->
        advance indices/budgets -> raise done flags.  One dispatch, zero
        host syncs.  With speculation on, the steady-state decode program
        is the draft-propose / target-verify step specialized to zero
        prompt lanes (``decode_only``) — still exactly one compilation."""
        if self.speculation is not None:
            return self._spec_impl(params, cache, state, block_tables,
                                   None, decode_only=True)
        with backend.use(self.matmul_backend), self._mesh_scope():
            rng, keys = split_keys(state.rng)
            if self.fabric is not None:
                logits, cache = self.fabric.decode_step(
                    params, cache, state.last, state.index, state.topo,
                    block_tables=block_tables,
                    paged_attn_impl=self.spec.execution.paged_attn_impl,
                    interpret=self._interpret)
            else:
                logits, cache = self._traced_model.decode_step(
                    params, cache, state.last, state.index,
                    block_tables=block_tables)
            toks = sample_per_slot(logits[:, 0], keys, state.temp,
                                   state.top_k, state.top_p)

            act = state.active
            act_i = act.astype(jnp.int32)
            rows = jnp.arange(self.max_batch)
            pos = jnp.minimum(state.count, self.max_len - 1)
            buf = state.buf.at[rows, pos].set(
                jnp.where(act, toks, state.buf[rows, pos]))
            count = state.count + act_i
            index = state.index + act_i
            hit_eos = act & (state.eos >= 0) & (toks == state.eos)
            # cache-full is index >= max_len: position max_len-1 is a real,
            # usable slot (the historical `max_len - 1` check wasted it)
            finish = act & (hit_eos | (count >= state.budget)
                            | (index >= self.max_len))
            state = state._replace(
                last=jnp.where(act[:, None], toks[:, None], state.last),
                index=index,
                active=act & ~finish,
                done=state.done | finish,
                count=count,
                buf=buf,
                rng=rng)
            return self._pin_outputs(cache, state)

    def _mixed_impl(self, params, cache, state: SlotState, block_tables,
                    chunk_len):
        """THE fused step of the chunked scheduler: one dispatch advances
        every slot by up to W = chunk_size lanes — prompt chunks for
        prefilling slots (``chunk_len[b]`` > 0, tokens gathered on device
        from ``prompt_buf``), the next decode token for decoding slots,
        nothing for idle ones — then samples, scatters tokens and
        advances indices/budgets/eos flags.  Zero host syncs; chunk
        grants are data, so this traces exactly once."""
        if self.speculation is not None:
            return self._spec_impl(params, cache, state, block_tables,
                                   chunk_len, decode_only=False)
        with backend.use(self.matmul_backend), self._mesh_scope():
            B, W = self.max_batch, self.chunk_size
            rng, keys = split_keys(state.rng)
            prefilling = chunk_len > 0
            decoding = state.active & (state.pf_pos >= state.prompt_len)
            n_live = jnp.where(prefilling, chunk_len,
                               jnp.where(decoding, 1, 0))
            start = jnp.where(prefilling, state.pf_pos, state.index)
            # lane tokens: the slot's next prompt window, or its last
            # sampled token in lane 0 (dead lanes carry garbage that the
            # lane masks drop)
            gidx = jnp.minimum(
                start[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :],
                self.max_len - 1)
            ptoks = jnp.take_along_axis(state.prompt_buf, gidx, axis=1)
            dtoks = jnp.pad(state.last, ((0, 0), (0, W - 1)))
            toks = jnp.where(prefilling[:, None], ptoks, dtoks)
            if self.fabric is not None:
                logits, cache = self.fabric.mixed_step(
                    params, cache, toks, start, n_live, state.topo,
                    block_tables=block_tables,
                    paged_attn_impl=self.spec.execution.paged_attn_impl,
                    interpret=self._interpret)
            else:
                logits, cache = self._traced_model.mixed_step(
                    params, cache, toks, start, n_live,
                    block_tables=block_tables, prefill_lanes=prefilling)

            # sampling lane: a completing prompt's last live lane, else 0
            completes = prefilling & \
                (state.pf_pos + chunk_len >= state.prompt_len)
            sel = jnp.where(completes, chunk_len - 1, 0)
            lsel = jnp.take_along_axis(logits, sel[:, None, None],
                                       axis=1)[:, 0]
            toks_s = sample_per_slot(lsel, keys, state.temp, state.top_k,
                                     state.top_p)

            emit = decoding | completes   # slots producing a token now
            rows = jnp.arange(B)
            pos = jnp.minimum(state.count, self.max_len - 1)
            buf = state.buf.at[rows, pos].set(
                jnp.where(emit, toks_s, state.buf[rows, pos]))
            count = state.count + emit.astype(jnp.int32)
            index = state.index + n_live
            pf_pos = state.pf_pos + jnp.where(prefilling, chunk_len, 0)
            hit_eos = emit & (state.eos >= 0) & (toks_s == state.eos)
            finish = emit & (hit_eos | (count >= state.budget)
                             | (index >= self.max_len))
            state = state._replace(
                last=jnp.where(emit[:, None], toks_s[:, None], state.last),
                index=index,
                active=state.active & ~finish,
                done=state.done | finish,
                count=count,
                buf=buf,
                rng=rng,
                pf_pos=pf_pos)
            return self._pin_outputs(cache, state)

    def _spec_impl(self, params, cache, state: SlotState, block_tables,
                   chunk_len, decode_only: bool):
        """The speculative fused step: draft-propose -> target-verify ->
        accept/rollback, ONE dispatch, zero host syncs.

        ``params``/``cache`` are ``(target, draft)`` pairs — the draft
        decodes from its own private dense cache inside this same jitted
        program.  Per decoding slot: the draft proposes ``k`` tokens
        (one masked ``mixed_step`` lane each, positions ``index + j``),
        then the target scores all ``k + 1`` positions in a single
        chunk-shaped attend — exactly the chunked-prefill machinery
        (``gqa_mixed``/``gqa_mixed_paged`` walking the block tables), so
        a verify pass costs one mixed dispatch, not k+1 decode steps.
        Acceptance is cumulative (``serving.sampling.speculative_accept``)
        and the *rollback is an index rewind*: ``index`` advances only by
        the accepted length m <= k+1, so the rejected suffix's stale KV
        sits beyond every causal mask and is overwritten by the next
        step's writes at the same positions.  Block-table tails freed by
        the rewind are reclaimed host-side (``_truncate_slot_blocks``).

        ``decode_only=True`` is the steady-state specialization (the
        ``_decode`` program): no prompt lanes anywhere, so the draft's
        chunk-prefill pass is dropped and the verify attend shrinks from
        ``chunk_size`` to ``k + 1`` lanes.
        """
        with backend.use(self.matmul_backend), self._mesh_scope():
            B = self.max_batch
            k = self.speculation.k
            greedy_mode = self.speculation.greedy_accept
            W = self.spec_horizon if decode_only else self.chunk_size
            rng, keys = split_keys(state.rng)
            if decode_only:
                prefilling = jnp.zeros((B,), bool)
            else:
                prefilling = chunk_len > 0
            decoding = state.active & (state.pf_pos >= state.prompt_len)
            p_t, p_d = params
            c_t, c_d = cache
            start = jnp.where(prefilling, state.pf_pos, state.index)

            if not decode_only:
                # draft rides the same prompt chunks: its private cache
                # must hold the prompt KV before it can propose (logits
                # discarded; a prefix-cache hit skips these positions for
                # the target but not the draft — see README, acceptance
                # simply degrades on the reused span)
                gidx = jnp.minimum(
                    start[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :],
                    self.max_len - 1)
                ptoks = jnp.take_along_axis(state.prompt_buf, gidx, axis=1)
                n_pf = jnp.where(prefilling, chunk_len, 0)
                _, c_d = self.draft_model.mixed_step(
                    p_d, c_d, ptoks, start, n_pf, prefill_lanes=prefilling)

            # draft proposes k tokens, one masked lane per inner pass
            # (mixed_step, NOT decode_step: dead lanes must write nothing
            # — idle and prefilling slots would corrupt their own cache)
            dec1 = jnp.where(decoding, 1, 0)
            cur = state.last
            proposals, dlogits = [], []
            for j in range(k):
                lg, c_d = self.draft_model.mixed_step(
                    p_d, c_d, cur, state.index + j, dec1)
                dl = lg[:, 0]
                g = jnp.argmax(dl, axis=-1).astype(jnp.int32)
                if greedy_mode:
                    d = g
                else:
                    # temperature-only proposal, matching the densities
                    # speculative_accept uses in its accept ratio
                    x = dl.astype(jnp.float32) \
                        / jnp.maximum(state.temp, 1e-6)[:, None]
                    dj = jax.vmap(jax.random.categorical)(
                        fold_in_keys(keys, j + 2), x).astype(jnp.int32)
                    d = jnp.where(state.temp <= 0.0, g, dj)
                proposals.append(d)
                dlogits.append(dl)
                cur = d[:, None]
            # write-only pass: park d_k's KV at index+k so a fully
            # accepted step leaves no hole in the draft cache (the next
            # propose pass attends across index..index+k)
            _, c_d = self.draft_model.mixed_step(
                p_d, c_d, cur, state.index + k, dec1)
            d_toks = jnp.stack(proposals, axis=1)          # [B, k]
            d_logits = jnp.stack(dlogits, axis=1)          # [B, k, V]

            # target verify: [last, d_1..d_k] occupy positions
            # index..index+k; lane j's logits condition on the prefix
            # plus proposals 1..j.  Lanes past the cache end are masked
            # (n_spec), their writes land in the null block.
            ver = jnp.concatenate([state.last, d_toks], axis=1)  # [B, k+1]
            ver_w = jnp.pad(ver, ((0, 0), (0, W - (k + 1))))
            n_spec = jnp.clip(self.max_len - state.index, 0, k + 1)
            n_live = jnp.where(prefilling, 0, jnp.where(decoding, n_spec, 0))
            toks = ver_w
            if not decode_only:
                n_live = jnp.where(prefilling, chunk_len, n_live)
                toks = jnp.where(prefilling[:, None], ptoks, ver_w)
            if self.fabric is not None:
                logits, c_t = self.fabric.mixed_step(
                    p_t, c_t, toks, start, n_live, state.topo,
                    block_tables=block_tables,
                    paged_attn_impl=self.spec.execution.paged_attn_impl,
                    interpret=self._interpret)
            else:
                logits, c_t = self._traced_model.mixed_step(
                    p_t, c_t, toks, start, n_live,
                    block_tables=block_tables, prefill_lanes=prefilling)

            # accept / rollback over the k+1 verify lanes
            n_acc, out = speculative_accept(
                logits[:, :k + 1], d_toks, d_logits, fold_in_keys(keys, 1),
                state.temp, greedy=greedy_mode)
            n_acc = jnp.minimum(n_acc, jnp.maximum(n_spec - 1, 0))
            jar = jnp.arange(k + 1, dtype=jnp.int32)[None, :]
            cand = (jar <= n_acc[:, None]) & decoding[:, None]
            room = state.count[:, None] + jar < state.budget[:, None]
            is_eos = (state.eos[:, None] >= 0) & (out == state.eos[:, None])
            stop = cand & room & is_eos
            eos_before = jnp.cumsum(stop.astype(jnp.int32), axis=1) \
                - stop.astype(jnp.int32)
            # valid lanes form a prefix run: room and eos cuts are
            # monotone in j, so m = sum(valid) and out[:, :m] is emitted
            valid = cand & room & (eos_before == 0)
            m = valid.sum(axis=1).astype(jnp.int32)

            rows = jnp.arange(B)
            # invalid lanes are routed out of bounds and dropped — a
            # where-write at a clamped position would race a valid lane's
            # scatter at max_len - 1
            wpos = jnp.where(valid, state.count[:, None] + jar, self.max_len)
            buf = state.buf.at[rows[:, None], wpos].set(out, mode="drop")
            count = state.count + m
            index = state.index + jnp.where(decoding, m, 0)
            pf_pos = state.pf_pos
            last_dec = jnp.take_along_axis(
                out, jnp.maximum(m - 1, 0)[:, None], axis=1)[:, 0]
            lastv = jnp.where(decoding, last_dec, state.last[:, 0])
            emit = decoding
            hit_eos = stop.any(axis=1)

            if not decode_only:
                # completing prompt chunks sample their first token from
                # the verify pass's own logits — identical to the base
                # mixed step
                completes = prefilling & \
                    (state.pf_pos + chunk_len >= state.prompt_len)
                sel = jnp.where(completes, chunk_len - 1, 0)
                lsel = jnp.take_along_axis(logits, sel[:, None, None],
                                           axis=1)[:, 0]
                toks_s = sample_per_slot(lsel, fold_in_keys(keys, 0),
                                         state.temp, state.top_k,
                                         state.top_p)
                buf = buf.at[rows, jnp.where(completes, count,
                                             self.max_len)].set(
                    toks_s, mode="drop")
                count = count + completes.astype(jnp.int32)
                index = index + jnp.where(prefilling, chunk_len, 0)
                pf_pos = pf_pos + jnp.where(prefilling, chunk_len, 0)
                lastv = jnp.where(completes, toks_s, lastv)
                emit = emit | completes
                hit_eos = hit_eos | (completes & (state.eos >= 0)
                                     & (toks_s == state.eos))

            finish = emit & (hit_eos | (count >= state.budget)
                             | (index >= self.max_len))
            state = state._replace(
                last=lastv[:, None],
                index=index,
                active=state.active & ~finish,
                done=state.done | finish,
                count=count,
                buf=buf,
                rng=rng,
                pf_pos=pf_pos,
                acc=state.acc + jnp.where(decoding,
                                          jnp.maximum(m - 1, 0), 0),
                spec_steps=state.spec_steps + decoding.astype(jnp.int32))
            c_t, state = self._pin_outputs(c_t, state)
            if self._mesh is not None:
                rep = shd.replicated(self._mesh)
                c_d = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(x, rep), c_d)
            return (c_t, c_d), state

    # ------------------------------------------------------------------
    # host-side control (dispatch-only between syncs)
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        if self.scheduler == "chunked":
            self._admit_chunked()
            return
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            prompt = req.prompt + req.prefix
            plen = len(prompt)
            budget = req.max_new_tokens - len(req.prefix)
            bucket = next((b for b in self.buckets if b >= plen), None)
            if bucket is None:
                raise ValueError(
                    f"prompt length {plen} exceeds max_len={self.max_len}")
            blocks: list[int] | None = None
            if self.paging is not None:
                # block-budget admission: seat the request iff its prompt's
                # blocks are free right now (FCFS — the queue head waits
                # rather than being overtaken by shorter prompts)
                blocks = self.allocator.alloc(blocks_for_tokens(
                    plen, self.paging.block_size))
                if blocks is None:
                    break
            self.queue.pop(0)
            if bucket not in self._prefill:
                if self.fabric is not None:
                    self._prefill[bucket] = jax.jit(
                        lambda p, t, tp, _b=bucket:
                        self._prefill_fabric_impl(_b, p, t, tp))
                else:
                    self._prefill[bucket] = jax.jit(
                        lambda p, t, e, _b=bucket:
                        self._prefill_impl(_b, p, t, e))
            toks = jnp.asarray(prompt + [0] * (bucket - plen), jnp.int32)[None]
            topo_row = jnp.zeros((N_REGS,), jnp.int32)
            if self.fabric is not None:
                topo_row = jnp.asarray(self._fleet_rows[req.model], jnp.int32)
                logits, one_cache = self._prefill[bucket](self.params, toks,
                                                          topo_row)
            else:
                extras = {}
                if self.cfg.frontend is not None:
                    extras["frontend"] = jnp.zeros(
                        (1, self.cfg.frontend.num_tokens, self.cfg.d_model),
                        jnp.bfloat16)
                logits, one_cache = self._prefill[bucket](self.params, toks,
                                                          extras)
            if self.paging is not None:
                self._slot_blocks[slot] = blocks
                row = blocks + [NULL_BLOCK] * (self.blocks_per_slot
                                               - len(blocks))
                self._tables[slot] = row
                self._tables_dirty = True
                self.cache = self._insert_paged(
                    self.cache, one_cache, jnp.asarray(row, jnp.int32), bucket)
            else:
                self.cache = self._insert(self.cache, one_cache, slot, bucket)
            sp = req.sampling or self.sampling
            temp, top_k, top_p = sp.as_arrays()
            self.state = self._admit_slot(
                self.state, logits[:, plen - 1], jnp.int32(slot),
                jnp.int32(plen), jnp.int32(budget),
                jnp.int32(-1 if req.eos_id is None else req.eos_id),
                temp, top_k, top_p, topo_row)
            req.slot = slot
            self.slot_req[slot] = req
            self._plen[slot] = plen
            self._budget[slot] = budget
            self._idx_ub[slot] = plen
            self._pf[slot] = plen
            self._seq += 1
            self._admit_seq[slot] = self._seq
            self._emit("admit", req.uid, slot=slot, cached_tokens=0)
            # the bucketed prefill dispatch samples the first token itself
            self._emit_first_token(req.uid)

    def _admit_chunked(self) -> None:
        """Token-budget admission: seat a request by *writing its prompt*
        into the device-resident chunk source — no prefill dispatch, no
        bucket compile.  The fused mixed step earns its first token once
        the scheduler has granted all its chunks."""
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            prompt = req.prompt + req.prefix
            plen = len(prompt)
            budget = req.max_new_tokens - len(req.prefix)
            start = 0
            if self.paging is not None:
                total = blocks_for_tokens(plen, self.paging.block_size)
                if self.prefix_cache is not None:
                    # consult the trie BEFORE allocating: the hit's blocks
                    # are pinned (incref + unpark) so the eviction the
                    # allocation below may trigger cannot reclaim them.
                    # The cached span is capped at plen - 1 — the last
                    # prompt token always runs through the model, because
                    # the first sample needs its logits.
                    hit = self.prefix_cache.lookup(
                        self._namespace(req.model), prompt, plen - 1)
                    self.prefix_cache.acquire(hit)
                    fresh = self._alloc_blocks(total - len(hit.blocks))
                    if fresh is None:
                        self.prefix_cache.release(hit)
                        break   # FCFS: the queue head waits for blocks
                    blocks = hit.blocks + fresh
                    start = hit.tokens
                    if hit.fork_block is not None:
                        # mid-block divergence: fork the partial source
                        # into the request's first private block, then
                        # unpin the source — concurrent writers never
                        # alias a shared block
                        self.cache = self._cow(self.cache,
                                               jnp.int32(hit.fork_block),
                                               jnp.int32(fresh[0]))
                        self.prefix_cache.drop_fork_source(hit)
                        start += hit.fork_tokens
                        self.stats["cow_forks"] += 1
                    if start:
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_hit_tokens"] += start
                else:
                    blocks = self.allocator.alloc(total)
                    if blocks is None:
                        break   # FCFS: the queue head waits for blocks
                self._slot_blocks[slot] = blocks
                row = blocks + [NULL_BLOCK] * (self.blocks_per_slot
                                               - len(blocks))
                self._tables[slot] = row
                self._tables_dirty = True
            self.queue.pop(0)
            toks = jnp.asarray(prompt + [0] * (self.max_len - plen),
                               jnp.int32)
            topo_row = jnp.zeros((N_REGS,), jnp.int32)
            if self.fabric is not None:
                topo_row = jnp.asarray(self._fleet_rows[req.model], jnp.int32)
            sp = req.sampling or self.sampling
            temp, top_k, top_p = sp.as_arrays()
            self.state = self._admit_chunk(
                self.state, jnp.int32(slot), toks, jnp.int32(plen),
                jnp.int32(budget),
                jnp.int32(-1 if req.eos_id is None else req.eos_id),
                temp, top_k, top_p, topo_row, jnp.int32(start))
            req.slot = slot
            self.slot_req[slot] = req
            self._plen[slot] = plen
            self._budget[slot] = budget
            # the scheduler's mirrors start at the cached span: the token
            # budget is charged only for the uncached suffix
            self._idx_ub[slot] = start
            self._pf[slot] = start
            self._reg_done[slot] = False
            self._seq += 1
            self._admit_seq[slot] = self._seq
            self._emit("admit", req.uid, slot=slot, cached_tokens=start)

    def _grant_chunks(self) -> list[int]:
        """The token-budget scheduler: up to ``token_budget`` prompt
        tokens per fused step, at most ``chunk_size`` per slot, split
        fairly across the prefilling slots (decode lanes ride along for
        free).  The fair share is what kills head-of-line blocking: a
        long prompt cannot monopolize the budget, so a short prompt
        admitted beside it still completes its prefill in one or two
        steps.  Leftover budget goes FCFS by admission order.  Pure host
        arithmetic over exact mirrors — no device read."""
        grants = [0] * self.max_batch
        order = [s for s in sorted(self._occupied(),
                                   key=lambda t: self._admit_seq[t])
                 if self._pf[s] < self._plen[s]]
        if not order:
            return grants
        share = max(min(self.token_budget // len(order), self.chunk_size), 1)
        left = self.token_budget
        for cap in (share, self.chunk_size):   # fair pass, then leftovers
            for slot in order:
                rem = self._plen[slot] - self._pf[slot] - grants[slot]
                g = min(cap - grants[slot], rem, left)
                if g <= 0:
                    continue
                grants[slot] += g
                left -= g
        return grants

    def _occupied(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _namespace(self, model: int):
        """Prefix-trie namespace of one request's KV blocks.  Fleet
        members share the physical pool but never a trie chain — a
        prompt's KV is a function of the model that prefilled it."""
        if self.fabric is not None:
            return self.fabric.cache_namespace(self.fleet[model], model)
        return 0

    # -- paged block budgeting ----------------------------------------
    def _alloc_blocks(self, n: int) -> list[int] | None:
        """``allocator.alloc`` with the LRU eviction tier behind it: when
        the free list cannot cover ``n``, parked (unreferenced but
        trie-cached) blocks are evicted oldest-first to make room before
        the caller falls back to preempting live requests."""
        got = self.allocator.alloc(n)
        if got is None and self.prefix_cache is not None:
            freed = self.prefix_cache.evict(n - self.allocator.num_free)
            if freed:
                self.stats["prefix_evictions"] += freed
                got = self.allocator.alloc(n)
        return got

    def _slot_token_cap(self, slot: int) -> int:
        """Most cache positions this slot can ever need (then it finishes)."""
        return min(self._plen[slot] + self._budget[slot] - 1, self.max_len)

    def _ensure_capacity(self, horizon: int) -> None:
        """Pre-reserve blocks so the next ``horizon`` fused steps cannot
        write outside a slot's blocks (the fused step itself never talks
        to the allocator).  Oldest slots are served first; when the pool
        runs dry the most recently admitted slot is preempted."""
        if self.paging is None:
            return
        bs = self.paging.block_size
        # a speculative step writes up to spec_horizon (= k+1) verify
        # positions per fused step instead of 1, so the reservation
        # window scales with it (over-reserved tails are reclaimed at
        # the next sync by _truncate_slot_blocks)
        h = horizon * self.spec_horizon
        for slot in sorted(self._occupied(),
                           key=lambda s: self._admit_seq[s]):
            if self.slot_req[slot] is None:   # preempted by an earlier turn
                continue
            if self._pf[slot] < self._plen[slot]:
                # a mid-prefill slot owns its prompt's blocks already; it
                # needs >= 1 step to finish the prompt, so it can write at
                # most horizon - 1 decode tokens on top within the window
                need_tokens = min(self._plen[slot] + h - 1,
                                  self._slot_token_cap(slot))
            else:
                need_tokens = min(self._idx_ub[slot] + h,
                                  self._slot_token_cap(slot))
            missing = blocks_for_tokens(need_tokens, bs) \
                - len(self._slot_blocks[slot])
            while missing > 0:
                got = self._alloc_blocks(missing)
                if got is not None:
                    n_have = len(self._slot_blocks[slot])
                    self._slot_blocks[slot] += got
                    row = self._tables[slot]
                    row[n_have:n_have + len(got)] = got
                    self._tables_dirty = True
                    break
                victims = [s for s in self._occupied() if s != slot]
                if not victims:
                    raise RuntimeError(
                        f"paged pool exhausted: {missing} more blocks needed "
                        f"for slot {slot} with no other slot to preempt — "
                        f"num_blocks={self.paging.num_blocks} cannot hold one "
                        "full request; increase num_blocks")
                self._preempt(max(victims, key=lambda s: self._admit_seq[s]))

    def _release_slot_blocks(self, slot: int) -> None:
        """Release a slot's blocks and null out its table row.

        With prefix caching this is a *decref*, not a free: blocks other
        requests still map just lose one reference, blocks the trie owns
        are parked in the LRU tier at refcount zero, and only unshared,
        uncached blocks return to the free list."""
        if self.prefix_cache is not None:
            zeros = self.allocator.decref(self._slot_blocks[slot])
            self.allocator.free(self.prefix_cache.park(zeros))
        else:
            self.allocator.free(self._slot_blocks[slot])
        self._slot_blocks[slot] = []
        self._tables[slot] = [NULL_BLOCK] * self.blocks_per_slot
        self._tables_dirty = True
        self._reg_done[slot] = False

    def _truncate_slot_blocks(self, slot: int, keep_tokens: int) -> None:
        """Roll back a slot's block tail after rejected speculation: the
        dispatch loop reserved ``spec_horizon`` positions per step but
        the accepted length is only known at the sync, so blocks past
        the last resident token are handed back through the decref-aware
        ``BlockAllocator.truncate`` — still-shared blocks just lose one
        reference, trie-owned blocks park in the LRU tier (never free:
        another request's prefix may gather from them), and only
        private, uncached blocks return to the free list.  The table
        tail is nulled so the next verify pass's masked overrun writes
        land in the null block, never a reassigned one."""
        keep = blocks_for_tokens(keep_tokens, self.paging.block_size)
        blocks = self._slot_blocks[slot]
        if keep >= len(blocks):
            return
        kept, zeros = self.allocator.truncate(blocks, keep)
        if self.prefix_cache is not None:
            zeros = self.prefix_cache.park(zeros)
        self.allocator.free(zeros)
        self._slot_blocks[slot] = kept
        row = self._tables[slot]
        row[keep:] = [NULL_BLOCK] * (self.blocks_per_slot - keep)
        self._tables_dirty = True

    def _preempt(self, slot: int) -> None:
        """Recompute-preemption: bank the slot's generated tokens, free its
        blocks, and push the request back to the queue head — it resumes
        by re-entering the scheduler with prompt+banked tokens (greedy
        streams are unchanged; the request keeps its uid and budget).  A
        slot preempted *mid-prefill* has banked nothing and simply
        restarts its chunk sequence from the prompt head."""
        req = self.slot_req[slot]
        # ONE bulk device_get for the whole bank (count + tokens), sliced
        # host-side: the per-slot count-then-buffer pair used to cost two
        # blocking syncs per preemption (RA005).  The transfer is bounded
        # by the host-known budget mirror, never max_len columns.
        cap = min(self._budget[slot], self.max_len)
        cnt_d, row = jax.device_get(
            (self.state.count[slot], self.state.buf[slot, :cap]))
        self.stats["device_gets"] += 1
        cnt = int(cnt_d)
        if cnt > 0:
            self.stats["harvest_elems"] += cnt
            req.prefix = req.prefix + [int(t) for t in row[:cnt]]
        self.state = self._evict_slot(self.state, jnp.int32(slot))
        if self.paging is not None:
            self._release_slot_blocks(slot)
        self.slot_req[slot] = None
        self._pf[slot] = 0
        req.slot = None
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        self._emit("preempt", req.uid, banked=len(req.prefix))

    def _dispatch(self) -> None:
        if self.paging is not None and self._tables_dirty:
            self.block_tables = jnp.asarray(self._tables, jnp.int32)
            self._tables_dirty = False
        if self.scheduler == "chunked":
            grants = self._grant_chunks()
            granted = sum(grants)
            # under speculation the draft rides inside the same dispatch:
            # the jitted step takes (target, draft) pairs for params and
            # cache, and the donated tuple comes back the same shape
            params: object = self.params
            cache: object = self.cache
            if self.speculation is not None:
                params = (self.params, self.draft_params)
                cache = (self.cache, self.draft_cache)
            if granted:
                cache, self.state = self._step(
                    params, cache, self.state, self.block_tables,
                    jnp.asarray(grants, jnp.int32))
            else:
                # steady state (no prompt work anywhere): the one-lane
                # fused decode is the W == 1 special case of the mixed
                # step — same math, same rng schedule, ~chunk_size x less
                # query compute.  Still exactly one dispatch per step.
                cache, self.state = self._decode(
                    params, cache, self.state, self.block_tables)
            if self.speculation is not None:
                self.cache, self.draft_cache = cache
            else:
                self.cache = cache
            self.stats["decode_steps"] += 1
            self.stats["prefill_tokens"] += granted
            self.stats["max_step_prefill_tokens"] = max(
                self.stats["max_step_prefill_tokens"], granted)
            for slot in self._occupied():
                if grants[slot]:
                    self._pf[slot] += grants[slot]
                    self._idx_ub[slot] = self._pf[slot]
                    if self._pf[slot] >= self._plen[slot]:
                        # this dispatch's completing chunk sampled the
                        # slot's first token (``completes`` in the step)
                        self._emit_first_token(self.slot_req[slot].uid)
                elif self._pf[slot] >= self._plen[slot]:
                    # a speculative step can land up to k+1 tokens; the
                    # mirror is an upper bound until the next sync
                    self._idx_ub[slot] = min(
                        self._idx_ub[slot] + self.spec_horizon,
                        self._slot_token_cap(slot))
            if self.prefix_cache is not None:
                self._register_prefixes()
            return
        self.cache, self.state = self._decode(self.params, self.cache,
                                              self.state, self.block_tables)
        self.stats["decode_steps"] += 1
        for slot in self._occupied():
            self._idx_ub[slot] = min(self._idx_ub[slot] + 1,
                                     self._slot_token_cap(slot))

    def _register_prefixes(self) -> None:
        """Register every slot whose prefill just completed: its whole
        prompt blocks enter the trie (existing chains win — the slot's
        duplicate block simply stays private and is freed at release).
        One-shot per occupancy; registration happens right after the
        completing dispatch, so any later reader's gather is ordered
        behind the writes by the device queue itself."""
        bs = self.paging.block_size
        for slot in self._occupied():
            if self._reg_done[slot] or self._pf[slot] < self._plen[slot]:
                continue
            req = self.slot_req[slot]
            tokens = req.prompt + req.prefix
            n_full = len(tokens) // bs
            if n_full:
                self.prefix_cache.insert(self._namespace(req.model), tokens,
                                         self._slot_blocks[slot][:n_full])
            self._reg_done[slot] = True

    def _harvest(self) -> list[Request]:
        """One bulk device_get of the done/count vectors; token buffers are
        pulled (one more bulk get) only for slots that actually finished,
        sliced to the longest finished stream — the transfer scales with
        the tokens produced, not with max_len."""
        if self.speculation is not None:
            done_h, count_h, acc_h, ss_h = jax.device_get(
                (self.state.done, self.state.count, self.state.acc,
                 self.state.spec_steps))
        else:
            done_h, count_h = jax.device_get(
                (self.state.done, self.state.count))
            acc_h = ss_h = None
        self.stats["device_gets"] += 1
        occ = self._occupied()
        slots = [i for i in occ if done_h[i]]
        for i in occ:   # sync point: tighten the index upper bounds
            if self._pf[i] < self._plen[i]:
                self._idx_ub[i] = self._pf[i]   # mid-prefill: mirror exact
            else:
                self._idx_ub[i] = self._plen[i] + max(int(count_h[i]) - 1, 0)
                if (self.speculation is not None and self.paging is not None
                        and not done_h[i]):
                    # speculative rollback, host half: the dispatch loop
                    # reserved spec_horizon positions/step; now that the
                    # exact resident length is known, hand the rejected
                    # tail's blocks back (shared ones park, never free)
                    self._truncate_slot_blocks(i, self._idx_ub[i])
            # completion-honest telemetry: the device_get above ordered
            # this sync behind the dispatched steps, so these counts (and
            # their wall stamps) reflect tokens that actually exist
            if acc_h is not None:
                self._emit("progress", self.slot_req[i].uid,
                           count=int(count_h[i]), accepted=int(acc_h[i]),
                           spec_steps=int(ss_h[i]))
            else:
                self._emit("progress", self.slot_req[i].uid,
                           count=int(count_h[i]))
        if not slots:
            return []
        maxc = max(int(count_h[i]) for i in slots)
        bufs = jax.device_get(
            self.state.buf[jnp.asarray(slots, jnp.int32), :maxc])
        self.stats["device_gets"] += 1
        self.stats["harvest_elems"] += len(slots) * maxc
        finished = []
        for row, i in zip(bufs, slots):
            req = self.slot_req[i]
            req.generated = req.prefix + [int(t) for t in row[:count_h[i]]]
            req.done = True
            if acc_h is not None:
                self.stats["spec_accepted"] += int(acc_h[i])
                self.stats["spec_steps"] += int(ss_h[i])
            self.slot_req[i] = None
            if self.paging is not None:
                self._release_slot_blocks(i)
            finished.append(req)
            self._emit("finish", req.uid, n_generated=len(req.generated))
        return finished

    def step(self) -> list[Request]:
        """Admit waiting requests, advance every active slot one token.
        Returns requests completed this step."""
        self._admit()
        if not self._occupied():
            return []
        self._ensure_capacity(1)
        self._dispatch()
        return self._harvest()

    def run_to_completion(self, max_steps: int = 10_000,
                          sync_every: int = 1) -> list[Request]:
        """Drain queue + slots.  ``sync_every=k`` dispatches k fused steps
        back-to-back before each harvest sync (admission and block
        reservation also happen at sync points, so large k trades
        slot-refill latency for zero host reads in steady state)."""
        done: list[Request] = []
        steps = 0
        while steps < max_steps:
            self._admit()
            if not self._occupied():
                break
            window = min(max(1, sync_every), max_steps - steps)
            self._ensure_capacity(window)
            for _ in range(window):
                self._dispatch()
                steps += 1
            done += self._harvest()
        return done

    @property
    def compilations(self) -> _Compilations:
        """Compile-count accounting (the Alg. 18 amortization claim).

        ``"prefill"``/``"decode"`` count the compilations serving each
        role.  Under the chunked scheduler both name the ONE fused mixed
        step — prefill stopped being a separate program.
        ``"prefill_buckets"`` is the legacy bucketed count and stays 0
        under the chunked scheduler; readers of it should migrate to
        ``compilations()["prefill"]``.
        """
        buckets = len(self._prefill)
        if self.scheduler == "chunked":
            n = self._step._cache_size()
            # the one-lane steady-state decode program may never compile
            # (workloads that always carry prompt work); the mixed step
            # is then the only program decoding
            return _Compilations(decode=self._decode._cache_size() or n,
                                 prefill=n, prefill_buckets=buckets)
        return _Compilations(decode=self._decode._cache_size(),
                             prefill=buckets, prefill_buckets=buckets)

    def memory_stats(self) -> FragmentationStats:
        """Pool occupancy + fragmentation (paged layout only).  Exact at
        sync points; between syncs resident tokens are an upper bound."""
        if self.paging is None:
            raise ValueError("memory_stats requires cache_layout='paged'")
        self.allocator.set_used_tokens(
            sum(self._idx_ub[i] for i in self._occupied()))
        return self.allocator.stats()
