"""Batched serving engine with device-resident continuous batching.

Compile-once discipline (the paper's Alg. 18 applied to serving):

* ``prefill_fn``  — compiled per prompt-length *bucket* (powers of two up
  to max_len): a new request is padded up to its bucket, prefilled at
  B=1, and its cache is scattered into a free slot of the shared batched
  cache.  Buckets bound the number of compilations the way the paper's
  maxima bound the fabric.
* ``decode_fn``   — compiled exactly once, and *fused*: model decode,
  sampling, per-slot index/budget/eos bookkeeping and the generated-token
  scatter all run in a single jitted step.  Idle slots compute masked
  garbage (idle PEs) that never reaches a live output.

Host↔device discipline (the paper's "no host intervention beyond the
topology registers"): **all** per-slot state — last sampled token, cache
position, remaining budget, eos id, active/done flags, and the generated
token ring — lives in device arrays (``SlotState``).  The host only
*dispatches* the fused step and harvests finished requests with one bulk
``device_get`` of the (done, count) vectors per sync — O(1) transfers
per step regardless of ``max_batch``, versus the seed engine's
O(max_batch) scalar round trips per decoded token.
``run_to_completion(sync_every=k)`` stretches that further: k fused
steps are dispatched back-to-back with no host read at all in between.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import backend
from repro.models.model import Model
from repro.serving.sampling import SamplingParams, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int | None = None


class SlotState(NamedTuple):
    """All per-slot decode state, resident on device (one pytree)."""

    last: jax.Array    # [B, 1] i32  token fed to the next decode step
    index: jax.Array   # [B]    i32  cache write position
    active: jax.Array  # [B]    bool slot is decoding
    done: jax.Array    # [B]    bool finished, not yet harvested/reused
    budget: jax.Array  # [B]    i32  max_new_tokens (incl. prefill token)
    count: jax.Array   # [B]    i32  tokens generated so far
    eos: jax.Array     # [B]    i32  eos id, -1 = none
    buf: jax.Array     # [B, max_len] i32 generated tokens
    rng: jax.Array     # PRNG key threaded through the fused step


def _buckets(max_len: int, smallest: int = 32) -> list[int]:
    out, b = [], smallest
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


class ServingEngine:
    def __init__(self, model: Model, *, max_batch: int = 8,
                 max_len: int = 512,
                 sampling: SamplingParams = SamplingParams(),
                 rng: jax.Array | None = None,
                 matmul_backend: str | None = None):
        cfg = model.cfg
        if cfg.family == "encoder":
            raise ValueError("encoder-only archs have no decode step")
        self.model = model
        self.cfg: ArchConfig = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampling = sampling
        self.buckets = _buckets(max_len)
        # engine-level kernel routing ("xla" | "pallas"); None inherits the
        # model's ModelOptions.matmul_backend.  An explicit engine setting
        # must win even over a pallas-configured model, so tracing goes
        # through a shadow Model carrying the effective backend (nested
        # backend.use() contexts would let the model's innermost win).
        self.matmul_backend = matmul_backend or model.opt.matmul_backend
        if self.matmul_backend == model.opt.matmul_backend:
            self._traced_model = model
        else:
            self._traced_model = Model(model.cfg, dataclasses.replace(
                model.opt, matmul_backend=self.matmul_backend))

        self.params: Any = None
        self.cache: Any = None
        self.state: SlotState = self._init_state(
            rng if rng is not None else jax.random.PRNGKey(0))
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self._uid = 0
        # host↔device traffic accounting (asserted O(1)/step by the tests)
        self.stats = {"decode_steps": 0, "device_gets": 0}

        self._decode = jax.jit(self._decode_impl)
        self._prefill = {}   # bucket -> jitted fn
        self._insert = jax.jit(self._insert_impl, static_argnums=(3,))
        self._admit_slot = jax.jit(self._admit_slot_impl)

    # ------------------------------------------------------------------
    def _init_state(self, rng: jax.Array) -> SlotState:
        B = self.max_batch
        return SlotState(
            last=jnp.zeros((B, 1), jnp.int32),
            index=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool),
            done=jnp.zeros((B,), bool),
            budget=jnp.zeros((B,), jnp.int32),
            count=jnp.zeros((B,), jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
            buf=jnp.zeros((B, self.max_len), jnp.int32),
            rng=rng)

    def load(self, params) -> None:
        self.params = params
        self.cache = self.model.init_cache(self.max_batch, self.max_len)

    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               eos_id: int | None = None) -> int:
        if len(prompt) > self.max_len:
            # reject at the door: raising later, mid-drain, would abort
            # run_to_completion with live requests still in flight
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"max_len={self.max_len}")
        self._uid += 1
        self.queue.append(Request(self._uid, list(prompt), max_new_tokens,
                                  eos_id))
        return self._uid

    # ------------------------------------------------------------------
    # jitted impls (traced under the configured matmul backend)
    # ------------------------------------------------------------------
    def _prefill_impl(self, bucket: int, params, tokens, extras):
        with backend.use(self.matmul_backend):
            batch = {"tokens": tokens, **extras}
            logits, cache = self._traced_model.prefill(params, batch,
                                                       max_len=self.max_len)
            return logits, cache

    def _insert_impl(self, global_cache, one_cache, slot, _bucket):
        def put(g, o):
            if g.ndim == o.ndim and g.shape[0] == o.shape[0] and g.ndim >= 2 \
                    and g.shape[1] == self.max_batch:
                return g.at[:, slot].set(o[:, 0])      # [L, B, ...] stacked
            return g.at[slot].set(o[0])                # [B, ...] per-layer
        return jax.tree.map(put, global_cache, one_cache)

    def _admit_slot_impl(self, state: SlotState, last_logits, slot, plen,
                         budget, eos) -> SlotState:
        """Seat one prefilled request: sample its first token and reset
        every per-slot field — all on device, no host round trip."""
        rng, k = jax.random.split(state.rng)
        first = sample(last_logits, k, self.sampling)[0]
        fin = budget <= 1   # a 1-token budget is spent by the prefill sample
        return SlotState(
            last=state.last.at[slot, 0].set(first),
            index=state.index.at[slot].set(plen),
            active=state.active.at[slot].set(~fin),
            done=state.done.at[slot].set(fin),
            budget=state.budget.at[slot].set(budget),
            count=state.count.at[slot].set(1),
            eos=state.eos.at[slot].set(eos),
            buf=state.buf.at[slot].set(0).at[slot, 0].set(first),
            rng=rng)

    def _decode_impl(self, params, cache, state: SlotState):
        """The fused device step: decode -> sample -> scatter token ->
        advance indices/budgets -> raise done flags.  One dispatch, zero
        host syncs."""
        with backend.use(self.matmul_backend):
            rng, k = jax.random.split(state.rng)
            logits, cache = self._traced_model.decode_step(
                params, cache, state.last, state.index)
            toks = sample(logits[:, 0], k, self.sampling)

            act = state.active
            act_i = act.astype(jnp.int32)
            rows = jnp.arange(self.max_batch)
            pos = jnp.minimum(state.count, self.max_len - 1)
            buf = state.buf.at[rows, pos].set(
                jnp.where(act, toks, state.buf[rows, pos]))
            count = state.count + act_i
            index = state.index + act_i
            hit_eos = act & (state.eos >= 0) & (toks == state.eos)
            finish = act & (hit_eos | (count >= state.budget)
                            | (index >= self.max_len - 1))
            state = SlotState(
                last=jnp.where(act[:, None], toks[:, None], state.last),
                index=index,
                active=act & ~finish,
                done=state.done | finish,
                budget=state.budget,
                count=count,
                eos=state.eos,
                buf=buf,
                rng=rng)
            return cache, state

    # ------------------------------------------------------------------
    # host-side control (dispatch-only between syncs)
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            bucket = next((b for b in self.buckets if b >= plen), None)
            if bucket is None:
                raise ValueError(
                    f"prompt length {plen} exceeds max_len={self.max_len}")
            if bucket not in self._prefill:
                self._prefill[bucket] = jax.jit(
                    lambda p, t, e, _b=bucket: self._prefill_impl(_b, p, t, e))
            toks = jnp.asarray(req.prompt + [0] * (bucket - plen),
                               jnp.int32)[None]
            extras = {}
            if self.cfg.frontend is not None:
                extras["frontend"] = jnp.zeros(
                    (1, self.cfg.frontend.num_tokens, self.cfg.d_model),
                    jnp.bfloat16)
            logits, one_cache = self._prefill[bucket](self.params, toks, extras)
            self.cache = self._insert(self.cache, one_cache, slot, bucket)
            self.state = self._admit_slot(
                self.state, logits[:, plen - 1], jnp.int32(slot),
                jnp.int32(plen), jnp.int32(req.max_new_tokens),
                jnp.int32(-1 if req.eos_id is None else req.eos_id))
            req.slot = slot
            self.slot_req[slot] = req

    def _occupied(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _dispatch(self) -> None:
        self.cache, self.state = self._decode(self.params, self.cache,
                                              self.state)
        self.stats["decode_steps"] += 1

    def _harvest(self) -> list[Request]:
        """One bulk device_get of the done/count vectors; token buffers are
        pulled (one more bulk get) only for slots that actually finished."""
        done_h, count_h = jax.device_get((self.state.done, self.state.count))
        self.stats["device_gets"] += 1
        slots = [i for i in self._occupied() if done_h[i]]
        if not slots:
            return []
        bufs = jax.device_get(self.state.buf[jnp.asarray(slots, jnp.int32)])
        self.stats["device_gets"] += 1
        finished = []
        for row, i in zip(bufs, slots):
            req = self.slot_req[i]
            req.generated = [int(t) for t in row[:count_h[i]]]
            req.done = True
            self.slot_req[i] = None
            finished.append(req)
        return finished

    def step(self) -> list[Request]:
        """Admit waiting requests, advance every active slot one token.
        Returns requests completed this step."""
        self._admit()
        if not self._occupied():
            return []
        self._dispatch()
        return self._harvest()

    def run_to_completion(self, max_steps: int = 10_000,
                          sync_every: int = 1) -> list[Request]:
        """Drain queue + slots.  ``sync_every=k`` dispatches k fused steps
        back-to-back before each harvest sync (admission also happens at
        sync points, so large k trades slot-refill latency for zero host
        reads in steady state)."""
        done: list[Request] = []
        steps = 0
        while steps < max_steps:
            self._admit()
            if not self._occupied():
                break
            for _ in range(min(max(1, sync_every), max_steps - steps)):
                self._dispatch()
                steps += 1
            done += self._harvest()
        return done

    @property
    def compilations(self) -> dict[str, int]:
        """Compile-count accounting (the Alg. 18 amortization claim)."""
        return {"decode": self._decode._cache_size(),
                "prefill_buckets": len(self._prefill)}
