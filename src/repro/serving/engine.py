"""Batched serving engine with slot-based continuous batching.

Compile-once discipline (the paper's Alg. 18 applied to serving):

* ``prefill_fn``  — compiled per prompt-length *bucket* (powers of two up
  to max_len): a new request is padded up to its bucket, prefilled at
  B=1, and its cache is scattered into a free slot of the shared batched
  cache.  Buckets bound the number of compilations the way the paper's
  maxima bound the fabric.
* ``decode_fn``   — compiled exactly once: all slots advance together
  with per-slot cache indices; idle slots compute masked garbage (idle
  PEs) that never reaches a live output.

Per-request state stays on the host; all device state is two pytrees
(params, batched cache) plus the per-slot index vector.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.serving.sampling import SamplingParams, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int | None = None


def _buckets(max_len: int, smallest: int = 32) -> list[int]:
    out, b = [], smallest
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


class ServingEngine:
    def __init__(self, model: Model, *, max_batch: int = 8,
                 max_len: int = 512,
                 sampling: SamplingParams = SamplingParams(),
                 rng: jax.Array | None = None):
        cfg = model.cfg
        if cfg.family == "encoder":
            raise ValueError("encoder-only archs have no decode step")
        self.model = model
        self.cfg: ArchConfig = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampling = sampling
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.buckets = _buckets(max_len)

        self.params: Any = None
        self.cache: Any = None
        self.indices = jnp.zeros((max_batch,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self._uid = 0

        self._decode = jax.jit(self._decode_impl)
        self._prefill = {}   # bucket -> jitted fn
        self._insert = jax.jit(self._insert_impl, static_argnums=(3,))

    # ------------------------------------------------------------------
    def load(self, params) -> None:
        self.params = params
        self.cache = self.model.init_cache(self.max_batch, self.max_len)

    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               eos_id: int | None = None) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, list(prompt), max_new_tokens,
                                  eos_id))
        return self._uid

    # ------------------------------------------------------------------
    def _prefill_impl(self, bucket: int, params, tokens, extras):
        batch = {"tokens": tokens, **extras}
        logits, cache = self.model.prefill(params, batch, max_len=self.max_len)
        return logits, cache

    def _insert_impl(self, global_cache, one_cache, slot, _bucket):
        def put(g, o):
            if g.ndim == o.ndim and g.shape[0] == o.shape[0] and g.ndim >= 2 \
                    and g.shape[1] == self.max_batch:
                return g.at[:, slot].set(o[:, 0])      # [L, B, ...] stacked
            return g.at[slot].set(o[0])                # [B, ...] per-layer
        return jax.tree.map(put, global_cache, one_cache)

    def _decode_impl(self, params, cache, tokens, indices, rng):
        logits, cache = self.model.decode_step(params, cache, tokens, indices)
        toks = sample(logits[:, 0], rng, self.sampling)
        return toks, cache

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            bucket = next(b for b in self.buckets if b >= plen)
            if bucket not in self._prefill:
                self._prefill[bucket] = jax.jit(
                    lambda p, t, e, _b=bucket: self._prefill_impl(_b, p, t, e))
            toks = jnp.asarray(req.prompt + [0] * (bucket - plen),
                               jnp.int32)[None]
            extras = {}
            if self.cfg.frontend is not None:
                extras["frontend"] = jnp.zeros(
                    (1, self.cfg.frontend.num_tokens, self.cfg.d_model),
                    jnp.bfloat16)
            logits, one_cache = self._prefill[bucket](self.params, toks, extras)
            self.cache = self._insert(self.cache, one_cache, slot, bucket)
            self.indices = self.indices.at[slot].set(plen)
            # first generated token comes from the last prompt position
            self.rng, k = jax.random.split(self.rng)
            first = sample(logits[:, plen - 1], k, self.sampling)
            req.generated.append(int(first[0]))
            req.slot = slot
            self.slot_req[slot] = req

    def _active(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def step(self) -> list[Request]:
        """Admit waiting requests, advance every active slot one token.
        Returns requests completed this step."""
        self._admit()
        active = self._active()
        if not active:
            return []
        tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        for i in active:
            tokens = tokens.at[i, 0].set(self.slot_req[i].generated[-1])
        self.rng, k = jax.random.split(self.rng)
        next_toks, self.cache = self._decode(self.params, self.cache, tokens,
                                             self.indices, k)
        self.indices = self.indices + jnp.asarray(
            [1 if self.slot_req[i] is not None else 0
             for i in range(self.max_batch)], jnp.int32)
        finished = []
        for i in active:
            req = self.slot_req[i]
            tok = int(next_toks[i])
            req.generated.append(tok)
            idx = int(self.indices[i])
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or idx >= self.max_len - 1):
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and not self._active():
                break
        return done

    @property
    def compilations(self) -> dict[str, int]:
        """Compile-count accounting (the Alg. 18 amortization claim)."""
        return {"decode": self._decode._cache_size(),
                "prefill_buckets": len(self._prefill)}
