"""Token sampling: greedy / temperature / top-k / top-p.

Two entry points:

* ``sample``          — trace-time ``SamplingParams`` constants (training
  eval, benchmarks, single-stream decode).  Uses ``lax.top_k`` and skips
  disabled filters entirely, so the compiled step is minimal.
* ``sample_per_slot`` — the serving path: temperature / top_k / top_p are
  **[B] device arrays**, i.e. data rather than trace constants, so one
  compiled fused decode step serves any per-request mixture (greedy rows
  included) without retracing.  The price is a full-vocab sort per step
  regardless of which filters are active — the compile-once discipline
  applied to sampling.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> disabled
    top_p: float = 1.0         # 1 -> disabled

    def as_arrays(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Scalar device operands for the jit-safe per-slot path."""
        return (jnp.float32(self.temperature), jnp.int32(self.top_k),
                jnp.float32(self.top_p))


def sample(logits: jax.Array, rng: jax.Array,
           params: SamplingParams) -> jax.Array:
    """logits: [B, V] -> tokens [B] int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / params.temperature
    if params.top_k:
        # lax.top_k instead of a full-vocab sort: this runs inside the
        # fused decode step, once per generated token
        kth = jax.lax.top_k(x, params.top_k)[0][:, -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    if params.top_p < 1.0:
        sorted_x = jnp.sort(x, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_x, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        keep = cum - probs < params.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_x, jnp.inf), axis=-1,
                         keepdims=True)
        x = jnp.where(x < cutoff, -jnp.inf, x)
    return jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)


# jit-region
def sample_per_slot(logits: jax.Array, rng: jax.Array,
                    temperature: jax.Array, top_k: jax.Array,
                    top_p: jax.Array) -> jax.Array:
    """logits [B, V]; temperature/top_p f32 [B], top_k i32 [B] -> [B] i32.

    Rows with temperature <= 0 are greedy (bit-identical to ``sample``'s
    greedy path); top_k == 0 and top_p == 1.0 disable those filters per
    row.  Everything is data, nothing retraces.
    """
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)[:, None]
    sorted_x = jnp.sort(x, axis=-1)[:, ::-1]
    # top-k: mask everything below the k-th largest (k == 0 -> keep all)
    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    kth = jnp.take_along_axis(sorted_x, (k_eff - 1)[:, None], axis=-1)
    x = jnp.where(x < kth, -jnp.inf, x)
    # top-p over the already-top-k-filtered distribution (same composition
    # as the static path); filtered entries have prob 0 and never shrink
    # the kept set, so top_p == 1.0 keeps everything.  Masking the sorted
    # array keeps it sorted — no second full-vocab sort in the fused step.
    sorted_f = jnp.where(sorted_x < kth, -jnp.inf, sorted_x)
    probs = jax.nn.softmax(sorted_f, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = cum - probs < top_p[:, None]
    cutoff = jnp.min(jnp.where(keep, sorted_f, jnp.inf), axis=-1,
                     keepdims=True)
    x = jnp.where(x < cutoff, -jnp.inf, x)
    toks = jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, toks)
