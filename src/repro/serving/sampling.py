"""Token sampling: greedy / temperature / top-k / top-p.

Two entry points:

* ``sample``          — trace-time ``SamplingParams`` constants (training
  eval, benchmarks, single-stream decode).  Uses ``lax.top_k`` and skips
  disabled filters entirely, so the compiled step is minimal.
* ``sample_per_slot`` — the serving path: temperature / top_k / top_p are
  **[B] device arrays**, i.e. data rather than trace constants, so one
  compiled fused decode step serves any per-request mixture (greedy rows
  included) without retracing.  The price is a full-vocab sort per step
  regardless of which filters are active — the compile-once discipline
  applied to sampling.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> disabled
    top_p: float = 1.0         # 1 -> disabled

    def as_arrays(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Scalar device operands for the jit-safe per-slot path."""
        return (jnp.float32(self.temperature), jnp.int32(self.top_k),
                jnp.float32(self.top_p))


def sample(logits: jax.Array, rng: jax.Array,
           params: SamplingParams) -> jax.Array:
    """logits: [B, V] -> tokens [B] int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / params.temperature
    if params.top_k:
        # lax.top_k instead of a full-vocab sort: this runs inside the
        # fused decode step, once per generated token
        kth = jax.lax.top_k(x, params.top_k)[0][:, -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    if params.top_p < 1.0:
        sorted_x = jnp.sort(x, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_x, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        keep = cum - probs < params.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_x, jnp.inf), axis=-1,
                         keepdims=True)
        x = jnp.where(x < cutoff, -jnp.inf, x)
    return jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)


# jit-region
def split_keys(keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split per-slot PRNG key lanes [B, 2] -> (carry [B, 2], use [B, 2]).

    The serving engine carries one key *per slot* in ``SlotState.rng``
    and splits every lane once per fused step: each slot's stream is a
    pure function of its own lane, so the harness can replay a trace
    byte-identically regardless of which other slots were resident.
    """
    both = jax.vmap(jax.random.split)(keys)        # [B, 2, 2]
    return both[:, 0], both[:, 1]


# jit-region
def fold_in_keys(keys: jax.Array, data: int) -> jax.Array:
    """Per-slot ``fold_in``: derive a named substream from each [B, 2]
    key lane (draft step j, accept pass, ...) without consuming it."""
    return jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, data)


# jit-region
def sample_per_slot(logits: jax.Array, rng: jax.Array,
                    temperature: jax.Array, top_k: jax.Array,
                    top_p: jax.Array) -> jax.Array:
    """logits [B, V]; temperature/top_p f32 [B], top_k i32 [B] -> [B] i32.

    Rows with temperature <= 0 are greedy (bit-identical to ``sample``'s
    greedy path); top_k == 0 and top_p == 1.0 disable those filters per
    row.  Everything is data, nothing retraces.

    ``rng`` is either one key [2] shared across rows (the historical
    shape) or per-slot key lanes [B, 2] — the serving path, where each
    slot draws from its own stream so replays are slot-local.
    """
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)[:, None]
    sorted_x = jnp.sort(x, axis=-1)[:, ::-1]
    # top-k: mask everything below the k-th largest (k == 0 -> keep all)
    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    kth = jnp.take_along_axis(sorted_x, (k_eff - 1)[:, None], axis=-1)
    x = jnp.where(x < kth, -jnp.inf, x)
    # top-p over the already-top-k-filtered distribution (same composition
    # as the static path); filtered entries have prob 0 and never shrink
    # the kept set, so top_p == 1.0 keeps everything.  Masking the sorted
    # array keeps it sorted — no second full-vocab sort in the fused step.
    sorted_f = jnp.where(sorted_x < kth, -jnp.inf, sorted_x)
    probs = jax.nn.softmax(sorted_f, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = cum - probs < top_p[:, None]
    cutoff = jnp.min(jnp.where(keep, sorted_f, jnp.inf), axis=-1,
                     keepdims=True)
    x = jnp.where(x < cutoff, -jnp.inf, x)
    if rng.ndim == 2:
        toks = jax.vmap(jax.random.categorical)(rng, x).astype(jnp.int32)
    else:
        toks = jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, toks)


# jit-region
def speculative_accept(target_logits: jax.Array, draft_toks: jax.Array,
                       draft_logits: jax.Array, keys: jax.Array,
                       temperature: jax.Array,
                       greedy: bool = True) -> tuple[jax.Array, jax.Array]:
    """Vectorized per-slot accept/reject over one verify pass.

    ``target_logits`` [B, k+1, V]: lane ``j`` is the target distribution
    after the prefix plus draft tokens ``1..j``; ``draft_toks`` [B, k]
    are the proposals (``draft_toks[:, j]`` was drawn from lane ``j`` of
    ``draft_logits`` [B, k, V]); ``keys`` [B, 2] per-slot key lanes.

    Returns ``(n_acc [B] i32, out [B, k+1] i32)``: ``out[:, :n_acc]``
    are the accepted proposals and ``out[:, n_acc]`` is the bonus /
    correction token, so a slot emits ``n_acc + 1`` tokens.

    Greedy path (``greedy=True`` or temperature <= 0): proposal ``j+1``
    is accepted iff it equals the target argmax at lane ``j``
    (cumulative AND), and since an accepted proposal *is* that argmax,
    ``out`` is simply the per-lane argmax — the emitted stream is
    token-identical to target-only greedy decode by induction.

    Stochastic path: standard rejection sampling — accept with
    probability ``min(1, p_t(d)/p_d(d))`` on the temperature-softened
    distributions; on the first reject, resample from the normalized
    residual ``max(p_t - p_d, 0)``.  (top-k/top-p filters are not
    applied on this path; greedy rows are exact regardless.)
    """
    b, lanes, _ = target_logits.shape
    k = lanes - 1
    g = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, k+1]
    match = g[:, :k] == draft_toks                            # [B, k]
    g_acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    # ``greedy`` is a trace-time Python bool (SpeculationSpec.greedy_accept,
    # fixed per engine), so this branch specializes the program, it never
    # retraces
    if greedy:  # ra: ignore[RA002]
        return g_acc, g

    t = jnp.maximum(temperature, 1e-6)[:, None, None]
    pt = jax.nn.softmax(target_logits.astype(jnp.float32) / t, axis=-1)
    pd = jax.nn.softmax(draft_logits.astype(jnp.float32) / t[:, :k], axis=-1)
    rows = jnp.arange(b)[:, None]
    cols = jnp.arange(k)[None, :]
    pt_d = pt[rows, cols, draft_toks]                         # [B, k]
    pd_d = pd[rows, cols, draft_toks]
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(
        fold_in_keys(keys, 0))
    ok = u * pd_d < pt_d                                      # [B, k]
    s_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    # residual at the reject lane (lane k when everything was accepted:
    # the residual degenerates to pt itself because pd is a one-hot of
    # nothing there — we just gather pt at lane s_acc and subtract a
    # zeroed pd slice)
    sel = jnp.minimum(s_acc, k)[:, None, None]
    pt_r = jnp.take_along_axis(pt, sel, axis=1)[:, 0]         # [B, V]
    pd_pad = jnp.concatenate(
        [pd, jnp.zeros_like(pd[:, :1])], axis=1)              # [B, k+1, V]
    pd_r = jnp.take_along_axis(pd_pad, sel, axis=1)[:, 0]
    resid = jnp.maximum(pt_r - jnp.where(s_acc[:, None] < k, pd_r, 0.0), 0.0)
    resid = resid / jnp.maximum(resid.sum(-1, keepdims=True), 1e-20)
    bonus = jax.vmap(jax.random.categorical)(
        fold_in_keys(keys, 1), jnp.log(jnp.maximum(resid, 1e-38))
    ).astype(jnp.int32)
    # out[:, j] = accepted proposal for j < n_acc, bonus at j == n_acc
    jar = jnp.arange(k + 1)[None, :]
    d_pad = jnp.concatenate(
        [draft_toks, jnp.zeros_like(draft_toks[:, :1])], axis=1)
    s_out = jnp.where(jar < s_acc[:, None], d_pad,
                      jnp.where(jar == s_acc[:, None], bonus[:, None], 0))
    is_greedy = temperature <= 0.0
    n_acc = jnp.where(is_greedy, g_acc, s_acc).astype(jnp.int32)
    out = jnp.where(is_greedy[:, None], g, s_out)
    return n_acc, out
