"""Token sampling: greedy / temperature / top-k / top-p."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> disabled
    top_p: float = 1.0         # 1 -> disabled


def sample(logits: jax.Array, rng: jax.Array,
           params: SamplingParams) -> jax.Array:
    """logits: [B, V] -> tokens [B] int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / params.temperature
    if params.top_k:
        # lax.top_k instead of a full-vocab sort: this runs inside the
        # fused decode step, once per generated token
        kth = jax.lax.top_k(x, params.top_k)[0][:, -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    if params.top_p < 1.0:
        sorted_x = jnp.sort(x, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_x, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        keep = cum - probs < params.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_x, jnp.inf), axis=-1,
                         keepdims=True)
        x = jnp.where(x < cutoff, -jnp.inf, x)
    return jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)
